"""LifeRaft-JAX: data-driven batch processing for TPU training & serving.

Reproduction + TPU-native extension of Wang, Burns & Malik, "LifeRaft:
Data-Driven, Batch Processing for the Exploration of Scientific
Databases" (CIDR 2009).  See DESIGN.md for the mapping.
"""
__version__ = "1.0.0"

"""SkyQuery-like query trace generation + workload statistics.

The paper's trace (§5.1): 2,000 long-running cross-match queries; the top
ten buckets are reused by 61% of queries (Fig. 5); 2% of buckets capture
50% of the workload (Fig. 6); temporally-close queries overlap in data
access.  ``make_trace`` generates traces with those properties (hotspot
Zipf popularity + temporal locality + Poisson/bursty arrivals) and
``workload_stats`` verifies them.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.sfc import htm_id, _normalize
from ..core.workload import Query
from .catalog import SkyCatalog

__all__ = ["TraceConfig", "make_trace", "workload_stats", "cone_sample"]


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_queries: int = 2_000
    arrival_rate: float = 0.25  # queries/sec (the paper's 'saturation')
    bursty: bool = False  # Markov-modulated burst arrivals
    burst_factor: float = 8.0
    burst_p: float = 0.05  # P(enter burst) per arrival
    # Query shape
    n_hotspots: int = 32
    zipf_s: float = 1.4  # hotspot popularity exponent
    hotspot_frac: float = 0.75  # queries targeting a hotspot (vs random sky)
    temporal_locality: float = 0.6  # P(reuse previous query's hotspot)
    objects_median: int = 400
    objects_sigma: float = 1.0  # lognormal sigma for per-query object count
    cone_radius_med: float = 0.06  # radians
    fullsky_frac: float = 0.04  # long 'navigate the entire sky' queries
    match_level_offset: int = 2  # bounding range = ancestor trixel this much coarser
    seed: int = 0


def cone_sample(center: np.ndarray, radius: float, n: int, rng) -> np.ndarray:
    """Uniform sample of ``n`` unit vectors within angular ``radius`` of center."""
    z = rng.uniform(np.cos(radius), 1.0, size=n)
    phi = rng.uniform(0.0, 2 * np.pi, size=n)
    r = np.sqrt(1 - z**2)
    local = np.stack([r * np.cos(phi), r * np.sin(phi), z], axis=-1)
    # Rotate +z to center.
    c = center / np.linalg.norm(center)
    if abs(c[2]) > 0.9999:
        return local if c[2] > 0 else local * np.array([1.0, 1.0, -1.0])
    axis = np.cross([0.0, 0.0, 1.0], c)
    axis = axis / np.linalg.norm(axis)
    ang = np.arccos(np.clip(c[2], -1, 1))
    K = np.array(
        [[0, -axis[2], axis[1]], [axis[2], 0, -axis[0]], [-axis[1], axis[0], 0]]
    )
    R = np.eye(3) + np.sin(ang) * K + (1 - np.cos(ang)) * (K @ K)
    return _normalize(local @ R.T)


def _zipf_probs(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


def make_trace(catalog: SkyCatalog, cfg: TraceConfig = TraceConfig()) -> list[Query]:
    """Generate a cross-match trace against ``catalog``.

    Each query carries the probe objects' unit vectors (payload) and
    per-object HTM bounding ranges; the WorkloadManager maps these to
    buckets via the catalog partitioner.
    """
    rng = np.random.default_rng(cfg.seed)
    hot = _normalize(rng.normal(size=(cfg.n_hotspots, 3)))
    probs = _zipf_probs(cfg.n_hotspots, cfg.zipf_s)
    level = catalog.level
    shift = np.uint64(2 * cfg.match_level_offset)

    queries: list[Query] = []
    t = 0.0
    in_burst = False
    prev_hotspot = 0
    for qid in range(cfg.n_queries):
        # --- arrivals (Poisson, optionally Markov-modulated bursts) ---
        rate = cfg.arrival_rate * (cfg.burst_factor if in_burst else 1.0)
        t += rng.exponential(1.0 / rate)
        if cfg.bursty:
            if in_burst:
                in_burst = rng.random() > 0.3  # bursts are short
            else:
                in_burst = rng.random() < cfg.burst_p

        # --- spatial target ---
        fullsky = rng.random() < cfg.fullsky_frac
        if fullsky:
            n_obj = int(
                rng.lognormal(np.log(cfg.objects_median * 8), cfg.objects_sigma)
            )
            pos = _normalize(rng.normal(size=(max(n_obj, 1), 3)))
        else:
            if rng.random() < cfg.hotspot_frac:
                if rng.random() < cfg.temporal_locality:
                    h = prev_hotspot
                else:
                    h = int(rng.choice(cfg.n_hotspots, p=probs))
                prev_hotspot = h
                center = hot[h]
            else:
                center = _normalize(rng.normal(size=3))
            radius = rng.lognormal(np.log(cfg.cone_radius_med), 0.6)
            n_obj = int(rng.lognormal(np.log(cfg.objects_median), cfg.objects_sigma))
            pos = cone_sample(center, min(radius, np.pi), max(n_obj, 1), rng)

        ids = htm_id(pos, level=level)
        anc = ids >> shift
        lo = anc << shift
        hi = ((anc + np.uint64(1)) << shift) - np.uint64(1)
        queries.append(
            Query(
                query_id=qid,
                arrival_time=t,
                keys_lo=lo,
                keys_hi=hi,
                payload={"positions": pos},
                meta={"fullsky": fullsky},
            )
        )
    return queries


def workload_stats(
    queries: Sequence[Query], bucket_of_range, n_buckets: int,
    bucket_of_keys=None,
) -> dict:
    """Fig. 5 / Fig. 6 statistics for a trace.

    Returns top-10 bucket query-coverage fraction, the bucket fraction
    capturing 50% of workload objects, and the per-bucket histograms.
    """
    touch = np.zeros(n_buckets, dtype=np.int64)  # queries touching bucket
    load = np.zeros(n_buckets, dtype=np.int64)  # objects routed to bucket
    per_query_buckets: list[set[int]] = []
    for q in queries:
        bs: set[int] = set()
        if bucket_of_keys is not None and q.n_objects:
            lo_b = bucket_of_keys(q.keys_lo)
            hi_b = bucket_of_keys(q.keys_hi)
            simple = lo_b == hi_b
            np.add.at(load, lo_b[simple].astype(np.int64), 1)
            bs.update(np.unique(lo_b[simple]).astype(int).tolist())
            for i in np.nonzero(~simple)[0]:
                for b in range(int(lo_b[i]), int(hi_b[i]) + 1):
                    load[b] += 1
                    bs.add(b)
        else:
            for i in range(q.n_objects):
                for b in bucket_of_range(int(q.keys_lo[i]), int(q.keys_hi[i])):
                    load[int(b)] += 1
                    bs.add(int(b))
        for b in bs:
            touch[b] += 1
        per_query_buckets.append(bs)
    top10 = set(np.argsort(-touch)[:10].tolist())
    frac_queries_top10 = (
        sum(1 for bs in per_query_buckets if bs & top10) / max(len(queries), 1)
    )
    order = np.argsort(-load)
    csum = np.cumsum(load[order])
    total = max(int(csum[-1]), 1)
    k50 = int(np.searchsorted(csum, 0.5 * total)) + 1
    return {
        "touch": touch,
        "load": load,
        "top10_query_frac": frac_queries_top10,
        "bucket_frac_for_50pct": k50 / n_buckets,
        "gini_load": _gini(load),
    }


def _gini(x: np.ndarray) -> float:
    x = np.sort(np.asarray(x, dtype=np.float64))
    n = len(x)
    if n == 0 or x.sum() == 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)

"""Synthetic sky catalogs standing in for SDSS/2MASS/USNOB archives.

The paper evaluates on the SDSS fact table (6 TB) partitioned into ~20,000
buckets of 10,000 objects each.  We generate catalogs of unit vectors with
realistic *clustered* density (objects cluster on the sky, which is what
makes equal-count HTM buckets non-uniform in area), bucket them with the
real HTM curve from ``repro.core.sfc``, and expose a ``BucketStore``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.bucket import BucketStore, Partitioner
from ..core.sfc import htm_id, unit_vectors, _normalize

__all__ = ["SkyCatalog", "make_catalog"]


@dataclasses.dataclass
class SkyCatalog:
    """A bucketed point catalog on the unit sphere."""

    positions: np.ndarray  # (n, 3) float64 unit vectors
    mags: np.ndarray  # (n,) synthetic magnitude attribute
    htm: np.ndarray  # (n,) uint64 HTM ids
    partitioner: Partitioner
    store: BucketStore
    level: int

    @property
    def n_objects(self) -> int:
        return len(self.positions)

    @property
    def n_buckets(self) -> int:
        return self.partitioner.n_buckets


def make_catalog(
    n_objects: int = 200_000,
    objects_per_bucket: int = 1_000,
    n_clusters: int = 64,
    cluster_frac: float = 0.5,
    cluster_scale: float = 0.05,
    htm_level: int = 10,
    seed: int = 0,
) -> SkyCatalog:
    """Clustered synthetic catalog.

    ``cluster_frac`` of objects fall in ``n_clusters`` Gaussian blobs
    (angular sigma ``cluster_scale`` rad) — mimicking galactic-plane /
    survey-footprint density — the rest are uniform.  Clustering is what
    gives the workload its Zipf-like bucket contention (Figs. 5/6).
    """
    rng = np.random.default_rng(seed)
    n_cl = int(n_objects * cluster_frac)
    n_un = n_objects - n_cl
    uni = unit_vectors(n_un, seed=seed + 1)
    centers = unit_vectors(n_clusters, seed=seed + 2)
    which = rng.integers(0, n_clusters, size=n_cl)
    pts = centers[which] + rng.normal(scale=cluster_scale, size=(n_cl, 3))
    clustered = _normalize(pts)
    positions = np.concatenate([uni, clustered], axis=0)
    rng.shuffle(positions, axis=0)
    mags = rng.uniform(14.0, 24.0, size=n_objects)

    ids = htm_id(positions, level=htm_level)
    part = Partitioner(ids, objects_per_bucket=objects_per_bucket)
    store = BucketStore(part, {"positions": positions, "mags": mags, "htm": ids})
    return SkyCatalog(
        positions=positions,
        mags=mags,
        htm=ids,
        partitioner=part,
        store=store,
        level=htm_level,
    )

"""Faithful application: SkyQuery-style astronomy cross-match."""
from .catalog import SkyCatalog, make_catalog
from .engine import CrossMatchEngine, MatchResult, ShardedCrossMatch
from .trace import TraceConfig, cone_sample, make_trace, workload_stats

__all__ = [
    "SkyCatalog",
    "make_catalog",
    "CrossMatchEngine",
    "MatchResult",
    "ShardedCrossMatch",
    "TraceConfig",
    "cone_sample",
    "make_trace",
    "workload_stats",
]

"""End-to-end cross-match engine: core scheduler + real join compute.

This is the paper's Fig. 3 wired together:

  Query Pre-Processor  -> WorkloadManager.submit
  Workload Manager     -> per-bucket workload queues + ages
  LifeRaft Scheduler   -> argmax U_a bucket selection (incremental index)
  Join Evaluator       -> hybrid plan + the cross-match kernel
  Bucket Cache         -> LRU over bucket payloads

The join itself runs as real JAX compute (``repro.kernels.crossmatch``):
probe objects of *every* pending query for the chosen bucket are batched
into one device call — the paper's single shared pass.  With
``fuse_k > 1`` the engine goes one step further: the top-k buckets by U_a
are evaluated in ONE segment-masked device call (``crossmatch_fused``),
amortizing dispatch across buckets the way the paper amortizes disk reads
across queries.  Probe batches are shape-bucketed to powers of two inside
the kernel wrappers, so a long trace compiles O(log max_batch) kernel
variants instead of one per distinct batch size.

Per-query predicates (here: magnitude cuts) are applied on the matched
tuples before results are routed back to their parent queries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.cache import BucketCache
from ..core.control import ControlLoop, TenantControlPlane
from ..core.dispatch import DispatchLoop
from ..core.hybrid import HybridPlanner
from ..core.metrics import CostModel, per_tenant_latency
from ..core.prefetch import PrefetchConfig, build_pipeline
from ..core.scheduler import BucketScheduler, LifeRaftScheduler, SchedulerDecision
from ..core.workload import Query, WorkloadManager
from .catalog import SkyCatalog

__all__ = ["MatchResult", "CrossMatchEngine"]


@dataclasses.dataclass
class MatchResult:
    """Per-query cross-match output."""

    query_id: int
    probe_idx: np.ndarray  # indices into the query's probe list
    match_obj: np.ndarray  # matched catalog object row (global index)
    best_dot: np.ndarray  # cos(angular distance) of the best match
    n_candidates: np.ndarray  # matches within the radius (probabilistic join)


class CrossMatchEngine:
    def __init__(
        self,
        catalog: SkyCatalog,
        scheduler: Optional[BucketScheduler] = None,
        cost_model: Optional[CostModel] = None,
        cache_capacity: int = 20,
        match_radius_rad: float = 1e-3,
        hybrid: Optional[HybridPlanner] = None,
        use_pallas: bool = False,
        mag_cut: float = 24.0,
        fuse_k: int = 1,
        control: Optional[ControlLoop | TenantControlPlane] = None,
        prefetch: bool | PrefetchConfig = False,
    ) -> None:
        self.catalog = catalog
        self.cost_model = cost_model or CostModel()
        self.scheduler = scheduler or LifeRaftScheduler(self.cost_model, alpha=0.25)
        # Queries are tenant-classed by their meta['tenant'] tag; probe
        # bytes price the §6 overflow budget (CostModel.probe_bytes).
        self.wm = WorkloadManager(
            catalog.partitioner.buckets_for_range,
            probe_bytes=self.cost_model.probe_bytes,
            min_unit_bytes=self.cost_model.min_unit_bytes,
        )
        self.cache = BucketCache(cache_capacity)
        self.cos_thr = float(np.cos(match_radius_rad))
        self.hybrid = hybrid
        self.use_pallas = use_pallas
        self.mag_cut = mag_cut
        self.fuse_k = max(1, int(fuse_k))
        self.results: dict[int, list[MatchResult]] = {}
        self.max_probe_batch = 0  # largest probe batch sent to the device
        # The shared scheduling inner loop; the controller (when given) is
        # consulted there, once per round, never here.  With ``prefetch``
        # on, horizon buckets are staged by real threaded store reads
        # while cost accounting stays on the virtual T_b channel.
        self.loop = DispatchLoop(
            self.scheduler, self.wm, self.cache, self._execute,
            control=control, fuse_k=self.fuse_k,
            tenant_of=self.wm.tenant_of_bucket,
            prefetch=build_pipeline(
                prefetch, self.scheduler, self.cache, self.cost_model.T_b,
                fetch=self.catalog.store.read,
            ),
        )

    # -- loop-owned counters (kept as attributes for back-compat) --------------
    @property
    def sim_clock(self) -> float:
        return self.loop.clock

    @sim_clock.setter
    def sim_clock(self, value: float) -> None:
        self.loop.clock = value

    @property
    def batches(self) -> int:
        return self.loop.batches  # buckets serviced

    @property
    def dispatches(self) -> int:
        return self.loop.dispatches  # device calls (== batches unless fused)

    # -- intake ----------------------------------------------------------------
    def submit(self, query: Query) -> None:
        self.wm.submit(query)
        self.loop.observe_arrival(query.arrival_time)
        self.results.setdefault(query.query_id, [])

    # -- per-bucket plumbing ---------------------------------------------------
    def _plan_and_fetch(self, decision: SchedulerDecision):
        """Hybrid plan + bucket payload with unified cache accounting:
        every resident read records a hit via ``cache.access`` (the indexed
        plan used to read through ``cache.get`` and skew the hit-rate);
        only scan plans establish residency on a miss.

        Residency is re-probed here rather than taken from the decision:
        within a fused dispatch an earlier bucket's insertion can evict a
        later one, and plan/cost must reflect the read that actually
        happens (the decision's snapshot only fed the priority score)."""
        b = decision.bucket_id
        in_cache = self.cache.contains(b)
        plan = (
            self.hybrid.plan(decision.queue_size, in_cache)
            if self.hybrid
            else None
        )
        if in_cache:
            payload = self.cache.get(b)
            self.cache.access(b)  # counts the hit, refreshes LRU
        else:
            payload = self.catalog.store.read(b)  # the 'disk read'
            if plan is None or plan.strategy == "scan":
                self.cache.access(b, payload)
            else:
                # Indexed cold read: no residency, but hit_rate must see
                # the miss or skewed stats return (symmetric accounting).
                self.cache.note_bypass_miss()
        cost = (
            plan.est_cost
            if plan is not None
            else self.cost_model.batch_cost(
                decision.queue_size, in_cache, self.wm.spilled_fraction(b)
            )
        )
        return plan, payload, cost

    def _gather_probes(self, bucket_id: int):
        q = self.wm.queue(bucket_id)
        # Servicing evaluates the whole queue — the spilled suffix is paged
        # back in for the pass (T_spill already charged in the cost).
        units = q.units + q.spilled_units
        probe_pos = np.concatenate(
            [
                self.wm.queries[u.query_id].payload["positions"][u.object_idx]
                for u in units
            ]
        )
        owners = np.concatenate(
            [np.full(u.size, u.query_id, dtype=np.int64) for u in units]
        )
        probe_local = np.concatenate([u.object_idx for u in units])
        return units, probe_pos, owners, probe_local

    def _route(
        self, bucket_id, units, owners, probe_local, best_idx, best_dot, n_cand,
        payload,
    ) -> None:
        matched = n_cand > 0
        # Per-query predicate on the joined tuples (paper: "query specific
        # predicates are applied on the output tuples that succeed").
        mags = np.asarray(payload["mags"])[
            np.clip(best_idx, 0, len(payload["mags"]) - 1)
        ]
        matched &= mags <= self.mag_cut
        global_rows = self.catalog.partitioner.object_slice(bucket_id)
        for u in units:
            sel = (owners == u.query_id) & matched
            if not sel.any():
                continue
            self.results[u.query_id].append(
                MatchResult(
                    query_id=u.query_id,
                    probe_idx=probe_local[sel],
                    match_obj=global_rows[best_idx[sel]],
                    best_dot=best_dot[sel],
                    n_candidates=n_cand[sel],
                )
            )

    # -- one scheduling step -----------------------------------------------------
    def step(self) -> Optional[int]:
        """Service one scheduling round (1 bucket, or top-k fused); returns
        the highest-priority bucket id serviced, or None if idle."""
        outcome = self.loop.round()
        return None if outcome is None else outcome.decisions[0].bucket_id

    def _execute(self, decisions, vector) -> float:
        """DispatchLoop executor: the batched/fused device call + routing.
        Returns the round's wall-clock cost."""
        from ..kernels.crossmatch import ops as cm_ops

        total_cost = 0.0
        if len(decisions) == 1:
            decision = decisions[0]
            b = decision.bucket_id
            _, payload, cost = self._plan_and_fetch(decision)
            total_cost += cost
            units, probe_pos, owners, probe_local = self._gather_probes(b)
            self.max_probe_batch = max(self.max_probe_batch, len(probe_pos))
            # --- the shared pass: one batched device call for every query ---
            best_idx, best_dot, n_cand = cm_ops.crossmatch(
                np.asarray(payload["positions"], dtype=np.float32),
                probe_pos.astype(np.float32),
                self.cos_thr,
                use_pallas=self.use_pallas,
            )
            self._route(
                b, units, owners, probe_local,
                np.asarray(best_idx), np.asarray(best_dot), np.asarray(n_cand),
                payload,
            )
        else:
            # --- fused multi-bucket pass: top-k buckets, ONE device call ---
            per_bucket = []
            bucket_parts, probe_parts, bseg, pseg = [], [], [], []
            row_off = 0
            for s, decision in enumerate(decisions):
                b = decision.bucket_id
                _, payload, cost = self._plan_and_fetch(decision)
                total_cost += cost
                units, probe_pos, owners, probe_local = self._gather_probes(b)
                pos = np.asarray(payload["positions"], dtype=np.float32)
                bucket_parts.append(pos)
                probe_parts.append(probe_pos.astype(np.float32))
                bseg.append(np.full(len(pos), s, np.int32))
                pseg.append(np.full(len(probe_pos), s, np.int32))
                per_bucket.append(
                    (b, payload, units, owners, probe_local, row_off,
                     len(probe_pos))
                )
                row_off += len(pos)
            self.max_probe_batch = max(
                self.max_probe_batch, sum(len(p) for p in probe_parts)
            )
            best_idx, best_dot, n_cand = cm_ops.crossmatch_fused(
                np.concatenate(bucket_parts),
                np.concatenate(probe_parts),
                np.concatenate(bseg),
                np.concatenate(pseg),
                self.cos_thr,
                use_pallas=self.use_pallas,
            )
            best_idx = np.asarray(best_idx)
            best_dot = np.asarray(best_dot)
            n_cand = np.asarray(n_cand)
            p_off = 0
            for b, payload, units, owners, probe_local, row_off, n_p in per_bucket:
                sl = slice(p_off, p_off + n_p)
                p_off += n_p
                local_idx = np.clip(
                    best_idx[sl] - row_off, 0, len(payload["mags"]) - 1
                )
                self._route(
                    b, units, owners, probe_local,
                    local_idx, best_dot[sl], n_cand[sl], payload,
                )

        return total_cost

    # -- drive a whole trace -------------------------------------------------------
    def run(self, queries: Sequence[Query]) -> dict[int, list[MatchResult]]:
        """Arrival-ordered replay: admit, then drain between arrivals."""
        for q in sorted(queries, key=lambda q: q.arrival_time):
            self.sim_clock = max(self.sim_clock, q.arrival_time)
            self.submit(q)
        while self.step() is not None:
            pass
        self.close()  # reap prefetch workers; they respawn if reused
        return self.results

    def close(self) -> None:
        """Release the prefetch staging threads (no-op without prefetch;
        step()-driven callers should close when done)."""
        if self.loop.prefetch is not None:
            self.loop.prefetch.close()

    # -- metrics --------------------------------------------------------------------
    def summary(self) -> dict:
        rt = self.wm.response_times()
        tenants = {q.tenant for q in self.wm.queries.values()}
        return {
            "n_queries": len(rt),
            "n_batches": self.batches,
            "n_dispatches": self.dispatches,
            "mean_response": float(np.mean(list(rt.values()))) if rt else 0.0,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "makespan": self.sim_clock,
            "per_tenant": per_tenant_latency(
                rt, self.wm.tenant_of_query, max(self.sim_clock, 1e-9), tenants
            )
            if len(tenants) > 1
            else {},
        }

"""End-to-end cross-match engine: core scheduler + real join compute.

This is the paper's Fig. 3 wired together:

  Query Pre-Processor  -> WorkloadManager.submit
  Workload Manager     -> per-bucket workload queues + ages
  LifeRaft Scheduler   -> argmax U_a bucket selection
  Join Evaluator       -> hybrid plan + the cross-match kernel
  Bucket Cache         -> LRU over bucket payloads

The join itself runs as real JAX compute (``repro.kernels.crossmatch``):
probe objects of *every* pending query for the chosen bucket are batched
into one device call — the paper's single shared pass.  Per-query
predicates (here: magnitude cuts) are applied on the matched tuples before
results are routed back to their parent queries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.cache import BucketCache
from ..core.hybrid import HybridCostModel, HybridPlanner
from ..core.metrics import CostModel
from ..core.scheduler import BucketScheduler, LifeRaftScheduler
from ..core.workload import Query, WorkloadManager
from .catalog import SkyCatalog

__all__ = ["MatchResult", "CrossMatchEngine"]


@dataclasses.dataclass
class MatchResult:
    """Per-query cross-match output."""

    query_id: int
    probe_idx: np.ndarray  # indices into the query's probe list
    match_obj: np.ndarray  # matched catalog object row (global index)
    best_dot: np.ndarray  # cos(angular distance) of the best match
    n_candidates: np.ndarray  # matches within the radius (probabilistic join)


class CrossMatchEngine:
    def __init__(
        self,
        catalog: SkyCatalog,
        scheduler: Optional[BucketScheduler] = None,
        cost_model: Optional[CostModel] = None,
        cache_capacity: int = 20,
        match_radius_rad: float = 1e-3,
        hybrid: Optional[HybridPlanner] = None,
        use_pallas: bool = False,
        mag_cut: float = 24.0,
    ) -> None:
        self.catalog = catalog
        self.cost_model = cost_model or CostModel()
        self.scheduler = scheduler or LifeRaftScheduler(self.cost_model, alpha=0.25)
        self.wm = WorkloadManager(catalog.partitioner.buckets_for_range)
        self.cache = BucketCache(cache_capacity)
        self.cos_thr = float(np.cos(match_radius_rad))
        self.hybrid = hybrid
        self.use_pallas = use_pallas
        self.mag_cut = mag_cut
        self.results: dict[int, list[MatchResult]] = {}
        self.sim_clock = 0.0
        self.batches = 0

    # -- intake ----------------------------------------------------------------
    def submit(self, query: Query) -> None:
        self.wm.submit(query)
        self.results.setdefault(query.query_id, [])

    # -- one scheduling step -----------------------------------------------------
    def step(self) -> Optional[int]:
        """Service one bucket batch; returns the bucket id or None if idle."""
        decision = self.scheduler.select(self.wm, self.cache, self.sim_clock)
        if decision is None:
            return None
        b = decision.bucket_id
        plan = (
            self.hybrid.plan(decision.queue_size, decision.in_cache)
            if self.hybrid
            else None
        )
        # Bucket payload through the cache (the 'disk read').
        payload = self.cache.get(b) if self.cache.contains(b) else None
        if payload is None:
            payload = self.catalog.store.read(b)
        if plan is None or plan.strategy == "scan":
            self.cache.access(b, payload)

        units = list(self.wm.queue(b).units)
        probe_pos = np.concatenate(
            [self.wm.queries[u.query_id].payload["positions"][u.object_idx] for u in units]
        )
        owners = np.concatenate(
            [np.full(u.size, u.query_id, dtype=np.int64) for u in units]
        )
        probe_local = np.concatenate([u.object_idx for u in units])

        # --- the shared pass: one batched device call for every query ---
        from ..kernels.crossmatch import ops as cm_ops

        best_idx, best_dot, n_cand = cm_ops.crossmatch(
            np.asarray(payload["positions"], dtype=np.float32),
            probe_pos.astype(np.float32),
            self.cos_thr,
            use_pallas=self.use_pallas,
        )
        best_idx = np.asarray(best_idx)
        best_dot = np.asarray(best_dot)
        n_cand = np.asarray(n_cand)

        matched = n_cand > 0
        # Per-query predicate on the joined tuples (paper: "query specific
        # predicates are applied on the output tuples that succeed").
        mags = np.asarray(payload["mags"])[np.clip(best_idx, 0, len(payload["mags"]) - 1)]
        matched &= mags <= self.mag_cut
        global_rows = self.catalog.partitioner.object_slice(b)

        for u in units:
            sel = (owners == u.query_id) & matched
            if not sel.any():
                continue
            self.results[u.query_id].append(
                MatchResult(
                    query_id=u.query_id,
                    probe_idx=probe_local[sel],
                    match_obj=global_rows[best_idx[sel]],
                    best_dot=best_dot[sel],
                    n_candidates=n_cand[sel],
                )
            )
        cost = (
            plan.est_cost
            if plan is not None
            else self.cost_model.batch_cost(decision.queue_size, decision.in_cache)
        )
        self.sim_clock += cost
        self.batches += 1
        self.wm.complete_bucket(b, self.sim_clock)
        return b

    # -- drive a whole trace -------------------------------------------------------
    def run(self, queries: Sequence[Query]) -> dict[int, list[MatchResult]]:
        """Arrival-ordered replay: admit, then drain between arrivals."""
        for q in sorted(queries, key=lambda q: q.arrival_time):
            self.sim_clock = max(self.sim_clock, q.arrival_time)
            self.submit(q)
        while self.step() is not None:
            pass
        return self.results

    # -- metrics --------------------------------------------------------------------
    def summary(self) -> dict:
        rt = self.wm.response_times()
        return {
            "n_queries": len(rt),
            "n_batches": self.batches,
            "mean_response": float(np.mean(list(rt.values()))) if rt else 0.0,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "makespan": self.sim_clock,
        }

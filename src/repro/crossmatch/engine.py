"""End-to-end cross-match engine: core scheduler + real join compute.

This is the paper's Fig. 3 wired together:

  Query Pre-Processor  -> WorkloadManager.submit
  Workload Manager     -> per-bucket workload queues + ages
  LifeRaft Scheduler   -> argmax U_a bucket selection (incremental index)
  Join Evaluator       -> hybrid plan + the cross-match kernel
  Bucket Cache         -> LRU over bucket payloads

The join itself runs as real JAX compute (``repro.kernels.crossmatch``):
probe objects of *every* pending query for the chosen bucket are batched
into one device call — the paper's single shared pass.  With
``fuse_k > 1`` the engine goes one step further: the top-k buckets by U_a
are evaluated in ONE segment-masked device call (``crossmatch_fused``),
amortizing dispatch across buckets the way the paper amortizes disk reads
across queries.  Probe batches are shape-bucketed to powers of two inside
the kernel wrappers, so a long trace compiles O(log max_batch) kernel
variants instead of one per distinct batch size.

Per-query predicates (here: magnitude cuts) are applied on the matched
tuples before results are routed back to their parent queries.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence

import numpy as np

from ..core.cache import BucketCache
from ..core.control import ControlLoop, TenantControlPlane
from ..core.dispatch import DispatchLoop
from ..core.hybrid import HybridPlanner
from ..core.metrics import CostModel, dispatch_stats, per_tenant_latency
from ..core.prefetch import PrefetchConfig, build_pipeline
from ..core.scheduler import BucketScheduler, LifeRaftScheduler, SchedulerDecision
from ..core.shard import ShardMap, StealConfig, StealEvent, split_slots
from ..core.workload import Query, WorkloadManager
from .catalog import SkyCatalog

__all__ = ["MatchResult", "CrossMatchEngine", "ShardedCrossMatch"]


@dataclasses.dataclass
class MatchResult:
    """Per-query cross-match output."""

    query_id: int
    probe_idx: np.ndarray  # indices into the query's probe list
    match_obj: np.ndarray  # matched catalog object row (global index)
    best_dot: np.ndarray  # cos(angular distance) of the best match
    n_candidates: np.ndarray  # matches within the radius (probabilistic join)


class CrossMatchEngine:
    def __init__(
        self,
        catalog: SkyCatalog,
        scheduler: Optional[BucketScheduler] = None,
        cost_model: Optional[CostModel] = None,
        cache_capacity: int = 20,
        match_radius_rad: float = 1e-3,
        hybrid: Optional[HybridPlanner] = None,
        use_pallas: bool = False,
        mag_cut: float = 24.0,
        fuse_k: int = 1,
        control: Optional[ControlLoop | TenantControlPlane] = None,
        prefetch: bool | PrefetchConfig = False,
        shared_plan: bool = False,
        share_width: int = 8,
        obs=None,
    ) -> None:
        self.catalog = catalog
        self.cost_model = cost_model or CostModel()
        self.scheduler = scheduler or LifeRaftScheduler(self.cost_model, alpha=0.25)
        # Queries are tenant-classed by their meta['tenant'] tag; probe
        # bytes price the §6 overflow budget (CostModel.probe_bytes).
        self.wm = WorkloadManager(
            catalog.partitioner.buckets_for_range,
            probe_bytes=self.cost_model.probe_bytes,
            min_unit_bytes=self.cost_model.min_unit_bytes,
        )
        self.cache = BucketCache(cache_capacity)
        self.cos_thr = float(np.cos(match_radius_rad))
        self.hybrid = hybrid
        self.use_pallas = use_pallas
        self.mag_cut = mag_cut
        self.fuse_k = max(1, int(fuse_k))
        # Shared query plans: evaluate the whole query batch's predicates
        # in ONE masked device call (per share_width-sized chunk) instead
        # of one dispatch per predicate class.  Off by default; the
        # per-query predicate surface is meta['radius'] / meta['mag_cut'].
        self.shared_plan = bool(shared_plan)
        self.share_width = max(1, int(share_width))
        self._pred_cache: dict[int, tuple[float, float]] = {}
        self._has_query_predicates = False
        self.results: dict[int, list[MatchResult]] = {}
        self.max_probe_batch = 0  # largest probe batch sent to the device
        # The shared scheduling inner loop; the controller (when given) is
        # consulted there, once per round, never here.  With ``prefetch``
        # on, horizon buckets are staged by real threaded store reads
        # while cost accounting stays on the virtual T_b channel.
        self.loop = DispatchLoop(
            self.scheduler, self.wm, self.cache, self._execute,
            control=control, fuse_k=self.fuse_k,
            tenant_of=self.wm.tenant_of_bucket,
            prefetch=build_pipeline(
                prefetch, self.scheduler, self.cache, self.cost_model.T_b,
                fetch=self.catalog.store.read,
                # Elevator sweep in *file* order: bucket id is an SFC run,
                # not a physical address (Partitioner.layout_position).
                layout_of=self.catalog.partitioner.layout_position,
            ),
        )
        self.obs = None
        if obs:
            # Lazy import (off-path never touches repro.obs).  Crossmatch
            # executes real device/array work, so spans ride on
            # perf_counter marks; decisions still come off the tap only.
            from ..obs import ensure as _obs_ensure

            self.obs = _obs_ensure(obs)
            self.obs.attach_loop(self.loop, track=0, clock="wall")

    # -- loop-owned counters (kept as attributes for back-compat) --------------
    @property
    def sim_clock(self) -> float:
        return self.loop.clock

    @sim_clock.setter
    def sim_clock(self, value: float) -> None:
        self.loop.clock = value

    @property
    def batches(self) -> int:
        return self.loop.batches  # buckets serviced

    @property
    def dispatches(self) -> int:
        return self.loop.dispatches  # device calls (== batches unless fused)

    # -- intake ----------------------------------------------------------------
    def submit(self, query: Query) -> None:
        self.wm.submit(query)
        self._note_submitted(query)

    def submit_decomposed(self, query: Query, per_bucket) -> None:
        """Shard-router intake: the coordinator decomposed the query once
        centrally; this engine receives only its shard's bucket slice."""
        self.wm.submit_decomposed(query, per_bucket)
        self._note_submitted(query)

    def _note_submitted(self, query: Query) -> None:
        self.loop.observe_arrival(query.arrival_time)
        self.results.setdefault(query.query_id, [])
        meta = query.meta or {}
        if "radius" in meta or "mag_cut" in meta:
            self._has_query_predicates = True

    # -- per-query predicates -----------------------------------------------------
    def _pred_of(self, query_id: int) -> tuple[float, float]:
        """(cos threshold, mag cut) for one query: its own
        meta['radius'] / meta['mag_cut'] when present, the engine-wide
        defaults otherwise."""
        pred = self._pred_cache.get(query_id)
        if pred is None:
            meta = self.wm.queries[query_id].meta or {}
            thr = (
                float(np.cos(float(meta["radius"])))
                if "radius" in meta
                else self.cos_thr
            )
            pred = (thr, float(meta.get("mag_cut", self.mag_cut)))
            self._pred_cache[query_id] = pred
        return pred

    def _pred_rows(self, owners: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Per-probe-row (cos threshold, mag cut) vectors from the rows'
        owning queries — the host-side gather that turns per-query
        predicates into the shared kernel's threshold operand."""
        if owners.size == 0:
            return np.empty(0, np.float32), np.empty(0, np.float64)
        uniq, inv = np.unique(owners, return_inverse=True)
        preds = np.array([self._pred_of(int(qid)) for qid in uniq], np.float64)
        return preds[inv, 0].astype(np.float32), preds[inv, 1]

    # -- per-bucket plumbing ---------------------------------------------------
    def _plan_and_fetch(self, decision: SchedulerDecision):
        """Hybrid plan + bucket payload with unified cache accounting:
        every resident read records a hit via ``cache.access`` (the indexed
        plan used to read through ``cache.get`` and skew the hit-rate);
        only scan plans establish residency on a miss.

        Residency is re-probed here rather than taken from the decision:
        within a fused dispatch an earlier bucket's insertion can evict a
        later one, and plan/cost must reflect the read that actually
        happens (the decision's snapshot only fed the priority score)."""
        b = decision.bucket_id
        in_cache = self.cache.contains(b)
        plan = (
            self.hybrid.plan(decision.queue_size, in_cache)
            if self.hybrid
            else None
        )
        if in_cache:
            payload = self.cache.get(b)
            self.cache.access(b)  # counts the hit, refreshes LRU
        else:
            payload = self.catalog.store.read(b)  # the 'disk read'
            if plan is None or plan.strategy == "scan":
                self.cache.access(b, payload)
            else:
                # Indexed cold read: no residency, but hit_rate must see
                # the miss or skewed stats return (symmetric accounting).
                self.cache.note_bypass_miss()
        cost = (
            plan.est_cost
            if plan is not None
            else self.cost_model.batch_cost(
                decision.queue_size, in_cache, self.wm.spilled_fraction(b)
            )
        )
        return plan, payload, cost

    def _gather_probes(self, bucket_id: int):
        q = self.wm.queue(bucket_id)
        # Servicing evaluates the whole queue — the spilled suffix is paged
        # back in for the pass (T_spill already charged in the cost).
        units = q.units + q.spilled_units
        if not units:  # zero-query bucket (public execute_shared callers)
            return (
                [],
                np.empty((0, 3), np.float64),
                np.empty(0, np.int64),
                np.empty(0, np.int64),
            )
        probe_pos = np.concatenate(
            [
                self.wm.queries[u.query_id].payload["positions"][u.object_idx]
                for u in units
            ]
        )
        owners = np.concatenate(
            [np.full(u.size, u.query_id, dtype=np.int64) for u in units]
        )
        probe_local = np.concatenate([u.object_idx for u in units])
        return units, probe_pos, owners, probe_local

    def _route(
        self, bucket_id, units, owners, probe_local, best_idx, best_dot, n_cand,
        payload, mag_cut_row=None,
    ) -> None:
        matched = n_cand > 0
        # Per-query predicate on the joined tuples (paper: "query specific
        # predicates are applied on the output tuples that succeed").
        # ``mag_cut_row`` carries each row's owning query's own cut when
        # queries have heterogeneous predicates.
        mags = np.asarray(payload["mags"])[
            np.clip(best_idx, 0, len(payload["mags"]) - 1)
        ]
        matched &= mags <= (self.mag_cut if mag_cut_row is None else mag_cut_row)
        global_rows = self.catalog.partitioner.object_slice(bucket_id)
        for u in units:
            sel = (owners == u.query_id) & matched
            if not sel.any():
                continue
            self.results[u.query_id].append(
                MatchResult(
                    query_id=u.query_id,
                    probe_idx=probe_local[sel],
                    match_obj=global_rows[best_idx[sel]],
                    best_dot=best_dot[sel],
                    n_candidates=n_cand[sel],
                )
            )

    # -- one scheduling step -----------------------------------------------------
    def step(self) -> Optional[int]:
        """Service one scheduling round (1 bucket, or top-k fused); returns
        the highest-priority bucket id serviced, or None if idle."""
        outcome = self.loop.round()
        return None if outcome is None else outcome.decisions[0].bucket_id

    def _execute(self, decisions, vector) -> float:
        """DispatchLoop executor: route the round to the shared-plan path,
        the per-predicate-class path (heterogeneous predicates without a
        shared plan), or the historical batched/fused path.  Returns the
        round's wall-clock cost."""
        if self.shared_plan:
            return self.execute_shared(decisions, vector)
        if self._has_query_predicates:
            return self._execute_per_predicate(decisions)
        return self._execute_batched(decisions)

    def _execute_batched(self, decisions) -> float:
        """The historical homogeneous-predicate path: one device call per
        round (single bucket, or the fuse_k segment-masked fused call)."""
        from ..kernels.crossmatch import ops as cm_ops

        total_cost = 0.0
        if len(decisions) == 1:
            decision = decisions[0]
            b = decision.bucket_id
            _, payload, cost = self._plan_and_fetch(decision)
            total_cost += cost
            units, probe_pos, owners, probe_local = self._gather_probes(b)
            self.max_probe_batch = max(self.max_probe_batch, len(probe_pos))
            # --- the shared pass: one batched device call for every query ---
            best_idx, best_dot, n_cand = cm_ops.crossmatch(
                np.asarray(payload["positions"], dtype=np.float32),
                probe_pos.astype(np.float32),
                self.cos_thr,
                use_pallas=self.use_pallas,
            )
            self._route(
                b, units, owners, probe_local,
                np.asarray(best_idx), np.asarray(best_dot), np.asarray(n_cand),
                payload,
            )
        else:
            # --- fused multi-bucket pass: top-k buckets, ONE device call ---
            per_bucket = []
            bucket_parts, probe_parts, bseg, pseg = [], [], [], []
            row_off = 0
            for s, decision in enumerate(decisions):
                b = decision.bucket_id
                _, payload, cost = self._plan_and_fetch(decision)
                total_cost += cost
                units, probe_pos, owners, probe_local = self._gather_probes(b)
                pos = np.asarray(payload["positions"], dtype=np.float32)
                bucket_parts.append(pos)
                probe_parts.append(probe_pos.astype(np.float32))
                bseg.append(np.full(len(pos), s, np.int32))
                pseg.append(np.full(len(probe_pos), s, np.int32))
                per_bucket.append(
                    (b, payload, units, owners, probe_local, row_off,
                     len(probe_pos))
                )
                row_off += len(pos)
            self.max_probe_batch = max(
                self.max_probe_batch, sum(len(p) for p in probe_parts)
            )
            best_idx, best_dot, n_cand = cm_ops.crossmatch_fused(
                np.concatenate(bucket_parts),
                np.concatenate(probe_parts),
                np.concatenate(bseg),
                np.concatenate(pseg),
                self.cos_thr,
                use_pallas=self.use_pallas,
            )
            best_idx = np.asarray(best_idx)
            best_dot = np.asarray(best_dot)
            n_cand = np.asarray(n_cand)
            p_off = 0
            for b, payload, units, owners, probe_local, row_off, n_p in per_bucket:
                sl = slice(p_off, p_off + n_p)
                p_off += n_p
                local_idx = np.clip(
                    best_idx[sl] - row_off, 0, len(payload["mags"]) - 1
                )
                self._route(
                    b, units, owners, probe_local,
                    local_idx, best_dot[sl], n_cand[sl], payload,
                )

        return total_cost

    def _execute_per_predicate(self, decisions) -> float:
        """Per-predicate-class baseline: queries carry their own radii /
        mag cuts, so the static-``cos_thr`` kernel needs one device call
        per (bucket, distinct threshold) pair — the dispatch storm the
        shared plan collapses.  Kept as the off-path so ``shared_plan``
        stays a pure performance switch with bit-equal results."""
        from ..kernels.crossmatch import ops as cm_ops

        total_cost = 0.0
        n_calls = 0
        for decision in decisions:
            b = decision.bucket_id
            _, payload, cost = self._plan_and_fetch(decision)
            total_cost += cost
            units, probe_pos, owners, probe_local = self._gather_probes(b)
            self.max_probe_batch = max(self.max_probe_batch, len(probe_pos))
            pos = np.asarray(payload["positions"], dtype=np.float32)
            probes32 = probe_pos.astype(np.float32)
            thr_row, mag_row = self._pred_rows(owners)
            best_idx = np.zeros(len(owners), np.int64)
            best_dot = np.zeros(len(owners), np.float32)
            n_cand = np.zeros(len(owners), np.int64)
            for thr in np.unique(thr_row):
                sel = thr_row == thr
                bi, bd, nc = cm_ops.crossmatch(
                    pos, probes32[sel], float(thr), use_pallas=self.use_pallas
                )
                best_idx[sel] = np.asarray(bi)
                best_dot[sel] = np.asarray(bd)
                n_cand[sel] = np.asarray(nc)
                n_calls += 1
            self._route(
                b, units, owners, probe_local, best_idx, best_dot, n_cand,
                payload, mag_cut_row=mag_row,
            )
        self.loop.note_device_dispatches(n_calls)
        return total_cost

    def execute_shared(self, bucket_group, vector=None) -> float:
        """Shared-plan executor: ONE masked device call (per share_width
        chunk) for the whole bucket group x query batch.

        ``bucket_group`` is the round's SchedulerDecisions (bare bucket ids
        are accepted and looked up).  All pending queries' predicates are
        gathered into per-probe-row threshold/mag-cut vectors and the join
        runs through ``crossmatch_shared`` — the (queries x objects) mask —
        so k buckets and Q predicate classes cost ceil(Q / share_width)
        dispatches instead of k*Q.  The hybrid planner's group plan is the
        third break-even axis: members it sends down the indexed path keep
        private per-predicate calls (tiny batches don't pay the shared
        scan), the scan members share the masked kernel.
        """
        from ..kernels.crossmatch import ops as cm_ops

        decisions = [
            d
            if hasattr(d, "bucket_id")
            else SchedulerDecision(
                bucket_id=int(d),
                score=0.0,
                in_cache=self.cache.contains(int(d)),
                queue_size=self.wm.queue(int(d)).size,
            )
            for d in bucket_group
        ]
        width = getattr(vector, "share_width", 0) or self.share_width
        total_cost = 0.0
        n_calls = 0

        # Group plan (third axis): members that still prefer indexed
        # probes peel off to their own calls; the rest share one plan.
        if self.hybrid is not None and hasattr(self.hybrid, "plan_group"):
            plans = self.hybrid.plan_group(
                [
                    (d.queue_size, self.cache.contains(d.bucket_id))
                    for d in decisions
                ]
            )
        else:
            plans = [None] * len(decisions)

        shared, indexed = [], []
        for decision, plan in zip(decisions, plans):
            if plan is not None and plan.strategy == "indexed":
                indexed.append(decision)
            else:
                shared.append(decision)
        if indexed:
            total_cost += self._execute_per_predicate(indexed)

        if not shared:
            return total_cost

        per_bucket = []
        bucket_parts, probe_parts, bseg, pseg = [], [], [], []
        row_off = 0
        for s, decision in enumerate(shared):
            b = decision.bucket_id
            _, payload, cost = self._plan_and_fetch(decision)
            total_cost += cost
            units, probe_pos, owners, probe_local = self._gather_probes(b)
            pos = np.asarray(payload["positions"], dtype=np.float32)
            bucket_parts.append(pos)
            probe_parts.append(probe_pos.astype(np.float32))
            bseg.append(np.full(len(pos), s, np.int32))
            pseg.append(np.full(len(probe_pos), s, np.int32))
            per_bucket.append(
                (b, payload, units, owners, probe_local, row_off,
                 len(probe_pos))
            )
            row_off += len(pos)
        bucket_cat = np.concatenate(bucket_parts)
        probes_cat = np.concatenate(probe_parts)
        bseg_cat = np.concatenate(bseg)
        pseg_cat = np.concatenate(pseg)
        owners_cat = np.concatenate([pb[3] for pb in per_bucket])
        self.max_probe_batch = max(self.max_probe_batch, len(probes_cat))
        thr_row, mag_row = self._pred_rows(owners_cat)

        # Chunk the query batch by share_width (the AIMD-bounded compile
        # ceiling): each chunk's probe rows go through one shared call
        # against the same concatenated bucket payload, and outputs are
        # scattered back into full-length arrays so routing below is
        # order-identical to the fused path.
        qids = list(dict.fromkeys(owners_cat.tolist()))  # first-appearance
        best_idx = np.zeros(len(owners_cat), np.int64)
        best_dot = np.zeros(len(owners_cat), np.float32)
        n_cand = np.zeros(len(owners_cat), np.int64)
        chunks = [qids[i : i + width] for i in range(0, len(qids), width)] or [[]]
        for chunk in chunks:
            rows = np.isin(owners_cat, chunk)
            if not rows.any():
                continue
            bi, bd, nc = cm_ops.crossmatch_shared(
                bucket_cat,
                probes_cat[rows],
                bseg_cat,
                pseg_cat[rows],
                thr_row[rows],
                use_pallas=self.use_pallas,
            )
            best_idx[rows] = np.asarray(bi)
            best_dot[rows] = np.asarray(bd)
            n_cand[rows] = np.asarray(nc)
            n_calls += 1
        occupancy = (
            len(qids) / (len(chunks) * width) if qids and chunks else 0.0
        )
        self.loop.note_device_dispatches(n_calls, shared_occupancy=occupancy)

        p_off = 0
        for b, payload, units, owners, probe_local, row_off, n_p in per_bucket:
            sl = slice(p_off, p_off + n_p)
            p_off += n_p
            local_idx = np.clip(
                best_idx[sl] - row_off, 0, len(payload["mags"]) - 1
            )
            self._route(
                b, units, owners, probe_local,
                local_idx, best_dot[sl], n_cand[sl], payload,
                mag_cut_row=mag_row[sl],
            )
        return total_cost

    # -- drive a whole trace -------------------------------------------------------
    def run(self, queries: Sequence[Query]) -> dict[int, list[MatchResult]]:
        """Arrival-ordered replay: admit, then drain between arrivals."""
        for q in sorted(queries, key=lambda q: q.arrival_time):
            self.sim_clock = max(self.sim_clock, q.arrival_time)
            self.submit(q)
        while self.step() is not None:
            pass
        self.close()  # reap prefetch workers; they respawn if reused
        return self.results

    def close(self) -> None:
        """Release the prefetch staging threads (no-op without prefetch;
        step()-driven callers should close when done)."""
        if self.loop.prefetch is not None:
            self.loop.prefetch.close()

    # -- metrics --------------------------------------------------------------------
    def summary(self) -> dict:
        rt = self.wm.response_times()
        tenants = sorted({q.tenant for q in self.wm.queries.values()})
        dstats = dispatch_stats(self.loop)
        return {
            "n_queries": len(rt),
            "n_batches": self.batches,
            "n_dispatches": self.dispatches,
            "device_dispatches": dstats["device_dispatches"],
            "shared_batch_occupancy": dstats["shared_batch_occupancy"],
            "mean_response": float(np.mean(list(rt.values()))) if rt else 0.0,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "makespan": self.sim_clock,
            "per_tenant": per_tenant_latency(
                rt, self.wm.tenant_of_query, max(self.sim_clock, 1e-9), tenants
            )
            if len(tenants) > 1
            else {},
        }


class ShardedCrossMatch:
    """Multi-shard cross-match: S shard-local engines over one catalog.

    Buckets are partitioned by SFC range (bucket ids are the
    Partitioner's SFC-run order) weighted by bucket bytes.  Each query
    is decomposed ONCE centrally and its per-bucket slices routed to the
    owning shards — object indices stay valid against the original query
    arrays, so ``_gather_probes`` on any shard reads the same positions
    the single-engine path would.  A query may span shards; its result
    set is the union of per-shard matches (buckets are disjoint across
    shards, so the union cannot double-count).

    Transport is threaded: each shard's :class:`DispatchLoop` drains on
    its own thread under a per-shard lock.  With ``steal`` set, a thread
    that runs dry at the low-water mark steals the byte-heaviest
    victim's highest-utility unstarted bucket under both shard locks
    (acquired in ascending id order — no deadlock), migrating pending
    units and canceling the victim's in-flight prefetch stage for the
    residual channel time only.  The stolen payload is cache-cold on the
    thief: its next service pays the full read.
    """

    def __init__(
        self,
        catalog: SkyCatalog,
        n_shards: int = 2,
        *,
        shard_map: Optional[ShardMap] = None,
        steal: Optional[StealConfig] = None,
        scheduler_factory=None,
        cost_model: Optional[CostModel] = None,
        cache_capacity: int = 20,
        control_factory=None,
        **engine_kwargs,
    ) -> None:
        self.catalog = catalog
        self.n_shards = max(1, int(n_shards))
        self.cost_model = cost_model or CostModel()
        self.shard_map = shard_map or ShardMap.from_partitioner(
            catalog.partitioner, self.n_shards
        )
        self.steal = steal
        self.steals: list[StealEvent] = []
        # Aggregate cache slots stay equal to a single-engine run with the
        # same ``cache_capacity`` — each shard gets its slice, remainder
        # slots going to the lowest shard ids (split_slots conserves sum).
        caps = split_slots(cache_capacity, self.n_shards)
        self.engines = [
            CrossMatchEngine(
                catalog,
                scheduler=scheduler_factory() if scheduler_factory else None,
                cost_model=self.cost_model,
                cache_capacity=caps[sid],
                control=control_factory() if control_factory else None,
                **engine_kwargs,
            )
            for sid in range(self.n_shards)
        ]
        # Router: decompose once, centrally; never services anything.
        self.router = WorkloadManager(
            catalog.partitioner.buckets_for_range,
            probe_bytes=self.cost_model.probe_bytes,
            min_unit_bytes=self.cost_model.min_unit_bytes,
        )
        self._locks = [threading.Lock() for _ in range(self.n_shards)]
        self._steal_lock = threading.Lock()
        # Drain-thread fault channel: a thread that dies mid-drain records
        # (shard id, exception) here and trips the abort flag so sibling
        # shards stop instead of spinning/stealing against a dead peer;
        # ``run`` re-raises at join time with the originating shard id.
        self._drain_errors: list[tuple[int, BaseException]] = []
        self._abort = threading.Event()

    # -- intake ----------------------------------------------------------------
    def submit(self, query: Query) -> None:
        per_bucket = self.router.decompose(query)
        slices: dict[int, dict[int, object]] = {}
        for b, idx in per_bucket.items():
            slices.setdefault(self.shard_map.shard_of(b), {})[b] = idx
        if not slices:
            # No matching buckets: shard 0 records the empty completion.
            self.engines[0].submit_decomposed(query, {})
            return
        for sid, sl in slices.items():
            self.engines[sid].submit_decomposed(query, sl)

    # -- threaded drain --------------------------------------------------------
    def run(self, queries: Sequence[Query]) -> dict[int, list[MatchResult]]:
        """Admit the whole trace, drain every shard on its own thread,
        merge per-shard result lists per query."""
        for q in sorted(queries, key=lambda q: q.arrival_time):
            for eng in self.engines:
                eng.sim_clock = max(eng.sim_clock, q.arrival_time)
            self.submit(q)
        threads = [
            threading.Thread(target=self._drain_guard, args=(sid,), daemon=True)
            for sid in range(self.n_shards)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for eng in self.engines:
            eng.close()
        if self._drain_errors:
            sid, exc = self._drain_errors[0]
            raise RuntimeError(
                f"shard {sid} drain thread died: {exc!r}"
            ) from exc
        return self.collect_results()

    def _drain_guard(self, sid: int) -> None:
        """Exception fence around one shard's drain loop: locks are
        released by their ``with`` blocks, the failure is recorded with
        its shard id, and the abort flag stops the sibling loops so the
        join in ``run`` returns instead of waiting on steals from a dead
        shard."""
        try:
            self._drain(sid)
        except BaseException as exc:  # noqa: BLE001 — re-raised at join
            self._drain_errors.append((sid, exc))
            self._abort.set()

    def _drain(self, sid: int) -> None:
        eng = self.engines[sid]
        while not self._abort.is_set():
            with self._locks[sid]:
                serviced = eng.step()
            if serviced is not None:
                continue
            if self.steal is not None and self._try_steal(sid):
                continue
            return

    def _try_steal(self, thief_id: int) -> bool:
        """One steal attempt by an idle shard.  Victim choice happens
        under the steal lock (serialized decisions); the migration itself
        holds both shard locks so neither loop can be mid-round."""
        cfg = self.steal
        with self._steal_lock:
            thief = self.engines[thief_id]
            if thief.wm.pending_bytes() > cfg.low_water_bytes:
                return False
            victims = [
                s
                for s in range(self.n_shards)
                if s != thief_id
                and len(self.engines[s].wm.nonempty_queues())
                >= cfg.min_victim_queues
            ]
            if not victims:
                return False
            vid = max(
                victims,
                key=lambda s: (self.engines[s].wm.pending_bytes(), -s),
            )
            victim = self.engines[vid]
            lo, hi = sorted((thief_id, vid))
            with self._locks[lo], self._locks[hi]:
                bucket_id = self._victim_top_bucket(victim)
                if bucket_id is None:
                    return False
                units = victim.wm.migrate_out(bucket_id)
                if not units:
                    return False
                if hasattr(victim.scheduler, "forget"):
                    victim.scheduler.forget(bucket_id)
                reclaimed = 0.0
                if victim.loop.prefetch is not None:
                    reclaimed = victim.loop.prefetch.cancel(
                        bucket_id, victim.loop.clock
                    )
                qids = sorted({u.query_id for u in units})
                qmap = {
                    q: victim.wm.queries[q]
                    for q in qids
                    if q in victim.wm.queries
                }
                thief.wm.migrate_in(units, qmap)
                self.shard_map.reassign(bucket_id, thief_id)
                thief.sim_clock = max(
                    thief.sim_clock, max(u.arrival_time for u in units)
                )
                for q in qmap.values():
                    thief.results.setdefault(q.query_id, [])
                    meta = q.meta or {}
                    if "radius" in meta or "mag_cut" in meta:
                        thief._has_query_predicates = True
                self.steals.append(
                    StealEvent(
                        bucket_id=bucket_id,
                        victim=vid,
                        thief=thief_id,
                        n_units=len(units),
                        nbytes=float(sum(u.nbytes for u in units)),
                        reclaimed_stage_s=reclaimed,
                        clock=thief.sim_clock,
                    )
                )
                return True

    @staticmethod
    def _victim_top_bucket(victim: CrossMatchEngine) -> Optional[int]:
        peek = getattr(victim.scheduler, "peek_topk", None)
        if peek is not None:
            top = peek(victim.wm, victim.cache, victim.loop.clock, 1)
            return top[0].bucket_id if top else None
        queues = victim.wm.nonempty_queues()
        if not queues:
            return None
        return max(queues, key=lambda q: (q.nbytes, -q.bucket_id)).bucket_id

    # -- results / metrics -----------------------------------------------------
    def collect_results(self) -> dict[int, list[MatchResult]]:
        merged: dict[int, list[MatchResult]] = {}
        for eng in self.engines:
            for qid, lst in eng.results.items():
                merged.setdefault(qid, []).extend(lst)
        return merged

    def response_times(self) -> dict[int, float]:
        """Per-query latency: the slowest shard's completion (the join)."""
        out: dict[int, float] = {}
        for eng in self.engines:
            for qid, t in eng.wm.response_times().items():
                out[qid] = max(out.get(qid, 0.0), t)
        return out

    def summary(self) -> dict:
        rt = self.response_times()
        hits = sum(eng.cache.stats.hits for eng in self.engines)
        accesses = sum(eng.cache.stats.accesses for eng in self.engines)
        return {
            "n_queries": len(rt),
            "n_shards": self.n_shards,
            "n_batches": sum(eng.batches for eng in self.engines),
            "n_dispatches": sum(eng.dispatches for eng in self.engines),
            "mean_response": float(np.mean(list(rt.values()))) if rt else 0.0,
            "cache_hit_rate": hits / accesses if accesses else 0.0,
            "makespan": max(eng.sim_clock for eng in self.engines),
            "steals": len(self.steals),
        }

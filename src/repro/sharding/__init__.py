from .logical import (
    DEFAULT_RULES,
    DECODE_RULES,
    ShardingRules,
    activate,
    current_rules,
    named_sharding,
    shard_hint,
)

__all__ = [
    "DEFAULT_RULES", "DECODE_RULES", "ShardingRules", "activate",
    "current_rules", "named_sharding", "shard_hint",
]

"""Logical-axis sharding rules with divisibility-aware fallback.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", ...).  A ``ShardingRules`` table maps logical names to mesh axes; a
rule is silently dropped for a given tensor when the dimension is not
divisible by the mesh-axis size (so every (arch x shape x mesh) cell
compiles — e.g. 8 KV heads on a 16-way model axis fall back to replicated
KV + sequence-sharded cache).

Models call ``shard_hint(x, names)``; outside an active mesh context this
is a no-op, so smoke tests on 1 CPU device never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "DECODE_RULES",
    "activate",
    "current_rules",
    "shard_hint",
    "logical_to_spec",
    "named_sharding",
]

# Logical name -> tuple of mesh axis names (tried in order; non-dividing
# axes are dropped per-tensor).  None = always replicated.
DEFAULT_RULES: dict[str, Optional[tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ff": ("model",),
    "ff_in": ("model",),  # row-parallel input dim of the down projection
    "vocab": ("model",),
    "experts": ("model",),
    # When n_experts doesn't divide the model axis (mixtral: 8 < 16) the
    # experts dim falls back to replicated and the per-expert FF dim picks
    # up the model axis instead (tensor-parallel experts) — the used-axis
    # set in spec_for prevents double assignment otherwise.
    "expert_ff": ("model",),
    "expert_cap": None,  # hillclimb: ("data",) shards the capacity dim
    "layers": None,
    "state": None,
    "conv": None,
    "dt": None,
    "inner": ("model",),  # mamba d_inner
    "kv_seq": None,  # training: KV seq replicated
    "opt": ("data",),  # ZeRO-1: optimizer-state extra sharding axis
    "cache_seq": None,
    "cache_kv_heads": ("model",),
}

# Decode-time overrides: when KV heads cannot take the model axis (kv < 16)
# the KV *sequence* takes it instead (sequence-parallel decode attention).
DECODE_RULES = dict(
    DEFAULT_RULES,
    cache_seq=("model",),
    cache_kv_heads=None,
)


class ShardingRules:
    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES if rules is None else rules)

    def axis_size(self, names: tuple[str, ...]) -> int:
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size

    def spec_for(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        """PartitionSpec for a tensor with given logical axes and shape."""
        parts = []
        used: set[str] = set()
        for name, dim in zip(logical, shape):
            entry = self.rules.get(name) if name else None
            if entry is None:
                parts.append(None)
                continue
            axes = tuple(a for a in entry if a in self.mesh.shape and a not in used)
            if axes and dim % self.axis_size(axes) == 0:
                parts.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
            else:
                # Divisibility fallback: try a prefix of the axes tuple.
                ok = None
                for k in range(len(axes) - 1, 0, -1):
                    sub = axes[:k]
                    if dim % self.axis_size(sub) == 0:
                        ok = sub
                        break
                if ok:
                    parts.append(ok if len(ok) > 1 else ok[0])
                    used.update(ok)
                else:
                    parts.append(None)
        return P(*parts)

    def sharding_for(self, logical, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical, shape))


_STATE = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def activate(rules: Optional[ShardingRules]):
    """Activate sharding rules for model tracing (launch code only)."""
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def shard_hint(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axes; no-op without active rules."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec_for(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def logical_to_spec(rules: ShardingRules, logical, shape) -> P:
    return rules.spec_for(logical, shape)


def named_sharding(rules: ShardingRules, logical, shape) -> NamedSharding:
    return rules.sharding_for(logical, shape)

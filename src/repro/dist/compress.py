"""Gradient compression: blockwise int8 quantization, top-k sparsification,
and error feedback.

Blockwise absmax quantization keeps the worst-case dequantization error at
``block_absmax / 127`` per element; error feedback folds the residual into
the next step so the compressed stream is unbiased in the long run
(sum of payloads + final residual == sum of gradients, exactly).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "quantize_blockwise",
    "dequantize_blockwise",
    "error_feedback_compress",
    "topk_compress",
]

_BLOCK = 256


def quantize_blockwise(x: jnp.ndarray, block: int = _BLOCK):
    """Symmetric int8 quantization with per-block absmax scales.

    Returns ``(q, scales)`` where ``q`` is int8 of shape (n_blocks, block)
    (zero-padded) and ``scales`` is float32 of shape (n_blocks,).
    """
    flat = jnp.ravel(x).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_blockwise(q: jnp.ndarray, scales: jnp.ndarray, shape):
    """Inverse of :func:`quantize_blockwise` (up to the quantization error)."""
    flat = (q.astype(jnp.float32) * scales[:, None]).ravel()
    size = 1
    for d in shape:
        size *= int(d)
    return flat[:size].reshape(shape)


def error_feedback_compress(grad: jnp.ndarray, residual=None, block: int = _BLOCK):
    """Quantize ``grad + residual``; return ``((q, scales), new_residual)``.

    The residual carries the quantization error forward so nothing is lost:
    sum(dequantized payloads) + final residual == sum(grads).
    """
    acc = grad if residual is None else grad + residual
    q, s = quantize_blockwise(acc, block)
    new_residual = acc - dequantize_blockwise(q, s, acc.shape)
    return (q, s), new_residual


def topk_compress(grad: jnp.ndarray, frac: float, residual=None):
    """Keep the top ``frac`` fraction of entries by magnitude; the rest go
    into the returned residual.  ``kept + residual == grad + old_residual``."""
    acc = grad if residual is None else grad + residual
    flat = jnp.ravel(acc)
    n = flat.shape[0]
    k = max(1, int(round(frac * n)))
    thresh = jnp.sort(jnp.abs(flat))[n - k]
    keep = jnp.abs(acc) >= thresh
    kept = jnp.where(keep, acc, 0.0)
    return kept, acc - kept

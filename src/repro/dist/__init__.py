"""repro.dist — distributed-training substrate: gradient compression and
fault tolerance.

The LifeRaft analogy carries over: stragglers are aged work units whose
priority grows until a backup task is dispatched (paper §6 'future work'
on straggler absorption), and gradient compression is the bandwidth-side
twin of bucket batching — amortize the expensive transfer across many
small updates.
"""
from .compress import (
    dequantize_blockwise,
    error_feedback_compress,
    quantize_blockwise,
    topk_compress,
)
from .ft import (
    FTResult,
    HeartbeatMonitor,
    StragglerPolicy,
    simulate_training_with_failures,
)

__all__ = [
    "dequantize_blockwise",
    "error_feedback_compress",
    "quantize_blockwise",
    "topk_compress",
    "FTResult",
    "HeartbeatMonitor",
    "StragglerPolicy",
    "simulate_training_with_failures",
]

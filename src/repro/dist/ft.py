"""Fault tolerance: heartbeat failure detection, straggler policy with
backup-task dispatch, and a discrete-event training simulator that models
failures rolling back to the last checkpoint.

This is the training-side instantiation of the paper's §6 observations:
a slow participant is an aged work unit — once its duration exceeds the
policy cutoff, a backup task is dispatched so one straggler cannot
stretch the whole synchronous step.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

__all__ = [
    "HeartbeatMonitor",
    "StragglerPolicy",
    "FTResult",
    "simulate_training_with_failures",
]


class HeartbeatMonitor:
    """Tracks per-rank heartbeats; ``check(now)`` returns newly-dead ranks."""

    def __init__(self, ranks: Iterable[int], timeout: float = 30.0) -> None:
        self.timeout = float(timeout)
        self._last: dict[int, float] = {r: -np.inf for r in ranks}
        self._dead: set[int] = set()

    def beat(self, rank: int, t: float) -> None:
        self._last[rank] = max(self._last.get(rank, -np.inf), t)
        self._dead.discard(rank)

    def check(self, now: float) -> list[int]:
        dead = [
            r
            for r, t in self._last.items()
            if r not in self._dead and now - t > self.timeout
        ]
        self._dead.update(dead)
        return sorted(dead)

    @property
    def alive(self) -> list[int]:
        return sorted(r for r in self._last if r not in self._dead)


class StragglerPolicy:
    """Flags step durations exceeding ``factor`` x the running mean.

    Flagged durations do NOT update the running statistics (a straggler
    must not inflate its own cutoff).  ``backup_cutoff`` is the duration
    after which a backup task should be dispatched.
    """

    def __init__(self, factor: float = 2.0) -> None:
        self.factor = float(factor)
        self._n = 0
        self._mean = 0.0

    def observe(self, duration: float) -> bool:
        """Record a step duration; returns True iff it is a straggler."""
        if self._n and duration > self.factor * self._mean:
            return True
        self._n += 1
        self._mean += (duration - self._mean) / self._n
        return False

    def backup_cutoff(self) -> float:
        return self.factor * self._mean if self._n else float("inf")


@dataclasses.dataclass
class FTResult:
    steps_done: int
    wall_time: float
    n_failures: int
    lost_steps: int
    n_backup_dispatches: int
    n_stragglers: int


def simulate_training_with_failures(
    n_steps: int,
    failure_rate: float = 0.0,
    straggler_rate: float = 0.0,
    straggler_slowdown: float = 4.0,
    checkpoint_every: int = 20,
    backup_tasks: bool = False,
    n_workers: int = 8,
    step_time: float = 1.0,
    restart_cost: float = 5.0,
    seed: int = 0,
) -> FTResult:
    """Discrete-event model of synchronous training with failures.

    Each step takes ``step_time`` unless a worker straggles
    (probability ``straggler_rate`` per step): without backup tasks the
    step takes ``straggler_slowdown`` x longer; with them a backup is
    dispatched at the policy cutoff and the step completes at ~2x.
    Failures (probability ``failure_rate * n_workers`` per step) roll the
    run back to the last checkpoint and pay ``restart_cost``.
    """
    rng = np.random.default_rng(seed)
    step = 0
    wall = 0.0
    last_ckpt = 0
    failures = 0
    lost = 0
    backups = 0
    stragglers = 0
    while step < n_steps:
        straggles = rng.random() < straggler_rate
        if straggles:
            stragglers += 1
            if backup_tasks:
                backups += 1
                wall += 2.0 * step_time  # cutoff + backup's fresh attempt
            else:
                wall += straggler_slowdown * step_time
        else:
            wall += step_time
        step += 1
        if step % checkpoint_every == 0:
            last_ckpt = step
        if failure_rate and rng.random() < failure_rate * n_workers:
            failures += 1
            lost += step - last_ckpt
            wall += restart_cost
            step = last_ckpt
    return FTResult(
        steps_done=step,
        wall_time=wall,
        n_failures=failures,
        lost_steps=lost,
        n_backup_dispatches=backups,
        n_stragglers=stragglers,
    )

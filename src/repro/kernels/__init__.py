"""Pallas TPU kernels (validated in interpret mode on CPU).

  crossmatch      — banded tiled dot-threshold spatial join (the paper's join)
  paged_attention — bucket-batched decode attention over KV pages
  grouped_matmul  — ragged group GEMM (MoE experts / multi-adapter buckets)
                    with the paper's hybrid indexed-vs-scan execution
"""
from . import crossmatch, grouped_matmul, paged_attention

__all__ = ["crossmatch", "grouped_matmul", "paged_attention"]

"""Pure-jnp oracle for the cross-match join.

Semantics (probabilistic spatial join on the unit sphere):
given catalog ``bucket`` (N,3) and probe set ``probes`` (M,3), both unit
vectors, and a cosine threshold ``cos_thr`` = cos(match radius):

  best_idx[m] = argmax_n <probes[m], bucket[n]>       (nearest neighbour)
  best_dot[m] = the corresponding max dot product
  n_cand[m]   = #{n : <probes[m], bucket[n]> >= cos_thr}

A probe 'matches' iff n_cand > 0 (equivalently best_dot >= cos_thr).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["crossmatch_ref", "crossmatch_fused_ref", "crossmatch_shared_ref"]


def crossmatch_ref(bucket: jnp.ndarray, probes: jnp.ndarray, cos_thr: float):
    dots = jnp.dot(probes, bucket.T)  # (M, N)
    best_idx = jnp.argmax(dots, axis=1).astype(jnp.int32)
    best_dot = jnp.max(dots, axis=1)
    n_cand = jnp.sum(dots >= cos_thr, axis=1).astype(jnp.int32)
    return best_idx, best_dot, n_cand


def crossmatch_fused_ref(
    bucket: jnp.ndarray,
    probes: jnp.ndarray,
    bucket_seg: jnp.ndarray,
    probe_seg: jnp.ndarray,
    cos_thr: float,
):
    """Segmented oracle: probe m only considers bucket rows with
    ``bucket_seg == probe_seg[m]``; other pairs get dot -2 (below any real
    dot and any threshold).  ``best_idx`` indexes the concatenated bucket."""
    dots = jnp.dot(probes, bucket.T)  # (M, N)
    same = probe_seg[:, None] == bucket_seg[None, :]
    dots = jnp.where(same, dots, jnp.float32(-2.0))
    best_idx = jnp.argmax(dots, axis=1).astype(jnp.int32)
    best_dot = jnp.max(dots, axis=1)
    n_cand = jnp.sum(dots >= cos_thr, axis=1).astype(jnp.int32)
    return best_idx, best_dot, n_cand


def crossmatch_shared_ref(
    bucket: jnp.ndarray,
    probes: jnp.ndarray,
    bucket_seg: jnp.ndarray,
    probe_seg: jnp.ndarray,
    probe_thr: jnp.ndarray,
):
    """Shared-plan oracle: the fused segment mask *plus* a per-probe-row
    threshold vector, realizing the (queries x objects) predicate mask.

    Each probe row belongs to one query; ``probe_thr[m]`` is that query's
    own cos(match radius), so heterogeneous per-query predicates evaluate
    in the same masked pass instead of one device dispatch per predicate
    class.  Thresholds must lie in (-2, 1] (real cosines do); masked and
    padded pairs sit at dot -2 and can never pass one.
    """
    dots = jnp.dot(probes, bucket.T)  # (M, N)
    same = probe_seg[:, None] == bucket_seg[None, :]
    dots = jnp.where(same, dots, jnp.float32(-2.0))
    best_idx = jnp.argmax(dots, axis=1).astype(jnp.int32)
    best_dot = jnp.max(dots, axis=1)
    n_cand = jnp.sum(dots >= probe_thr[:, None], axis=1).astype(jnp.int32)
    return best_idx, best_dot, n_cand

"""Pallas TPU kernel: blocked dot-threshold cross-match.

TPU-native adaptation of the paper's sorted merge-scan join (§3.1): on the
sphere, ``angdist(a,b) < eps  <=>  <u_a,u_b> > cos(eps)``, so the per-bucket
join is a (M,3)x(3,N) matmul + threshold — an MXU workload, not a
pointer-chase.  Both operands arrive HTM-sorted, so the match matrix is
band-limited; the optional ``band`` parameter skips tiles outside the band
(block-sparse matmul), which is the kernel-level analogue of the paper's
"only overlapping buckets are joined".

Layout: the coordinate axis is zero-padded to 8 so the K dimension of the
MXU matmul is tile-aligned; M and N are padded to block multiples by the
``ops`` wrapper.  Grid = (M/bm, N/bn) with the N dimension innermost and
"arbitrary" semantics: each probe-tile's outputs are revisited across
bucket tiles and accumulated with a running max / count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "crossmatch_pallas",
    "crossmatch_fused_pallas",
    "crossmatch_shared_pallas",
    "COORD_PAD",
    "PAD_SEG",
]

COORD_PAD = 8  # zero-padded coordinate dimension (MXU K alignment)
_NEG = -2.0  # dots lie in [-1, 1]
_BIG = 2**30
PAD_SEG = float(2**20)  # segment id assigned to padded rows (sorts last,
#                         exactly representable in f32, matches no real seg)


def _accumulate(dots, j, bn, cos_thr, idx_ref, dot_ref, cnt_ref):
    """Fold one (bm, bn) tile of dots into the running max/argmin-id/count."""
    ids = jax.lax.broadcasted_iota(jnp.int32, dots.shape, 1) + j * bn
    tile_best = jnp.max(dots, axis=1)
    is_best = dots >= tile_best[:, None]
    tile_idx = jnp.min(jnp.where(is_best, ids, jnp.int32(_BIG)), axis=1)
    tile_cnt = jnp.sum((dots >= cos_thr).astype(jnp.int32), axis=1)

    run_best = dot_ref[...]
    improved = tile_best > run_best
    dot_ref[...] = jnp.where(improved, tile_best, run_best)
    idx_ref[...] = jnp.where(improved, tile_idx, idx_ref[...])
    cnt_ref[...] = cnt_ref[...] + tile_cnt


def _kernel(bucket_ref, probe_ref, idx_ref, dot_ref, cnt_ref, *, cos_thr, bn, band):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dot_ref[...] = jnp.full_like(dot_ref, jnp.float32(_NEG))
        idx_ref[...] = jnp.zeros_like(idx_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    def _body():
        p = probe_ref[...]  # (bm, COORD_PAD)
        b = bucket_ref[...]  # (bn, COORD_PAD)
        dots = jax.lax.dot_general(
            p,
            b,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bm, bn)
        _accumulate(dots, j, bn, cos_thr, idx_ref, dot_ref, cnt_ref)

    if band is None:
        _body()
    else:
        # Band-sparse: both inputs are SFC-sorted, so matches concentrate
        # near the (scaled) diagonal. Tiles outside the band are skipped
        # entirely — no load, no matmul.
        n_i = pl.num_programs(0)
        n_j = pl.num_programs(1)
        center = (i * n_j) // jnp.maximum(n_i, 1)
        pl.when(jnp.abs(j - center) <= band)(_body)


@functools.partial(jax.jit, static_argnames=("cos_thr", "bm", "bn", "band", "interpret"))
def crossmatch_pallas(
    bucket: jnp.ndarray,  # (N, COORD_PAD) f32, N % bn == 0
    probes: jnp.ndarray,  # (M, COORD_PAD) f32, M % bm == 0
    cos_thr: float,
    bm: int = 128,
    bn: int = 512,
    band: int | None = None,
    interpret: bool = True,
):
    m, kp = probes.shape
    n, kb = bucket.shape
    assert kp == COORD_PAD and kb == COORD_PAD, (kp, kb)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_kernel, cos_thr=cos_thr, bn=bn, band=band)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, COORD_PAD), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, COORD_PAD), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),  # best_idx
            jax.ShapeDtypeStruct((m,), jnp.float32),  # best_dot
            jax.ShapeDtypeStruct((m,), jnp.int32),  # n_cand
        ],
        interpret=interpret,
    )(bucket, probes)
    return out


def _fused_kernel(
    bucket_ref, probe_ref, bseg_ref, pseg_ref, idx_ref, dot_ref, cnt_ref,
    *, cos_thr, bn
):
    """Segmented (multi-bucket) cross-match tile.

    Probe row m may only match bucket rows whose segment id equals
    ``pseg[m]`` — the grouped_matmul trick applied to the join: k buckets'
    payloads and probe queues are concatenated segment-by-segment and
    evaluated in ONE device call, amortizing dispatch the way the paper
    amortizes disk reads across queries.  Both inputs arrive sorted by
    segment, so the valid region is block-diagonal; tiles whose segment
    ranges don't overlap are skipped entirely.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dot_ref[...] = jnp.full_like(dot_ref, jnp.float32(_NEG))
        idx_ref[...] = jnp.zeros_like(idx_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    ps = pseg_ref[...]  # (bm,) f32 segment ids, ascending
    bs = bseg_ref[...]  # (bn,) f32 segment ids, ascending

    def _body():
        p = probe_ref[...]
        b = bucket_ref[...]
        dots = jax.lax.dot_general(
            p, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bm, bn)
        same = ps[:, None] == bs[None, :]
        dots = jnp.where(same, dots, jnp.float32(_NEG))
        _accumulate(dots, j, bn, cos_thr, idx_ref, dot_ref, cnt_ref)

    overlap = (jnp.min(bs) <= jnp.max(ps)) & (jnp.max(bs) >= jnp.min(ps))
    pl.when(overlap)(_body)


def _shared_kernel(
    bucket_ref, probe_ref, bseg_ref, pseg_ref, thr_ref, idx_ref, dot_ref, cnt_ref,
    *, bn
):
    """Shared-plan tile: the fused segment mask plus per-probe thresholds.

    The query axis is fused into the kernel: each probe row carries its own
    query's cos threshold in ``thr_ref``, so a batch of queries with
    heterogeneous predicates — which the static-``cos_thr`` kernels would
    split into one dispatch (and one compile) per predicate class — runs as
    ONE masked device call.  The (queries x objects) predicate mask is the
    segment mask composed with the per-row threshold compare inside
    ``_accumulate``.  Same block-diagonal tile skip as the fused kernel.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dot_ref[...] = jnp.full_like(dot_ref, jnp.float32(_NEG))
        idx_ref[...] = jnp.zeros_like(idx_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    ps = pseg_ref[...]  # (bm,) f32 segment ids, ascending
    bs = bseg_ref[...]  # (bn,) f32 segment ids, ascending

    def _body():
        p = probe_ref[...]
        b = bucket_ref[...]
        dots = jax.lax.dot_general(
            p, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bm, bn)
        same = ps[:, None] == bs[None, :]
        dots = jnp.where(same, dots, jnp.float32(_NEG))
        # Per-row thresholds broadcast against the (bm, bn) dots tile.
        _accumulate(dots, j, bn, thr_ref[...][:, None], idx_ref, dot_ref, cnt_ref)

    overlap = (jnp.min(bs) <= jnp.max(ps)) & (jnp.max(bs) >= jnp.min(ps))
    pl.when(overlap)(_body)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def crossmatch_shared_pallas(
    bucket: jnp.ndarray,  # (N, COORD_PAD) f32, N % bn == 0, seg-sorted
    probes: jnp.ndarray,  # (M, COORD_PAD) f32, M % bm == 0, seg-sorted
    bucket_seg: jnp.ndarray,  # (N,) f32 segment id per bucket row
    probe_seg: jnp.ndarray,  # (M,) f32 segment id per probe row
    probe_thr: jnp.ndarray,  # (M,) f32 per-probe cos threshold (traced!)
    bm: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    m, kp = probes.shape
    n, kb = bucket.shape
    assert kp == COORD_PAD and kb == COORD_PAD, (kp, kb)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_shared_kernel, bn=bn)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, COORD_PAD), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, COORD_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),  # best_idx (concat rows)
            jax.ShapeDtypeStruct((m,), jnp.float32),  # best_dot
            jax.ShapeDtypeStruct((m,), jnp.int32),  # n_cand
        ],
        interpret=interpret,
    )(bucket, probes, bucket_seg, probe_seg, probe_thr)
    return out


@functools.partial(jax.jit, static_argnames=("cos_thr", "bm", "bn", "interpret"))
def crossmatch_fused_pallas(
    bucket: jnp.ndarray,  # (N, COORD_PAD) f32, N % bn == 0, seg-sorted
    probes: jnp.ndarray,  # (M, COORD_PAD) f32, M % bm == 0, seg-sorted
    bucket_seg: jnp.ndarray,  # (N,) f32 segment id per bucket row
    probe_seg: jnp.ndarray,  # (M,) f32 segment id per probe row
    cos_thr: float,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    m, kp = probes.shape
    n, kb = bucket.shape
    assert kp == COORD_PAD and kb == COORD_PAD, (kp, kb)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_fused_kernel, cos_thr=cos_thr, bn=bn)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, COORD_PAD), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, COORD_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),  # best_idx (concat rows)
            jax.ShapeDtypeStruct((m,), jnp.float32),  # best_dot
            jax.ShapeDtypeStruct((m,), jnp.int32),  # n_cand
        ],
        interpret=interpret,
    )(bucket, probes, bucket_seg, probe_seg)
    return out

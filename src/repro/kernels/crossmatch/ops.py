"""Jitted public wrapper for the cross-match kernel.

Handles padding (coordinate axis -> COORD_PAD, M/N -> block multiples),
dispatches to the Pallas kernel or the jnp reference, and slices padding
back off.  The engine calls this; tests sweep shapes against ``ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import COORD_PAD, crossmatch_pallas
from .ref import crossmatch_ref

__all__ = ["crossmatch"]


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def _pad_coords(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.pad(x, ((0, 0), (0, COORD_PAD - x.shape[1])))


@functools.partial(
    jax.jit, static_argnames=("cos_thr", "use_pallas", "bm", "bn", "band", "interpret")
)
def _crossmatch_jit(
    bucket, probes, cos_thr, use_pallas, bm, bn, band, interpret
):
    m = probes.shape[0]
    if not use_pallas:
        return crossmatch_ref(bucket, probes, cos_thr)
    bucket_p = _pad_coords(_pad_rows(bucket.astype(jnp.float32), bn))
    probes_p = _pad_coords(_pad_rows(probes.astype(jnp.float32), bm))
    idx, dot, cnt = crossmatch_pallas(
        bucket_p, probes_p, cos_thr, bm=bm, bn=bn, band=band, interpret=interpret
    )
    # Padded bucket rows are all-zero -> dot 0; they can only win when every
    # real dot is negative, in which case best_dot < cos_thr anyway.
    n_real = bucket.shape[0]
    idx = jnp.minimum(idx, n_real - 1)
    return idx[:m], dot[:m], cnt[:m]


def crossmatch(
    bucket,
    probes,
    cos_thr: float,
    use_pallas: bool = False,
    bm: int = 128,
    bn: int = 512,
    band: int | None = None,
    interpret: bool = True,
):
    """Cross-match ``probes`` against ``bucket`` (both (?,3) unit vectors).

    Returns (best_idx, best_dot, n_cand), each of length len(probes).
    ``use_pallas=False`` uses the jnp reference path (fast on CPU);
    ``use_pallas=True`` runs the TPU kernel (interpret mode off-TPU).
    """
    bucket = jnp.asarray(bucket, dtype=jnp.float32)
    probes = jnp.asarray(probes, dtype=jnp.float32)
    return _crossmatch_jit(
        bucket, probes, float(cos_thr), use_pallas, bm, bn, band, interpret
    )

"""Jitted public wrappers for the cross-match kernels.

Handles padding and dispatch for two entry points:

``crossmatch``        — one bucket vs its probe batch.  Probe and bucket
                        counts are padded to the next power of two
                        (*shape bucketing*), so a query trace triggers
                        O(log max_M) jit compilations instead of one per
                        distinct batch size; ``jit_cache_size()`` exposes
                        the compile count for benchmarks.
``crossmatch_fused``  — k buckets in ONE device call: payloads and probe
                        batches are concatenated with segment ids and the
                        join is evaluated as a segment-masked matmul
                        (grouped_matmul-style), amortizing dispatch the
                        way the paper amortizes disk reads.

Padded-row correctness: coordinates are zero-padded to ``COORD_PAD`` and a
*marker column* is used so padded bucket rows dot to exactly -2 with every
probe (probes carry 1.0 in the marker column, padded bucket rows -2.0,
real bucket rows 0.0).  -2 is below any real dot (unit vectors give
dots in [-1, 1]) and any threshold, so padded rows can never win the
argmax nor inflate ``n_cand`` — including when ``cos_thr <= 0`` (match
radius >= pi/2), which used to count every zero-padded row.  The fused
path gets the same guarantee from its segment mask (padded rows carry
segment ``PAD_SEG``, which matches no real segment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import (
    COORD_PAD,
    PAD_SEG,
    crossmatch_fused_pallas,
    crossmatch_pallas,
    crossmatch_shared_pallas,
)
from .ref import crossmatch_fused_ref, crossmatch_ref, crossmatch_shared_ref

__all__ = ["crossmatch", "crossmatch_fused", "crossmatch_shared", "jit_cache_size"]

_PAD_THR = 2.0  # threshold for padded probe rows: above any dot, passes never

_MARKER_COL = 3  # first zero-padded coordinate column; see module docstring
_MIN_SHAPE = 8  # floor for power-of-two shape buckets


def _pow2_ceil(n: int, floor: int = _MIN_SHAPE) -> int:
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def _mark_probes(probes8: jnp.ndarray) -> jnp.ndarray:
    """Every probe row carries 1.0 in the marker column."""
    return probes8.at[:, _MARKER_COL].set(1.0)


def _sentinel_bucket_rows(bucket8: jnp.ndarray, n_real: int) -> jnp.ndarray:
    """Rows past ``n_real`` get -2.0 in the marker column: their dot with
    any (marked) probe is exactly -2, below every real dot and threshold."""
    if bucket8.shape[0] > n_real:
        bucket8 = bucket8.at[n_real:, _MARKER_COL].set(-2.0)
    return bucket8


def _host_prepare(bucket, probes):
    """Pow2-pad, COORD_PAD-widen, and marker/sentinel-mark both operands in
    host numpy — one array build + one transfer per operand at the jit
    boundary instead of a chain of eager device pads."""
    bucket = np.asarray(bucket, np.float32)
    probes = np.asarray(probes, np.float32)
    if bucket.shape[1] > _MARKER_COL or probes.shape[1] > _MARKER_COL:
        raise ValueError(
            f"coordinate width must be <= {_MARKER_COL}; column "
            f"{_MARKER_COL} is reserved for the padded-row marker"
        )
    n_true, m_true = bucket.shape[0], probes.shape[0]
    b8 = np.zeros((_pow2_ceil(n_true), COORD_PAD), np.float32)
    b8[:n_true, : bucket.shape[1]] = bucket
    b8[n_true:, _MARKER_COL] = -2.0
    p8 = np.zeros((_pow2_ceil(m_true), COORD_PAD), np.float32)
    p8[:m_true, : probes.shape[1]] = probes
    p8[:, _MARKER_COL] = 1.0
    return b8, p8, n_true, m_true


@functools.partial(
    jax.jit, static_argnames=("cos_thr", "use_pallas", "bm", "bn", "band", "interpret")
)
def _crossmatch_jit(bucket8, probes8, cos_thr, use_pallas, bm, bn, band, interpret):
    """Inputs are already COORD_PAD wide, marker-marked, and pow2-padded;
    padded bucket rows dot to -2 with every probe on both paths."""
    m = probes8.shape[0]
    if not use_pallas:
        return crossmatch_ref(bucket8, probes8, cos_thr)
    n_in = bucket8.shape[0]
    bucket_p = _sentinel_bucket_rows(_pad_rows(bucket8, bn), n_in)
    probes_p = _mark_probes(_pad_rows(probes8, bm))
    idx, dot, cnt = crossmatch_pallas(
        bucket_p, probes_p, cos_thr, bm=bm, bn=bn, band=band, interpret=interpret
    )
    return idx[:m], dot[:m], cnt[:m]


def crossmatch(
    bucket,
    probes,
    cos_thr: float,
    use_pallas: bool = False,
    bm: int = 128,
    bn: int = 512,
    band: int | None = None,
    interpret: bool = True,
):
    """Cross-match ``probes`` against ``bucket`` (both (?,3) unit vectors).

    Returns (best_idx, best_dot, n_cand), each of length len(probes).
    ``use_pallas=False`` uses the jnp reference path (fast on CPU);
    ``use_pallas=True`` runs the TPU kernel (interpret mode off-TPU).

    Both operands are padded to the next power of two (in host numpy)
    before entering the jitted core, so the number of distinct compiled
    shapes over a whole trace is O(log2(max probe count)) rather than
    O(#batches).
    """
    bucket8, probes8, n_true, m_true = _host_prepare(bucket, probes)
    idx, dot, cnt = _crossmatch_jit(
        bucket8, probes8, float(cos_thr), use_pallas, bm, bn, band, interpret
    )
    # Padded rows cannot win (marker dot -2), but clamp for belt-and-braces.
    idx = jnp.minimum(idx[:m_true], max(n_true - 1, 0))
    return idx, dot[:m_true], cnt[:m_true]


def jit_cache_size() -> int:
    """Total shapes compiled across the single-bucket, fused, and
    shared-plan cores (benchmarks gate this staying O(log max batch))."""
    try:
        return int(
            _crossmatch_jit._cache_size()
            + _crossmatch_fused_jit._cache_size()
            + _crossmatch_shared_jit._cache_size()
        )
    except AttributeError:  # very old jax
        return -1


@functools.partial(
    jax.jit, static_argnames=("cos_thr", "use_pallas", "bm", "bn", "interpret")
)
def _crossmatch_fused_jit(
    bucket8, probes8, bucket_seg, probe_seg, cos_thr, use_pallas, bm, bn, interpret
):
    m = probes8.shape[0]
    if not use_pallas:
        return crossmatch_fused_ref(bucket8, probes8, bucket_seg, probe_seg, cos_thr)
    n_in = bucket8.shape[0]
    bucket_p = _pad_rows(bucket8, bn)
    probes_p = _pad_rows(probes8, bm)
    pad_b = bucket_p.shape[0] - n_in
    if pad_b:
        bucket_seg = jnp.concatenate(
            [bucket_seg, jnp.full((pad_b,), PAD_SEG, jnp.float32)]
        )
    pad_p = probes_p.shape[0] - m
    if pad_p:
        probe_seg = jnp.concatenate(
            [probe_seg, jnp.full((pad_p,), PAD_SEG, jnp.float32)]
        )
    idx, dot, cnt = crossmatch_fused_pallas(
        bucket_p, probes_p, bucket_seg, probe_seg, cos_thr,
        bm=bm, bn=bn, interpret=interpret,
    )
    return idx[:m], dot[:m], cnt[:m]


@functools.partial(jax.jit, static_argnames=("use_pallas", "bm", "bn", "interpret"))
def _crossmatch_shared_jit(
    bucket8, probes8, bucket_seg, probe_seg, probe_thr, use_pallas, bm, bn, interpret
):
    m = probes8.shape[0]
    if not use_pallas:
        return crossmatch_shared_ref(
            bucket8, probes8, bucket_seg, probe_seg, probe_thr
        )
    n_in = bucket8.shape[0]
    bucket_p = _pad_rows(bucket8, bn)
    probes_p = _pad_rows(probes8, bm)
    pad_b = bucket_p.shape[0] - n_in
    if pad_b:
        bucket_seg = jnp.concatenate(
            [bucket_seg, jnp.full((pad_b,), PAD_SEG, jnp.float32)]
        )
    pad_p = probes_p.shape[0] - m
    if pad_p:
        probe_seg = jnp.concatenate(
            [probe_seg, jnp.full((pad_p,), PAD_SEG, jnp.float32)]
        )
        probe_thr = jnp.concatenate(
            [probe_thr, jnp.full((pad_p,), _PAD_THR, jnp.float32)]
        )
    idx, dot, cnt = crossmatch_shared_pallas(
        bucket_p, probes_p, bucket_seg, probe_seg, probe_thr,
        bm=bm, bn=bn, interpret=interpret,
    )
    return idx[:m], dot[:m], cnt[:m]


def crossmatch_shared(
    bucket,
    probes,
    bucket_seg,
    probe_seg,
    probe_thr,
    use_pallas: bool = False,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    """Shared-plan cross-match: the query axis fused into ONE device call.

    Like ``crossmatch_fused``, but the cos threshold is a *traced* per-probe
    array (``probe_thr[m]`` = probe m's owning query's cos(radius)) instead
    of a static scalar.  A batch of queries with K distinct match radii
    therefore costs one dispatch and at most one compile per pow2 shape
    pair — the static-threshold paths would pay K dispatches and K compile
    cache entries.  Thresholds must lie in (-2, 1]; real cosines do, and
    padded probe rows get ``_PAD_THR`` (+2, passes nothing).

    Returns (best_idx, best_dot, n_cand) of length len(probes); best_idx
    indexes the concatenated bucket array.
    """
    bucket8, probes8, n_true, m_true = _host_prepare(bucket, probes)
    # Segment mask fences padded/real rows, exactly as in the fused path.
    bucket8[:, _MARKER_COL] = 0.0
    probes8[:, _MARKER_COL] = 0.0
    bseg = np.full(bucket8.shape[0], PAD_SEG, np.float32)
    bseg[:n_true] = np.asarray(bucket_seg, np.float32)
    pseg = np.full(probes8.shape[0], PAD_SEG, np.float32)
    pseg[:m_true] = np.asarray(probe_seg, np.float32)
    thr = np.full(probes8.shape[0], _PAD_THR, np.float32)
    thr[:m_true] = np.asarray(probe_thr, np.float32)
    idx, dot, cnt = _crossmatch_shared_jit(
        bucket8, probes8, jnp.asarray(bseg), jnp.asarray(pseg), jnp.asarray(thr),
        use_pallas, bm, bn, interpret,
    )
    idx = jnp.minimum(idx[:m_true], max(n_true - 1, 0))
    return idx, dot[:m_true], cnt[:m_true]


def crossmatch_fused(
    bucket,
    probes,
    bucket_seg,
    probe_seg,
    cos_thr: float,
    use_pallas: bool = False,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    """Fused multi-bucket cross-match: ONE device call for k buckets.

    ``bucket``/``probes`` are the segment-sorted concatenations of the k
    bucket payloads / probe batches; ``bucket_seg``/``probe_seg`` give each
    row's segment (0..k-1).  A probe only matches bucket rows of its own
    segment; ``best_idx`` indexes the *concatenated* bucket array (callers
    subtract their segment's row offset).  A probe whose segment is empty
    gets n_cand == 0.

    Shapes are padded to powers of two (padded rows get segment
    ``PAD_SEG``), bounding compile count over a trace.
    """
    bucket8, probes8, n_true, m_true = _host_prepare(bucket, probes)
    # The segment mask replaces the marker column: padded/real row fencing
    # comes from PAD_SEG, so neutralize the marker values set above.
    bucket8[:, _MARKER_COL] = 0.0
    probes8[:, _MARKER_COL] = 0.0
    bseg = np.full(bucket8.shape[0], PAD_SEG, np.float32)
    bseg[:n_true] = np.asarray(bucket_seg, np.float32)
    pseg = np.full(probes8.shape[0], PAD_SEG, np.float32)
    pseg[:m_true] = np.asarray(probe_seg, np.float32)
    idx, dot, cnt = _crossmatch_fused_jit(
        bucket8, probes8, jnp.asarray(bseg), jnp.asarray(pseg),
        float(cos_thr), use_pallas, bm, bn, interpret,
    )
    idx = jnp.minimum(idx[:m_true], max(n_true - 1, 0))
    return idx, dot[:m_true], cnt[:m_true]

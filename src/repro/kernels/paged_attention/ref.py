"""Pure-jnp oracle for paged decode attention (GQA, one token per seq).

Semantics: q (B, H, D); paged KV with ``page_table`` (B, P) selecting pages
of shape (page_size, KV, D) from the global pools; per-sequence lengths
mask out slots at or past ``seq_lens[b]``.  Equivalent to dense causal
decode attention over the gathered cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["paged_attention_ref"]


def paged_attention_ref(
    q: jnp.ndarray,  # (B, H, D)
    k_pages: jnp.ndarray,  # (N_pages, page, KV, D)
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, P) int32
    seq_lens: jnp.ndarray,  # (B,) int32
) -> jnp.ndarray:
    B, H, D = q.shape
    _, page, KV, _ = k_pages.shape
    P = page_table.shape[1]
    G = H // KV
    k = k_pages[page_table].reshape(B, P * page, KV, D)
    v = v_pages[page_table].reshape(B, P * page, KV, D)
    qg = q.reshape(B, KV, G, D)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(D))
    t = jnp.arange(P * page)
    valid = t[None, :] < seq_lens[:, None]  # (B, T)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v.dtype), v)
    return out.reshape(B, H, D)

"""Public wrapper for paged decode attention + cache<->page utilities."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import paged_attention_pallas
from .ref import paged_attention_ref

__all__ = ["paged_attention", "dense_to_pages"]


def paged_attention(
    q,
    k_pages,
    v_pages,
    page_table,
    seq_lens,
    use_pallas: bool = False,
    interpret: bool = True,
):
    """Decode attention over paged KV. q: (B,H,D) -> (B,H,D)."""
    q = jnp.asarray(q)
    if use_pallas:
        return paged_attention_pallas(
            q, k_pages, v_pages, page_table.astype(jnp.int32),
            seq_lens.astype(jnp.int32), interpret=interpret,
        )
    return paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens)


def dense_to_pages(k: jnp.ndarray, v: jnp.ndarray, page: int):
    """(B, S, KV, D) dense cache -> page pools + identity page table.

    Testing/bridging helper: page i of sequence b is global page b*P+i."""
    B, S, KV, D = k.shape
    assert S % page == 0
    P = S // page
    k_pages = k.reshape(B * P, page, KV, D)
    v_pages = v.reshape(B * P, page, KV, D)
    page_table = (jnp.arange(B)[:, None] * P + jnp.arange(P)[None, :]).astype(jnp.int32)
    return k_pages, v_pages, page_table

"""Pallas TPU kernel: paged decode attention (bucket-batched KV access).

The serving-side materialization of LifeRaft's bucket model: KV pages are
the buckets (fixed-size, spatially coherent units of expensive state) and
all query heads for a sequence share each page read — one HBM->VMEM
transfer amortized over the whole head batch, with online-softmax
accumulation so pages stream through VMEM in page_table order.

Grid: (B, pages_per_seq); the page index for (b, p) is scalar-prefetched
from the page table, so Mosaic pipelines the gather of page p+1 while
page p is being processed.  Scratch: flash (m, l, acc) per sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_pallas"]

_NEG_INF = -1e30


def _kernel(
    pt_ref,  # scalar prefetch: (B, P) page table
    lens_ref,  # scalar prefetch: (B,) seq lens
    q_ref,  # (1, H, D)
    k_ref,  # (1, page, KV, D) — the page selected by the index map
    v_ref,
    o_ref,  # (1, H, D)
    m_ref,  # scratch (KV, G) f32  running max
    l_ref,  # scratch (KV, G) f32  running denominator
    acc_ref,  # scratch (H, D) f32 running numerator
    *,
    page: int,
    n_pages: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (H, D)
    H, D = q.shape
    k = k_ref[0]  # (page, KV, D)
    v = v_ref[0]
    KV = k.shape[1]
    G = H // KV

    qg = q.reshape(KV, G, D)
    s = jax.lax.dot_general(
        qg.reshape(KV * G, D),
        k.reshape(page * KV, D),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(KV, G, page, KV)
    # keep only the diagonal KV pairing: score[kv, g, t] = <q[kv,g], k[t,kv]>
    eye = jax.lax.broadcasted_iota(jnp.int32, (KV, 1, 1, KV), 0) == \
        jax.lax.broadcasted_iota(jnp.int32, (KV, 1, 1, KV), 3)
    s = jnp.sum(jnp.where(eye, s, 0.0), axis=3)  # (KV, G, page)
    s = s / jnp.sqrt(jnp.float32(D))

    # mask invalid slots of this page
    t0 = p * page
    slot = jax.lax.broadcasted_iota(jnp.int32, (KV, G, page), 2) + t0
    valid = slot < lens_ref[b]
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)  # (KV, G)
    pexp = jnp.exp(s - m_new[..., None])  # (KV, G, page)
    pexp = jnp.where(valid, pexp, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    m_ref[...] = m_new

    pv = jax.lax.dot_general(
        pexp.reshape(KV * G, page).astype(v.dtype),
        v.reshape(page, KV * D),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(KV, G, KV, D)
    eye2 = jax.lax.broadcasted_iota(jnp.int32, (KV, 1, KV, 1), 0) == \
        jax.lax.broadcasted_iota(jnp.int32, (KV, 1, KV, 1), 2)
    pv = jnp.sum(jnp.where(eye2, pv, 0.0), axis=2)  # (KV, G, D)
    acc_ref[...] = acc_ref[...] * alpha.reshape(H, 1) + pv.reshape(H, D)

    @pl.when(p == n_pages - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...].reshape(H, 1), 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jnp.ndarray,  # (B, H, D)
    k_pages: jnp.ndarray,  # (N, page, KV, D)
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, P) int32
    seq_lens: jnp.ndarray,  # (B,) int32
    interpret: bool = True,
):
    B, H, D = q.shape
    N, page, KV, _ = k_pages.shape
    P = page_table.shape[1]
    grid = (B, P)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, p, pt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, KV, D), lambda b, p, pt, ln: (pt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, page, KV, D), lambda b, p, pt, ln: (pt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, p, pt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, H // KV), jnp.float32),
            pltpu.VMEM((KV, H // KV), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, n_pages=P),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)

"""Pure-jnp oracle for the ragged grouped matmul.

Rows of ``x`` are grouped (sorted by group, ragged sizes); row r in group g
is multiplied by that group's weight matrix:  y[r] = x[r] @ w[g].
Used by MoE expert FFNs (group = expert) and multi-adapter serving
(group = adapter bucket).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["grouped_matmul_ref", "row_groups"]


def row_groups(group_sizes: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Group id per row from ragged group sizes (rows past total -> last)."""
    bounds = jnp.cumsum(group_sizes)
    return jnp.searchsorted(bounds, jnp.arange(n_rows), side="right")


def grouped_matmul_ref(x: jnp.ndarray, group_sizes: jnp.ndarray, w: jnp.ndarray):
    """x: (T, d); group_sizes: (G,) summing to <= T; w: (G, d, f) -> (T, f)."""
    gid = row_groups(group_sizes, x.shape[0])
    gid = jnp.minimum(gid, w.shape[0] - 1)
    wg = w[gid]  # (T, d, f) — oracle only; the kernel never materializes this
    return jnp.einsum("td,tdf->tf", x, wg)

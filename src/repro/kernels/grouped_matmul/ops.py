"""Public wrapper: ragged grouped matmul + the paper's hybrid execution.

``grouped_matmul``   — dense tile-mapped kernel path (the 'sequential scan').
``hybrid_grouped_matmul`` — per-group plan selection: groups with tiny row
counts take a gathered jnp path (the 'indexed join'); everything else runs
through the Pallas kernel.  The threshold mirrors core.hybrid's break-even.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import grouped_matmul_pallas
from .ref import grouped_matmul_ref, row_groups

__all__ = ["grouped_matmul", "hybrid_grouped_matmul", "pad_groups_to_tiles"]


def pad_groups_to_tiles(x, group_sizes, bt: int):
    """Scatter rows so each group's rows start at a tile boundary.

    Returns (x_padded, tile_gid, row_map) where row_map[r] is the padded
    row of original row r (used to gather outputs back).
    """
    G = group_sizes.shape[0]
    padded_sizes = ((group_sizes + bt - 1) // bt) * bt
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(padded_sizes)[:-1].astype(jnp.int32)])
    gid = row_groups(group_sizes, x.shape[0]).astype(jnp.int32)
    gid = jnp.minimum(gid, G - 1)
    # position of each row within its group
    offset_in_group = jnp.arange(x.shape[0], dtype=jnp.int32) - jnp.cumsum(
        jnp.concatenate([jnp.zeros(1, jnp.int32), group_sizes[:-1].astype(jnp.int32)])
    )[gid]
    row_map = starts[gid] + offset_in_group
    T_pad = int(((int(group_sizes.shape[0]) * bt)))  # static lower bound
    return row_map, padded_sizes, starts


def grouped_matmul(
    x: jnp.ndarray,
    group_sizes: jnp.ndarray,
    w: jnp.ndarray,
    bt: int = 128,
    bf: int = 256,
    bk: int = 512,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """y[r] = x[r] @ w[group(r)].

    Requires group boundaries tile-aligned (every group size a multiple of
    ``bt``) for the kernel path — the MoE capacity layout guarantees this.
    Falls back to the reference for ragged-unaligned input.
    """
    T, d = x.shape
    if not use_pallas:
        return grouped_matmul_ref(x, group_sizes, w)
    # tile -> group map (computed in-graph; becomes a scalar-prefetch arg)
    n_tiles = T // bt
    first_row = jnp.arange(n_tiles, dtype=jnp.int32) * bt
    tile_gid = row_groups(group_sizes, T).astype(jnp.int32)[first_row]
    tile_gid = jnp.minimum(tile_gid, w.shape[0] - 1)
    # pad f to bf multiple
    f = w.shape[-1]
    pf = (-f) % min(bf, f) if f >= bf else (-f) % f
    bf_eff = min(bf, f + pf)
    if pf:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pf)))
    pk = (-d) % min(bk, d)
    if pk:
        x = jnp.pad(x, ((0, 0), (0, pk)))
        w = jnp.pad(w, ((0, 0), (0, pk), (0, 0)))
    out = grouped_matmul_pallas(
        x, tile_gid, w, bt=bt, bf=bf_eff, bk=min(bk, x.shape[1]),
        interpret=interpret,
    )
    return out[:, :f]


def hybrid_grouped_matmul(
    x: jnp.ndarray,
    group_sizes: jnp.ndarray,
    w: jnp.ndarray,
    threshold_rows: int = 16,
    **kw,
):
    """Paper §3.4 at kernel level: indexed path for tiny groups, scan path
    for contended groups.  Differentiable w.r.t. x and w on both paths."""
    dense = grouped_matmul(x, group_sizes, w, **kw)
    gid = row_groups(group_sizes, x.shape[0])
    gid = jnp.minimum(gid, w.shape[0] - 1)
    small = (group_sizes < threshold_rows)[gid]  # rows on the indexed path
    # Indexed path: per-row gathered weight matmul (random access).
    wg = w[gid]  # (T, d, f) gather — only efficient when few rows; XLA DCEs
    indexed = jnp.einsum("td,tdf->tf", x, wg)
    return jnp.where(small[:, None], indexed, dense)

"""Pallas TPU kernel: ragged grouped matmul with scalar-prefetched group map.

The LifeRaft structure at kernel level: each *group* (MoE expert /
LoRA-adapter bucket) owns a weight matrix that is expensive to bring into
VMEM (the bucket read, T_b); every row routed to the group shares that one
residency (the workload queue's shared pass, T_m per row).  Rows arrive
group-sorted and group boundaries are tile-aligned, so each row-tile maps
to exactly one group; the per-tile group id is a scalar-prefetch operand,
letting Pallas pipeline the correct weight block from HBM ahead of compute.

Grid: (row_tiles, f_tiles, d_tiles) with the contraction (d) innermost,
accumulating in an f32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul_pallas"]


def _kernel(tile_gid_ref, x_ref, w_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bt", "bf", "bk", "interpret")
)
def grouped_matmul_pallas(
    x: jnp.ndarray,  # (T, d), rows group-sorted, T % bt == 0
    tile_gid: jnp.ndarray,  # (T // bt,) int32 — group id per row tile
    w: jnp.ndarray,  # (G, d, f)
    bt: int = 128,
    bf: int = 256,
    bk: int = 512,
    interpret: bool = True,
):
    T, d = x.shape
    G, dw, f = w.shape
    assert dw == d
    bk = min(bk, d)
    bf = min(bf, f)
    assert T % bt == 0 and d % bk == 0 and f % bf == 0, (T, d, f, bt, bk, bf)
    nk = d // bk
    grid = (T // bt, f // bf, nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k, g: (i, k)),
            # weight block for this tile's group: scalar-prefetched gather
            pl.BlockSpec((None, bk, bf), lambda i, j, k, g: (g[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda i, j, k, g: (i, j)),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, f), x.dtype),
        interpret=interpret,
    )(tile_gid, x, w)

"""Tap adapters: the only place observability touches the engines.

Everything here *consumes* the existing side-channel taps —
``DispatchLoop.add_round_tap``, the sharded coordinators' ``on_round`` /
``on_steal``, ``Journal.obs_tap``, and the daemon's admission outcome —
and only ever **reads** the objects it is handed (``DispatchOutcome``,
``StealEvent``, loop/cache/workload state).  Mutating a tapped outcome
would corrupt the journal and the goldens, which consume the same objects;
the ``obs-tap-pure`` lint rule (tools/analysis) enforces this for every
registered tap, including these.

Design constraints (see docs/observability.md):

* **Decision-path untouched** — no tap changes scheduler, cache, workload
  or controller state; every golden replays bit-identically with obs on
  (tested across all scenarios in tests/test_obs.py).
* **Cheap per round** — child metrics are resolved once at attach time;
  the per-round tap is counter adds, up to three histogram bisects, one
  tuple append, and a vector-change tuple compare.  The O(queues) tenant
  walk is sampled every ``ObsConfig.age_sample_every`` rounds (round-count
  based, so virtual-clock determinism is preserved).  The obs-on/obs-off
  throughput ratio is gated >= 0.97x in benchmarks/bench_obs.py.
* **Deterministic on virtual clocks** — nothing wall-clock enters the
  registry unless the tap was attached with ``clock="wall"`` (crossmatch)
  or feeds from real I/O (journal fsync), so simulate/serving snapshots
  are run-to-run identical.
"""
from __future__ import annotations

import dataclasses
from time import perf_counter
from typing import Optional

from .exporters import metrics_snapshot, perfetto_trace, prometheus_text
from .registry import DEFAULT_TIME_BUCKETS, MetricsRegistry
from .tracer import ControlExplain, RoundTracer

__all__ = ["ObsConfig", "Observability", "ensure"]

# Queue ages span ms .. minutes, not the sub-ms tail the time ladder has.
_AGE_BUCKETS = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
    10.0, 30.0, 60.0, 300.0,
)

_VEC_FIELDS = ("alpha", "fuse_k", "spill", "share_width", "horizon")
# What telemetry signal drives each control law (docs/adaptive.md): the
# explain message leads with the field's own trigger.
_FIELD_SIGNAL = {
    "alpha": "saturation",
    "fuse_k": "occupancy",
    "spill": "pending_bytes",
    "share_width": "shared_occupancy",
    "horizon": "stall_frac",
}


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Knobs for the observability layer (all bounded, all default-on)."""

    trace: bool = True  # record round spans / steal arrows
    trace_limit: int = 100_000  # spans kept before counting drops
    explain_limit: int = 10_000
    age_sample_every: int = 16  # rounds between O(queues) tenant walks


def ensure(obs) -> Optional["Observability"]:
    """Coerce an ``obs=`` argument: falsy -> None, True -> fresh instance,
    an :class:`Observability` passes through (the way to export later)."""
    if not obs:
        return None
    if obs is True:
        return Observability()
    return obs


class Observability:
    """One registry + tracer + explain channel, attachable to many taps."""

    def __init__(self, config: Optional[ObsConfig] = None) -> None:
        self.config = config or ObsConfig()
        self.registry = MetricsRegistry()
        self.tracer = (
            RoundTracer(limit=self.config.trace_limit)
            if self.config.trace else None
        )
        self.explain = ControlExplain(limit=self.config.explain_limit)
        self._steal_m = None
        self._journal_m = None

    # -- attach points -----------------------------------------------------
    def attach_loop(
        self, loop, *, track: int = 0, clock: str = "virtual",
        name: Optional[str] = None,
    ) -> "_LoopTap":
        """Chain a metrics/tracing tap onto ``loop`` via ``add_round_tap``.

        ``clock="virtual"`` stamps spans on the loop's simulated clock;
        ``clock="wall"`` (crossmatch/daemon) uses ``perf_counter`` marks
        between taps, which additionally measures host-side select time.
        """
        tap = _LoopTap(self, loop, int(track), wall=(clock == "wall"))
        loop.add_round_tap(tap)
        if self.tracer is not None:
            self.tracer.name_track(track, name or f"shard-{track}")
        return tap

    def note_steal(self, ev) -> None:
        """``on_steal`` tap: one work-steal migration (reads ``ev`` only)."""
        m = self._steal_m
        if m is None:
            reg = self.registry
            m = self._steal_m = (
                reg.counter(
                    "liferaft_steals_total",
                    "Work-steal migrations between shards",
                ),
                reg.counter(
                    "liferaft_steal_units_total",
                    "Work units migrated by stealing",
                ),
                reg.counter(
                    "liferaft_steal_bytes_total",
                    "Bytes of pending work migrated by stealing",
                ),
                reg.counter(
                    "liferaft_steal_reclaimed_seconds_total",
                    "Channel seconds refunded by canceling in-flight "
                    "prefetch stages of stolen buckets",
                ),
            )
        m[0].inc()
        m[1].inc(int(ev.n_units))
        m[2].inc(float(getattr(ev, "nbytes", 0.0)))
        m[3].inc(float(getattr(ev, "reclaimed_stage_s", 0.0)))
        if self.tracer is not None:
            self.tracer.note_steal(
                int(ev.victim), int(ev.thief),
                float(getattr(ev, "clock", 0.0)),
                int(ev.bucket_id), int(ev.n_units),
            )

    def chain_steal_tap(self, prev):
        """Return an ``on_steal`` callable firing ``prev`` first (mirrors
        ``add_round_tap`` ordering), then this instance's steal tap."""
        if prev is None:
            return self.note_steal

        def chained(ev, _prev=prev, _obs=self):
            _prev(ev)
            _obs.note_steal(ev)

        return chained

    def attach_journal(self, journal) -> None:
        """Install the append/fsync latency tap (``Journal.obs_tap``)."""
        journal.obs_tap = self._on_journal

    def _on_journal(self, rtype: str, total_s: float, fsync_s) -> None:
        m = self._journal_m
        if m is None:
            reg = self.registry
            m = self._journal_m = (
                reg.histogram(
                    "liferaft_journal_append_seconds",
                    "Wall latency of one journal append (write+flush"
                    "+fsync when synced)",
                ),
                reg.histogram(
                    "liferaft_journal_fsync_seconds",
                    "Wall latency of the fsync barrier on synced appends",
                ),
                {},
            )
        m[0].observe(total_s)
        if fsync_s is not None:
            m[1].observe(fsync_s)
        key = (rtype or "?", fsync_s is not None)
        c = m[2].get(key)
        if c is None:
            c = m[2][key] = self.registry.counter(
                "liferaft_journal_appends_total",
                "Journal records appended",
                type=key[0], synced=str(key[1]).lower(),
            )
        c.inc()

    def note_admission(
        self, tenant: str, accepted: bool, reason: Optional[str] = None,
    ) -> None:
        """Admission-control outcome for one submission."""
        verdict = "accepted" if accepted else "rejected"
        self.registry.counter(
            "liferaft_admission_total",
            "Admission-control verdicts per tenant",
            tenant=tenant, verdict=verdict,
        ).inc()
        if not accepted:
            self.registry.counter(
                "liferaft_admission_rejected_total",
                "Admission rejections by quota reason",
                tenant=tenant, reason=reason or "?",
            ).inc()

    def note_recovery(self, records: int, rounds: int) -> None:
        """Startup recovery scope (journal records / replayed rounds)."""
        reg = self.registry
        reg.gauge(
            "liferaft_recovery_records",
            "Journal records read during startup recovery",
        ).set(records)
        reg.gauge(
            "liferaft_recovery_replayed_rounds",
            "Dispatch rounds re-executed and diffed during recovery",
        ).set(rounds)

    # -- exports -----------------------------------------------------------
    def prometheus(self) -> str:
        return prometheus_text(self.registry)

    def snapshot(self) -> dict:
        out = {
            "metrics": metrics_snapshot(self.registry),
            "control_explain": list(self.explain.events),
        }
        if self.tracer is not None:
            out["trace"] = {
                "rounds": len(self.tracer.rounds),
                "steals": len(self.tracer.steals),
                "dropped": self.tracer.dropped,
                "tracks": self.tracer.tracks(),
            }
        return out

    def perfetto(self) -> dict:
        if self.tracer is None:
            return {"traceEvents": [], "displayTimeUnit": "ms"}
        return perfetto_trace(self.tracer)


class _LoopTap:
    """The per-round tap chained onto one DispatchLoop.

    Reads the outcome and the loop's public state; never writes either.
    All child metrics are resolved in ``__init__`` so ``__call__`` stays
    allocation-light.
    """

    __slots__ = (
        "obs", "loop", "track", "wall", "tracer", "explain",
        "age_every", "_round_i",
        "m_rounds", "m_buckets", "m_dev", "h_cost", "h_stall", "h_exec",
        "h_select", "h_wall", "g_hit", "_cache", "_cache_m", "_cache_last",
        "_dev_last", "g_vec", "_vec_last", "_tvec_last",
        "m_spill", "m_spill_bytes", "_tenant_m", "_epoch", "_mark",
    )

    def __init__(self, obs: Observability, loop, track: int, wall: bool):
        reg = obs.registry
        t = str(track)
        self.obs = obs
        self.loop = loop
        self.track = track
        self.wall = wall
        self.tracer = obs.tracer
        self.explain = obs.explain
        self.age_every = max(1, obs.config.age_sample_every)
        self._round_i = 0
        self.m_rounds = reg.counter(
            "liferaft_rounds_total", "Scheduling rounds dispatched",
            track=t,
        )
        self.m_buckets = reg.counter(
            "liferaft_buckets_serviced_total",
            "Bucket batches serviced (fused rounds count each bucket)",
            track=t,
        )
        self.m_dev = reg.counter(
            "liferaft_device_dispatches_total",
            "Device calls issued (< buckets under shared plans)",
            track=t,
        )
        self.h_cost = reg.histogram(
            "liferaft_round_cost_seconds",
            "Total engine-clock cost of one round (stall + execute)",
            track=t,
        )
        self.h_stall = reg.histogram(
            "liferaft_round_stall_seconds",
            "Residual prefetch stall paid by the round (nonzero only)",
            track=t,
        )
        self.h_exec = reg.histogram(
            "liferaft_round_execute_seconds",
            "Execute portion of the round (cost - stall)",
            track=t,
        )
        self.h_select = reg.histogram(
            "liferaft_round_select_seconds",
            "Measured host-side select/plan overhead (wall-clock taps "
            "only; the virtual clock prices selection at zero)",
            track=t,
        ) if wall else None
        self.h_wall = reg.histogram(
            "liferaft_round_wall_seconds",
            "Wall time between consecutive rounds (wall-clock taps only)",
            track=t,
        ) if wall else None
        self.g_hit = reg.gauge(
            "liferaft_cache_hit_ratio", "Cumulative cache hit rate",
            track=t,
        )
        cache = getattr(loop, "cache", None)
        self._cache = getattr(cache, "stats", None)
        self._cache_m = (
            reg.counter(
                "liferaft_cache_demand_hits_total",
                "Cache hits on demand-resident buckets", track=t,
            ),
            reg.counter(
                "liferaft_cache_prefetch_hits_total",
                "Cache hits satisfied by a prefetched fill", track=t,
            ),
            reg.counter(
                "liferaft_cache_misses_total", "Cache misses", track=t,
            ),
            reg.counter(
                "liferaft_cache_evictions_total", "Cache evictions",
                track=t,
            ),
            reg.counter(
                "liferaft_cache_prefetch_unused_total",
                "Prefetched fills evicted untouched", track=t,
            ),
        )
        self._cache_last = self._cache_snapshot()
        self._dev_last = loop.device_dispatches
        self.g_vec = {
            f: reg.gauge(
                f"liferaft_control_{f}",
                f"Applied ControlVector {f} (merged vector under the "
                f"tenant plane)",
                track=t,
            )
            for f in _VEC_FIELDS
        }
        self._vec_last = None
        self._tvec_last: dict = {}
        self.m_spill = (
            reg.counter(
                "liferaft_spill_transitions_total",
                "Buckets spilled to the overflow tier", track=t,
                direction="spill",
            ),
            reg.counter(
                "liferaft_spill_transitions_total",
                "Buckets spilled to the overflow tier", track=t,
                direction="unspill",
            ),
        )
        self.m_spill_bytes = (
            reg.counter(
                "liferaft_spill_bytes_total",
                "Bytes moved across the spill boundary", track=t,
                direction="spill",
            ),
            reg.counter(
                "liferaft_spill_bytes_total",
                "Bytes moved across the spill boundary", track=t,
                direction="unspill",
            ),
        )
        self._tenant_m: dict = {}
        self._epoch = perf_counter() if wall else 0.0
        self._mark = 0.0

    def _cache_snapshot(self):
        st = self._cache
        if st is None:
            return None
        return (
            st.demand_hits, st.prefetch_hits, st.misses,
            st.evictions, st.prefetch_unused,
        )

    # -- the tap (chained after any pre-existing on_round consumers) -------
    def __call__(self, outcome) -> None:
        loop = self.loop
        cost = outcome.cost
        stall = outcome.stall
        exe = cost - stall
        ndec = len(outcome.decisions)
        self.m_rounds.inc()
        self.m_buckets.inc(ndec)
        self.h_cost.observe(cost)
        self.h_exec.observe(exe)
        if stall:
            self.h_stall.observe(stall)
        dd = loop.device_dispatches
        if dd != self._dev_last:
            self.m_dev.inc(dd - self._dev_last)
            self._dev_last = dd
        cur = self._cache_snapshot()
        if cur is not None:
            last = self._cache_last
            if cur != last:
                for m, c, prev in zip(self._cache_m, cur, last):
                    if c != prev:
                        m.inc(c - prev)
                self._cache_last = cur
            self.g_hit.set(self._cache.hit_rate)
        if outcome.spill_changed:
            self._note_spill(outcome.spill_changed)
        vec = outcome.vector
        key = (
            vec.alpha, vec.fuse_k, vec.spill,
            getattr(vec, "share_width", 0), getattr(vec, "horizon", 0),
        )
        if key != self._vec_last:
            self._note_vector(key, self._vec_last, track=str(self.track))
            self._vec_last = key
        tvecs = outcome.tenant_vectors
        if tvecs:
            self._note_tenant_vectors(tvecs)
        self._round_i += 1
        if self._round_i % self.age_every == 0:
            self._sample_tenants()
        tr = self.tracer
        if tr is None:
            return
        if self.wall:
            now = perf_counter() - self._epoch
            wall_dur = now - self._mark
            sel = max(0.0, wall_dur - cost)
            if self.h_select is not None:
                self.h_select.observe(sel)
                self.h_wall.observe(wall_dur)
            # Wall spans: the measured interval, with the select child the
            # slice the cost model cannot see.  Model stall/execute don't
            # nest on the wall axis, so they ride in args via the round
            # histograms instead of as children.
            tr.note_round(
                self.track, self._mark, wall_dur,
                (("select", sel),) if sel > 0.0 else (),
                ndec,
            )
            self._mark = now
        else:
            t1 = loop.clock  # the round just advanced it by cost
            children = (
                (("prefetch_stall", stall), ("execute", exe))
                if stall else (("execute", exe),)
            )
            tr.note_round(self.track, t1 - cost, cost, children, ndec)

    # -- slow paths (change- or sample-triggered) --------------------------
    def _note_spill(self, changed) -> None:
        wm = self.loop.wm
        spilled_frac = getattr(wm, "spilled_fraction", None)
        queues = getattr(wm, "queues", None)
        for b in changed:
            frac = spilled_frac(b) if spilled_frac is not None else 0.0
            q = queues.get(b) if queues is not None else None
            if frac > 0.0:
                self.m_spill[0].inc()
                if q is not None:
                    self.m_spill_bytes[0].inc(
                        float(getattr(q, "spilled_bytes", 0.0))
                    )
            else:
                self.m_spill[1].inc()
                if q is not None:
                    self.m_spill_bytes[1].inc(
                        float(getattr(q, "resident_bytes", 0.0))
                    )

    def _reason(self, field: str, tel) -> str:
        lead = _FIELD_SIGNAL.get(field, "telemetry")
        return (
            f"{lead} moved (rate={tel.arrival_rate:.3g}/s"
            f" depth={tel.pending_objects}"
            f" oldest={tel.oldest_age_ms:.0f}ms"
            f" hit={tel.cache_hit_rate:.2f}"
            f" occ={tel.occupancy:.2f}"
            f" stall={tel.prefetch_stall_frac:.2f})"
        )

    def _note_vector(self, key, last, track: str) -> None:
        gauges = self.g_vec
        tel = None
        for i, f in enumerate(_VEC_FIELDS):
            v = float(key[i])
            gauges[f].set(v)
            if last is not None and key[i] != last[i]:
                if tel is None:
                    tel = self.loop.telemetry()  # pure read; change-rate only
                self.explain.note(
                    track, self.loop.clock, f,
                    float(last[i]), v, self._reason(f, tel),
                )

    def _note_tenant_vectors(self, tvecs) -> None:
        for tname, v in tvecs.items():
            key = (
                v.alpha, v.fuse_k, v.spill,
                getattr(v, "share_width", 0), getattr(v, "horizon", 0),
            )
            last = self._tvec_last.get(tname)
            if key == last:
                continue
            self._tvec_last[tname] = key
            if last is not None:
                tel = self.loop.telemetry()
                for i, f in enumerate(_VEC_FIELDS):
                    if key[i] != last[i]:
                        self.explain.note(
                            f"{self.track}:{tname}", self.loop.clock, f,
                            float(last[i]), float(key[i]),
                            self._reason(f, tel),
                        )

    def _sample_tenants(self) -> None:
        tels = self.loop._tenant_telemetry()  # one O(queues) read-only pass
        reg = self.obs.registry
        for tname in sorted(tels):
            tel = tels[tname]
            m = self._tenant_m.get(tname)
            if m is None:
                m = self._tenant_m[tname] = (
                    reg.histogram(
                        "liferaft_tenant_queue_age_seconds",
                        "Oldest pending-unit age per tenant (sampled "
                        "every age_sample_every rounds)",
                        buckets=_AGE_BUCKETS, tenant=tname,
                    ),
                    reg.gauge(
                        "liferaft_tenant_pending_objects",
                        "Pending objects per tenant", tenant=tname,
                    ),
                    reg.gauge(
                        "liferaft_tenant_pending_bytes",
                        "Pending bytes per tenant", tenant=tname,
                    ),
                )
            m[0].observe(tel.oldest_age_ms / 1e3)
            m[1].set(tel.pending_objects)
            m[2].set(tel.pending_bytes)

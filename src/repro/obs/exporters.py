"""Exporters: Prometheus text exposition, JSON snapshot, Perfetto trace.

All three are pure functions over the registry/tracer stores — exporting
never mutates observability state, so a snapshot can be taken mid-run (the
daemon serves these) and the output is deterministic for virtual-clocked
runs (sorted iteration everywhere; see ``registry.MetricsRegistry``).

The Perfetto export is the Chrome trace-event JSON object format
(``{"traceEvents": [...]}``, timestamps in microseconds): one named thread
per track (an S-track timeline for a sharded run), complete ``"X"`` spans
for rounds and their latency-breakdown children, and flow events
(``"s"``/``"f"``, ``cat == "steal"``) drawing each work-steal migration as
an arrow from the victim's track to the thief's.  Loadable directly in
https://ui.perfetto.dev or chrome://tracing.
"""
from __future__ import annotations

__all__ = ["prometheus_text", "metrics_snapshot", "perfetto_trace"]

_US = 1e6  # seconds -> trace microseconds


def _esc_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v) -> str:
    # Prometheus floats: ints render bare, floats via repr (shortest
    # round-trip, so snapshots diff bit-identically).
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v)


def _series_name(name: str, labels, extra=()) -> str:
    items = list(labels) + list(extra)
    if not items:
        return name
    body = ",".join(f'{k}="{_esc_label(str(v))}"' for k, v in items)
    return f"{name}{{{body}}}"


def prometheus_text(registry) -> str:
    """Prometheus text exposition (version 0.0.4) of the registry."""
    out: list[str] = []
    for name, typ, help_, series in registry.families():
        if help_:
            out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} {typ}")
        for key, m in series:
            if typ == "histogram":
                for le, cum in m.cumulative():
                    out.append(
                        f"{_series_name(name + '_bucket', key, [('le', le)])}"
                        f" {cum}"
                    )
                out.append(f"{_series_name(name + '_sum', key)} {_fmt(m.sum)}")
                out.append(f"{_series_name(name + '_count', key)} {m.count}")
            else:
                out.append(f"{_series_name(name, key)} {_fmt(m.value)}")
    return "\n".join(out) + "\n"


def metrics_snapshot(registry) -> dict:
    """JSON-safe snapshot (deterministic ordering); see registry.snapshot."""
    return registry.snapshot()


def perfetto_trace(tracer, *, process_name: str = "liferaft") -> dict:
    """Chrome-trace-event/Perfetto JSON for the recorded spans + steals."""
    events: list[dict] = [{
        "ph": "M", "name": "process_name", "pid": 1, "tid": 0,
        "args": {"name": process_name},
    }]
    for track in tracer.tracks():
        tname = tracer.track_names.get(track, f"shard-{track}")
        events.append({
            "ph": "M", "name": "thread_name", "pid": 1, "tid": track,
            "args": {"name": tname},
        })
        events.append({
            "ph": "M", "name": "thread_sort_index", "pid": 1, "tid": track,
            "args": {"sort_index": track},
        })
    for track, t0, dur, children, n_buckets in tracer.rounds:
        events.append({
            "ph": "X", "name": "round", "cat": "round",
            "pid": 1, "tid": track,
            "ts": t0 * _US, "dur": dur * _US,
            "args": {"buckets": n_buckets},
        })
        t = t0
        for cname, cdur in children:
            if cdur <= 0.0:
                continue
            events.append({
                "ph": "X", "name": cname, "cat": "round",
                "pid": 1, "tid": track,
                "ts": t * _US, "dur": cdur * _US,
            })
            t += cdur
    for i, (victim, thief, t, bucket_id, n_units) in enumerate(tracer.steals):
        ts = t * _US
        args = {"bucket": bucket_id, "units": n_units}
        # Instant markers on both tracks make the migration visible even
        # when a renderer hides flows; the s/f pair draws the arrow.
        events.append({
            "ph": "i", "s": "t", "name": "steal", "cat": "steal",
            "pid": 1, "tid": victim, "ts": ts, "args": args,
        })
        events.append({
            "ph": "s", "id": i, "name": "steal", "cat": "steal",
            "pid": 1, "tid": victim, "ts": ts,
        })
        events.append({
            "ph": "f", "bp": "e", "id": i, "name": "steal", "cat": "steal",
            "pid": 1, "tid": thief, "ts": ts,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}

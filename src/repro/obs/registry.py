"""Metrics primitives: counters, gauges, bounded-bucket histograms.

The registry is deliberately tiny and stdlib-only.  Two properties matter
more than features:

* **Hot-tap cheapness** — tap adapters resolve their child metrics *once*
  at attach time (``registry.counter(name, help, **labels)`` returns the
  labeled child directly), so the per-round work is a float add or a
  bisect, never a dict/label allocation.
* **Deterministic snapshots** — ``snapshot()`` and the exporters iterate
  families and label sets in sorted order and store only plain floats/ints,
  so two runs of the same virtual-clocked simulation produce *equal*
  snapshot dicts (a tested property; see tests/test_obs.py).

Histograms use a fixed, bounded bucket ladder (no dynamic resize): an
observation lands in the first bucket whose upper bound is ``>= v``
(Prometheus ``le`` semantics) and anything beyond the last bound lands in
the overflow bucket.  Quantiles are the usual linear-interpolation
estimate over the cumulative counts, clamped to the last finite bound for
overflow mass.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Optional

__all__ = [
    "DEFAULT_TIME_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

# Log-spaced seconds ladder: covers sub-ms fsyncs up to multi-second
# spill-heavy rounds.  14 bounds + overflow keeps every histogram bounded.
DEFAULT_TIME_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Counter:
    """Monotonic counter (floats allowed: byte totals, seconds totals)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-bucket histogram with Prometheus ``le`` semantics."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds=DEFAULT_TIME_BUCKETS) -> None:
        bounds = tuple(float(b) for b in bounds)
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram bounds must be ascending: {bounds}")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # last slot = overflow (+Inf)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile estimate (0 when empty)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            prev_cum = cum
            cum += c
            if cum >= target:
                if i >= len(self.bounds):  # overflow: clamp to last bound
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                frac = (target - prev_cum) / c
                return lo + (hi - lo) * min(1.0, max(0.0, frac))
        return self.bounds[-1]

    def cumulative(self) -> list:
        """``[(le, cumulative_count), ...]`` ending with ``("+Inf", count)``."""
        out = []
        cum = 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            out.append((b, cum))
        out.append(("+Inf", self.count))
        return out


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    __slots__ = ("name", "type", "help", "buckets", "series")

    def __init__(self, name, typ, help_, buckets) -> None:
        self.name = name
        self.type = typ
        self.help = help_
        self.buckets = buckets
        self.series: dict = {}  # sorted label-items tuple -> metric


class MetricsRegistry:
    """Name -> family of labeled children.  See module docstring."""

    def __init__(self) -> None:
        self._families: dict[str, _Family] = {}

    def _child(self, typ: str, name: str, help_: str, buckets, labels):
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = _Family(name, typ, help_, buckets)
        elif fam.type != typ:
            raise ValueError(
                f"metric {name!r} already registered as {fam.type}, not {typ}"
            )
        elif typ == "histogram" and buckets is not None and fam.buckets != buckets:
            raise ValueError(f"metric {name!r} bucket ladder mismatch")
        key = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        child = fam.series.get(key)
        if child is None:
            if typ == "histogram":
                child = Histogram(buckets or DEFAULT_TIME_BUCKETS)
            else:
                child = _TYPES[typ]()
            fam.series[key] = child
        return child

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._child("counter", name, help, None, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._child("gauge", name, help, None, labels)

    def histogram(
        self, name: str, help: str = "",
        buckets: Optional[tuple] = None, **labels,
    ) -> Histogram:
        if buckets is not None:
            buckets = tuple(float(b) for b in buckets)
        return self._child("histogram", name, help, buckets, labels)

    def families(self) -> list:
        """``(name, type, help, [(label_items, metric), ...])`` sorted for
        deterministic export."""
        out = []
        for name in sorted(self._families):
            fam = self._families[name]
            series = sorted(fam.series.items())
            out.append((fam.name, fam.type, fam.help, series))
        return out

    def snapshot(self) -> dict:
        """Plain-data, deterministically ordered dump (JSON-safe)."""
        out: dict = {}
        for name, typ, help_, series in self.families():
            rows = []
            for key, m in series:
                labels = {k: v for k, v in key}
                if typ == "histogram":
                    rows.append({
                        "labels": labels,
                        "buckets": [
                            [le, c] for le, c in m.cumulative()
                        ],
                        "sum": m.sum,
                        "count": m.count,
                        "p50": m.quantile(0.50),
                        "p95": m.quantile(0.95),
                    })
                else:
                    rows.append({"labels": labels, "value": m.value})
            out[name] = {"type": typ, "help": help_, "series": rows}
        return out

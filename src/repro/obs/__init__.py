"""repro.obs — metrics, round tracing and exporters off the decision taps.

The observability layer is fed **exclusively** through the engines' side
channels (``DispatchLoop.add_round_tap``, the sharded ``on_round`` /
``on_steal`` callbacks, ``Journal.obs_tap``, the daemon's admission
outcome): the decision path neither knows nor cares it exists, every
golden replays bit-identically with it on, and with ``obs=`` off (the
default everywhere) this package is never imported — the engines import
it lazily inside their enabled branch only.

Public surface:

* :class:`Observability` — one registry + tracer + ControlExplain bundle,
  attachable to any number of loops/journals/daemons; pass it as the
  ``obs=`` argument of ``simulate_batched`` / ``simulate_sharded`` /
  ``run_policy`` / ``LifeRaftEngine`` / ``ShardedServingEngine`` /
  ``CrossMatchEngine`` / ``ServiceDaemon``.
* :class:`ObsConfig` — bounds and sampling knobs.
* :class:`MetricsRegistry` / :class:`RoundTracer` / :class:`ControlExplain`
  — the underlying stores.
* ``prometheus_text`` / ``metrics_snapshot`` / ``perfetto_trace`` — pure
  exporters (also reachable as ``Observability.prometheus`` /
  ``.snapshot`` / ``.perfetto``).

See docs/observability.md for the metric catalog, span schema and the
taps-only design rationale.
"""
from .adapters import Observability, ObsConfig, ensure
from .exporters import metrics_snapshot, perfetto_trace, prometheus_text
from .registry import (
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .tracer import ControlExplain, RoundTracer

__all__ = [
    "Observability",
    "ObsConfig",
    "ensure",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "DEFAULT_TIME_BUCKETS",
    "RoundTracer",
    "ControlExplain",
    "prometheus_text",
    "metrics_snapshot",
    "perfetto_trace",
]

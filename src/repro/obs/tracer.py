"""Span-tree round tracer + the ControlExplain change log.

The tracer records each scheduling round as one span on its track (track =
shard id; unsharded loops are track 0) with nested child spans for the
round's latency breakdown, all on the **engine clock**:

* virtual engines (simulate/serving) — span boundaries are exact virtual
  seconds: ``[clock - cost, clock]`` with ``prefetch_stall`` / ``execute``
  children partitioning the interval (selection is free on the cost
  model's clock, so there is no ``select`` child);
* wall-clock engines (crossmatch, daemon) — span boundaries are
  ``perf_counter`` marks between consecutive taps, so the leading
  ``select`` child is the *measured* host-side select/plan overhead the
  virtual clock cannot see.

Storage is append-only tuples (the tap adapters are on the per-round path;
event-dict construction is deferred to export time — see
``exporters.perfetto_trace``).  Both stores are bounded: past ``limit``
events are counted in ``dropped`` instead of growing without bound under a
long-lived daemon.

``ControlExplain`` is the "why did the controller move" channel: one entry
per ControlVector field change, stamped with the engine clock and a
telemetry-derived reason string ("alpha 0.2->0.35: rate=12/s oldest=514ms").
"""
from __future__ import annotations

__all__ = ["RoundTracer", "ControlExplain"]


class RoundTracer:
    """Bounded store of round spans and steal arrows, keyed by track."""

    __slots__ = ("limit", "dropped", "rounds", "steals", "track_names")

    def __init__(self, limit: int = 100_000) -> None:
        self.limit = int(limit)
        self.dropped = 0
        # (track, t0, dur, children, n_buckets); children is a tuple of
        # (name, dur) pairs laid out consecutively from t0.
        self.rounds: list = []
        # (victim, thief, t, bucket_id, n_units)
        self.steals: list = []
        self.track_names: dict[int, str] = {}

    def name_track(self, track: int, name: str) -> None:
        self.track_names.setdefault(int(track), str(name))

    def note_round(
        self, track: int, t0: float, dur: float, children, n_buckets: int,
    ) -> None:
        if len(self.rounds) >= self.limit:
            self.dropped += 1
            return
        self.rounds.append((track, t0, dur, children, n_buckets))

    def note_steal(
        self, victim: int, thief: int, t: float, bucket_id: int, n_units: int,
    ) -> None:
        if len(self.steals) >= self.limit:
            self.dropped += 1
            return
        self.steals.append((victim, thief, t, bucket_id, n_units))

    def tracks(self) -> list:
        ts = {r[0] for r in self.rounds}
        for v, t, *_ in self.steals:
            ts.add(v)
            ts.add(t)
        return sorted(ts)


class ControlExplain:
    """One entry per ControlVector field change, with the trigger signal."""

    __slots__ = ("limit", "dropped", "events")

    def __init__(self, limit: int = 10_000) -> None:
        self.limit = int(limit)
        self.dropped = 0
        self.events: list = []

    def note(
        self, track, clock: float, field: str, old, new, reason: str,
    ) -> None:
        if len(self.events) >= self.limit:
            self.dropped += 1
            return
        self.events.append({
            "track": track,
            "clock": clock,
            "field": field,
            "from": old,
            "to": new,
            "message": f"{field} {old:g}->{new:g}: {reason}",
        })

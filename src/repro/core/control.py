"""Closed-loop adaptive control plane (paper §4 adaptation + §6 overflow).

The paper's headline mechanism is *adaptation*: LifeRaft "adaptively and
incrementally trades off processing queries in arrival order and
data-driven batch processing" based on workload saturation and queuing
times.  This module centralizes every run-time knob into one feedback
loop so both engines and the simulator make identical control decisions:

    telemetry (per scheduling round)          ControlVector (per round)
    ------------------------------------      -------------------------
    arrival rate   <- SaturationEstimator     alpha   (Eq. 2 blend)
    queue depth/age <- WorkloadManager    ->  fuse_k  (buckets/dispatch)
    cache hit rate <- BucketCache             spill   (§6 overflow)
    batch occupancy <- executor

* ``alpha`` follows the paper's §4 rule when a ``TradeoffTable`` of
  offline curves is available (min response s.t. throughput >= (1-tol) *
  max), and otherwise a table-free fallback that maps EWMA saturation
  (arrival rate + backlog depth) onto [alpha_min, alpha_max]: idle ->
  arrival order (low response), saturated -> data-driven (throughput).
  Either way the step per round is rate-limited (``alpha_step``) so the
  scheduler shifts *gradually*, per the paper's framing.
* ``fuse_k`` is AIMD on batch occupancy: when dispatches run underfull
  and several queues are pending, fuse one more bucket into the next
  grouped device call; when dispatches saturate, back off.
* ``spill`` engages §6 workload overflow (with hysteresis) when resident
  pending objects exceed a budget; ``apply_spill`` enforces it on the
  WorkloadManager by spilling youngest-first victims (spilled queues pay
  the cost model's T_spill surcharge in the scheduler score, so they are
  deprioritized until age reclaims them — never starved).

``DispatchLoop`` (core/dispatch.py) is the single consumer: it snapshots
telemetry, calls :meth:`ControlLoop.update` once per scheduling round,
and applies the resulting vector.  Engines never touch the knobs
directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .adaptive import SaturationEstimator, TradeoffTable

__all__ = [
    "ControlVector",
    "Telemetry",
    "ControlConfig",
    "ControlLoop",
    "apply_spill",
]


@dataclasses.dataclass(frozen=True)
class ControlVector:
    """One scheduling round's control decision, applied by DispatchLoop."""

    alpha: float  # Eq. 2 in-order vs data-driven blend, in [0, 1]
    fuse_k: int  # buckets serviced per fused dispatch, >= 1
    spill: bool  # engage §6 workload overflow this round


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Per-round sensor snapshot fed to the controller."""

    now: float
    arrival_rate: float  # EWMA queries/sec (SaturationEstimator)
    pending_objects: int  # total pending work units across queues
    resident_objects: int  # pending objects NOT spilled to host
    n_queues: int  # nonempty workload queues
    oldest_age_ms: float  # age of the oldest pending request
    cache_hit_rate: float  # BucketCache lifetime hit rate
    occupancy: float  # last dispatch's batch fill fraction, [0, 1]


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    # -- alpha ---------------------------------------------------------------
    table: Optional[TradeoffTable] = None  # offline §4 curves (preferred)
    tolerance: float = 0.2  # throughput loss tolerated for response
    alpha_init: float = 0.5
    alpha_min: float = 0.0
    alpha_max: float = 1.0
    alpha_step: float = 0.1  # max |d alpha| per round (rate limit)
    halflife_s: float = 30.0  # arrival-rate EWMA halflife
    rate_knee: float = 0.5  # qps at which the fallback saturates
    depth_knee: float = 2_000.0  # backlog at which the fallback saturates
    depth_smoothing: float = 0.2  # EWMA weight for the backlog signal
    # -- fuse_k --------------------------------------------------------------
    fuse_k_init: int = 1
    fuse_k_max: int = 8
    occ_low: float = 0.5  # below: dispatches underfull -> fuse more
    occ_high: float = 0.95  # above: dispatches saturated -> back off
    # -- spill ---------------------------------------------------------------
    spill_budget_objects: Optional[int] = None  # None disables overflow
    spill_low_water: float = 0.8  # disengage below this fraction


class ControlLoop:
    """The one feedback loop driving alpha, fuse_k, and spill.

    ``observe_arrival`` is O(1) and called on every query/request intake;
    ``update`` is called once per scheduling round by the DispatchLoop and
    returns the ControlVector for that round.
    """

    def __init__(self, config: ControlConfig = ControlConfig()) -> None:
        self.cfg = config
        self.estimator = SaturationEstimator(config.halflife_s)
        self._alpha = min(max(config.alpha_init, config.alpha_min), config.alpha_max)
        self._fuse_k = max(1, int(config.fuse_k_init))
        self._depth_ewma = 0.0
        self._spilling = False
        self.rounds = 0
        self.last: Optional[ControlVector] = None

    # -- sensors ----------------------------------------------------------------
    def observe_arrival(self, t: float) -> float:
        return self.estimator.observe_arrival(t)

    @property
    def arrival_rate(self) -> float:
        return self.estimator.rate

    # -- the loop ---------------------------------------------------------------
    def update(self, tel: Telemetry) -> ControlVector:
        vec = ControlVector(
            alpha=self._update_alpha(tel),
            fuse_k=self._update_fuse_k(tel),
            spill=self._update_spill(tel),
        )
        self.last = vec
        self.rounds += 1
        return vec

    # -- alpha law --------------------------------------------------------------
    def _update_alpha(self, tel: Telemetry) -> float:
        cfg = self.cfg
        target = None
        if cfg.table is not None:
            try:
                target = cfg.table.select_alpha(tel.arrival_rate, cfg.tolerance)
            except ValueError:  # empty table -> table-free fallback
                target = None
        if target is None:
            target = self._fallback_target(tel)
        target = min(max(target, cfg.alpha_min), cfg.alpha_max)
        delta = max(-cfg.alpha_step, min(cfg.alpha_step, target - self._alpha))
        self._alpha = min(max(self._alpha + delta, 0.0), 1.0)
        return self._alpha

    def _fallback_target(self, tel: Telemetry) -> float:
        """Table-free EWMA law: saturation in [0,1] from arrival rate and
        backlog depth; idle -> alpha_max (arrival order), saturated ->
        alpha_min (data-driven batch)."""
        cfg = self.cfg
        w = cfg.depth_smoothing
        self._depth_ewma += w * (tel.pending_objects - self._depth_ewma)
        sat = max(
            tel.arrival_rate / cfg.rate_knee if cfg.rate_knee > 0 else 0.0,
            self._depth_ewma / cfg.depth_knee if cfg.depth_knee > 0 else 0.0,
        )
        sat = min(sat, 1.0)
        return cfg.alpha_max - (cfg.alpha_max - cfg.alpha_min) * sat

    # -- fuse_k law -------------------------------------------------------------
    def _update_fuse_k(self, tel: Telemetry) -> int:
        """AIMD on batch occupancy: underfull dispatches with pending breadth
        fuse one more bucket; saturated dispatches back off."""
        cfg = self.cfg
        k = self._fuse_k
        if tel.occupancy < cfg.occ_low and tel.n_queues > k:
            k += 1
        elif tel.occupancy > cfg.occ_high and k > 1:
            k -= 1
        k = max(1, min(k, cfg.fuse_k_max, max(tel.n_queues, 1)))
        self._fuse_k = k
        return k

    # -- spill law --------------------------------------------------------------
    def _update_spill(self, tel: Telemetry) -> bool:
        cfg = self.cfg
        if cfg.spill_budget_objects is None:
            return False
        if tel.resident_objects > cfg.spill_budget_objects:
            self._spilling = True
        elif tel.pending_objects <= cfg.spill_budget_objects * cfg.spill_low_water:
            self._spilling = False
        return self._spilling


def apply_spill(wm, vector: ControlVector, config: ControlConfig) -> list[int]:
    """Enforce the §6 overflow budget on a workload manager.

    When ``vector.spill``: spill youngest-first victims (their requesters
    have waited least; the age term reclaims them later) until resident
    pending objects fit the budget, always leaving at least one resident
    queue.  When disengaged: page queues back in oldest-first while they
    fit under the low-water mark.  Returns the bucket ids whose spill
    state changed this round.
    """
    budget = config.spill_budget_objects
    if budget is None or not hasattr(wm, "spill_bucket"):
        return []
    changed: list[int] = []
    nonempty = [(q.oldest_arrival, q.bucket_id, q.size) for q in wm.nonempty_queues()]
    resident = [(t, b, n) for t, b, n in nonempty if not wm.is_spilled(b)]
    resident_total = sum(n for _, _, n in resident)
    if vector.spill:
        # Youngest first == largest oldest_arrival first.
        for t, b, n in sorted(resident, reverse=True):
            if resident_total <= budget or len(resident) - len(changed) <= 1:
                break
            if wm.spill_bucket(b):
                changed.append(b)
                resident_total -= n
    else:
        low = budget * config.spill_low_water
        spilled = sorted(
            (t, b, n) for t, b, n in nonempty if wm.is_spilled(b)
        )  # oldest first
        for t, b, n in spilled:
            if resident_total + n > low:
                break
            if wm.unspill_bucket(b):
                changed.append(b)
                resident_total += n
    return changed

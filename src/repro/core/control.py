"""Closed-loop adaptive control plane (paper §4 adaptation + §6 overflow).

The paper's headline mechanism is *adaptation*: LifeRaft "adaptively and
incrementally trades off processing queries in arrival order and
data-driven batch processing" based on workload saturation and queuing
times.  This module centralizes every run-time knob into one feedback
loop so both engines and the simulator make identical control decisions:

    telemetry (per scheduling round)          ControlVector (per round)
    ------------------------------------      -------------------------
    arrival rate   <- SaturationEstimator     alpha   (Eq. 2 blend)
    queue depth/age <- WorkloadManager    ->  fuse_k  (buckets/dispatch)
    cache hit rate <- BucketCache             spill   (§6 overflow)
    batch occupancy <- executor

* ``alpha`` follows the paper's §4 rule when a ``TradeoffTable`` of
  offline curves is available (min response s.t. throughput >= (1-tol) *
  max), and otherwise a table-free fallback that maps EWMA saturation
  (arrival rate + backlog depth) onto [alpha_min, alpha_max]: idle ->
  arrival order (low response), saturated -> data-driven (throughput).
  Either way the step per round is rate-limited (``alpha_step``) so the
  scheduler shifts *gradually*, per the paper's framing.
* ``fuse_k`` is AIMD on batch occupancy: when dispatches run underfull
  and several queues are pending, fuse one more bucket into the next
  grouped device call; when dispatches saturate, back off.
* ``spill`` engages §6 workload overflow (with hysteresis) when resident
  pending probe *bytes* exceed the budget (``spill_budget_bytes``; the
  object-count proxy survives as the legacy ``spill_budget_objects``
  mode); ``apply_spill`` enforces it by walking victim queues
  youngest-first and spilling exactly the deficit — whole queues, then a
  *partial* spill of the boundary victim whose oldest units stay resident
  (spilled bytes pay a pro-rated T_spill surcharge in the scheduler
  score, so they are deprioritized until age reclaims them — never
  starved).

``TenantControlPlane`` lifts all of this to multi-tenant: one ControlLoop
per tenant class (interactive vs batch — CasJobs' queue split, SharedDB's
per-class SLOs) over per-tenant telemetry slices, one shared
SaturationEstimator, and a budget arbiter that waterfills the global §6
byte budget across tenants by weight.

``DispatchLoop`` (core/dispatch.py) is the single consumer: it snapshots
telemetry, calls :meth:`ControlLoop.update` (or the plane's) once per
scheduling round, and applies the resulting vector(s).  Engines never
touch the knobs directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Sequence

from .adaptive import SaturationEstimator, TradeoffTable

__all__ = [
    "ControlVector",
    "Telemetry",
    "ControlConfig",
    "ControlLoop",
    "TenantPolicy",
    "TenantControlPlane",
    "ShardGrant",
    "ShardControlPlane",
    "AdmissionQuota",
    "AdmissionRejected",
    "AdmissionController",
    "apply_spill",
    "unspill_price",
    "waterfill",
]


@dataclasses.dataclass(frozen=True)
class ControlVector:
    """One scheduling round's control decision, applied by DispatchLoop."""

    alpha: float  # Eq. 2 in-order vs data-driven blend, in [0, 1]
    fuse_k: int  # buckets serviced per fused dispatch, >= 1
    spill: bool  # engage §6 workload overflow this round
    horizon: int = 0  # prefetch lookahead H (0: law disabled, use static H)
    share_width: int = 0  # queries per shared-plan call (0: law disabled)


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Per-round sensor snapshot fed to the controller.  Under the
    multi-tenant plane, one snapshot per tenant class (queues owned by
    that tenant only)."""

    now: float
    arrival_rate: float  # EWMA queries/sec (SaturationEstimator)
    pending_objects: int  # total pending work units across queues
    resident_objects: int  # pending objects NOT spilled to host
    n_queues: int  # nonempty workload queues
    oldest_age_ms: float  # age of the oldest pending request
    cache_hit_rate: float  # BucketCache lifetime hit rate
    occupancy: float  # last dispatch's batch fill fraction, [0, 1]
    pending_bytes: float = 0.0  # total pending probe bytes
    resident_bytes: float = 0.0  # probe bytes NOT spilled (§6 budget target)
    # -- prefetch pipeline signals (all zero without a pipeline) --------------
    prefetch_stall_frac: float = 0.0  # last round's stall share of round time
    prefetch_wasted: int = 0  # prefetched fills evicted untouched last round
    prefetch_inflight: int = 0  # stages in flight on the staging channel
    # -- shared-plan signals (zero without a shared executor) -----------------
    shared_occupancy: float = 0.0  # queries / (chunks * share_width), [0, 1]


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    # -- alpha ---------------------------------------------------------------
    table: Optional[TradeoffTable] = None  # offline §4 curves (preferred)
    tolerance: float = 0.2  # throughput loss tolerated for response
    alpha_init: float = 0.5
    alpha_min: float = 0.0
    alpha_max: float = 1.0
    alpha_step: float = 0.1  # max |d alpha| per round (rate limit)
    halflife_s: float = 30.0  # arrival-rate EWMA halflife
    rate_knee: float = 0.5  # qps at which the fallback saturates
    depth_knee: float = 2_000.0  # backlog at which the fallback saturates
    depth_smoothing: float = 0.2  # EWMA weight for the backlog signal
    # -- fuse_k --------------------------------------------------------------
    fuse_k_init: int = 1
    fuse_k_max: int = 8
    occ_low: float = 0.5  # below: dispatches underfull -> fuse more
    occ_high: float = 0.95  # above: dispatches saturated -> back off
    # -- share_width (shared query plans) -------------------------------------
    share_width_init: int = 8
    share_width_max: int = 0  # 0 disables the law (static width applies)
    share_occ_low: float = 0.5  # below: mostly padding -> narrow the plan
    share_occ_high: float = 0.95  # above: chunks saturate width -> widen
    # -- prefetch horizon H ---------------------------------------------------
    prefetch_horizon_init: int = 4
    prefetch_horizon_max: int = 0  # 0 disables the law (static H applies)
    stall_high: float = 0.05  # stall share of round time: above -> deepen H
    stall_low: float = 1e-3  # at/below this AND fills wasted -> shrink H
    # -- spill ---------------------------------------------------------------
    spill_budget_objects: Optional[int] = None  # legacy object-count budget
    spill_budget_bytes: Optional[float] = None  # byte-accurate §6 budget
    #   (preferred; enables *partial* queue spill — see apply_spill)
    spill_low_water: float = 0.8  # disengage below this fraction
    # Price the *spill* victim walk by each queue's T_spill
    # wait-cost-per-byte (lowest relief-per-byte evicted first), mirroring
    # the unspill-grant pricing.  On by default since the PR 6 golden
    # waiver (see docs/adaptive.md): the goldens of byte-mode scenarios
    # with T_spill > 0 were deliberately re-recorded under the priced
    # walk.  Unpriced walks (no cost model or T_spill == 0) are
    # youngest-first either way; set False to replay pre-waiver traces.
    price_spill_victims: bool = True
    # Legacy unspill: page each spilled queue's whole suffix back in one
    # shot instead of the paged oldest-first protocol.  Wholesale paging
    # is all-or-nothing per queue: a big queue either blocks the walk or
    # lands entirely at once — keep it off unless replaying old traces.
    wholesale_unspill: bool = False


class ControlLoop:
    """The one feedback loop driving alpha, fuse_k, and spill.

    ``observe_arrival`` is O(1) and called on every query/request intake;
    ``update`` is called once per scheduling round by the DispatchLoop and
    returns the ControlVector for that round.
    """

    def __init__(
        self,
        config: ControlConfig = ControlConfig(),
        estimator: Optional[SaturationEstimator] = None,
    ) -> None:
        self.cfg = config
        # ``estimator`` may be shared (TenantControlPlane: one arrival
        # stream feeds every tenant's saturation signal).
        self.estimator = estimator or SaturationEstimator(config.halflife_s)
        self._alpha = min(max(config.alpha_init, config.alpha_min), config.alpha_max)
        self._fuse_k = max(1, int(config.fuse_k_init))
        self._share_width = max(1, int(config.share_width_init))
        self._horizon = max(1, int(config.prefetch_horizon_init))
        self._depth_ewma = 0.0
        self._spilling = False
        self.rounds = 0
        self.last: Optional[ControlVector] = None

    # -- sensors ----------------------------------------------------------------
    def observe_arrival(self, t: float) -> float:
        return self.estimator.observe_arrival(t)

    @property
    def arrival_rate(self) -> float:
        return self.estimator.rate

    # -- the loop ---------------------------------------------------------------
    def update(self, tel: Telemetry) -> ControlVector:
        vec = ControlVector(
            alpha=self._update_alpha(tel),
            fuse_k=self._update_fuse_k(tel),
            spill=self._update_spill(tel),
            horizon=self._update_horizon(tel),
            share_width=self._update_share_width(tel),
        )
        self.last = vec
        self.rounds += 1
        return vec

    # -- alpha law --------------------------------------------------------------
    def _update_alpha(self, tel: Telemetry) -> float:
        cfg = self.cfg
        target = None
        if cfg.table is not None:
            try:
                target = cfg.table.select_alpha(tel.arrival_rate, cfg.tolerance)
            except ValueError:  # empty table -> table-free fallback
                target = None
        if target is None:
            target = self._fallback_target(tel)
        target = min(max(target, cfg.alpha_min), cfg.alpha_max)
        delta = max(-cfg.alpha_step, min(cfg.alpha_step, target - self._alpha))
        self._alpha = min(max(self._alpha + delta, 0.0), 1.0)
        return self._alpha

    def _fallback_target(self, tel: Telemetry) -> float:
        """Table-free EWMA law: saturation in [0,1] from arrival rate and
        backlog depth; idle -> alpha_max (arrival order), saturated ->
        alpha_min (data-driven batch)."""
        cfg = self.cfg
        w = cfg.depth_smoothing
        self._depth_ewma += w * (tel.pending_objects - self._depth_ewma)
        sat = max(
            tel.arrival_rate / cfg.rate_knee if cfg.rate_knee > 0 else 0.0,
            self._depth_ewma / cfg.depth_knee if cfg.depth_knee > 0 else 0.0,
        )
        sat = min(sat, 1.0)
        return cfg.alpha_max - (cfg.alpha_max - cfg.alpha_min) * sat

    # -- fuse_k law -------------------------------------------------------------
    def _update_fuse_k(self, tel: Telemetry) -> int:
        """AIMD on batch occupancy: underfull dispatches with pending breadth
        fuse one more bucket; saturated dispatches back off."""
        cfg = self.cfg
        k = self._fuse_k
        if tel.occupancy < cfg.occ_low and tel.n_queues > k:
            k += 1
        elif tel.occupancy > cfg.occ_high and k > 1:
            k -= 1
        k = max(1, min(k, cfg.fuse_k_max, max(tel.n_queues, 1)))
        self._fuse_k = k
        return k

    # -- share_width law ---------------------------------------------------------
    def _update_share_width(self, tel: Telemetry) -> int:
        """AIMD ceiling on queries per shared-plan device call, bounding
        the pow2 compile shapes the shared kernel can reach.  Polarity is
        the *reverse* of fuse_k's: high shared occupancy means demand
        saturates the current width (the executor is splitting query
        batches into extra chunks) — widen to cut chunk count; low
        occupancy means the last chunk was mostly padding — narrow, so
        compile shapes shrink back.  Disabled (returns 0) unless
        ``share_width_max`` is set, keeping vectors inert for
        configurations without a shared executor."""
        cfg = self.cfg
        if cfg.share_width_max <= 0:
            return 0
        w = self._share_width
        if tel.shared_occupancy > cfg.share_occ_high:
            w += 1
        elif tel.shared_occupancy < cfg.share_occ_low and w > 1:
            w -= 1
        w = max(1, min(w, cfg.share_width_max))
        self._share_width = w
        return w

    # -- prefetch-horizon law -----------------------------------------------------
    def _update_horizon(self, tel: Telemetry) -> int:
        """AIMD-style H sizing, mirroring the fuse_k law: a round that
        stalled on an in-flight stage means the pipeline looked ahead too
        shallowly — deepen the horizon; stall-free rounds that *wasted*
        fills (prefetched buckets evicted untouched) mean it looked too
        far — back off.  Disabled (returns 0) unless
        ``prefetch_horizon_max`` is set, so vectors stay inert for
        configurations without a pipeline."""
        cfg = self.cfg
        if cfg.prefetch_horizon_max <= 0:
            return 0
        h = self._horizon
        if tel.prefetch_stall_frac > cfg.stall_high:
            h += 1
        elif (
            tel.prefetch_stall_frac <= cfg.stall_low
            and tel.prefetch_wasted > 0
            and h > 1
        ):
            h -= 1
        h = max(1, min(h, cfg.prefetch_horizon_max))
        self._horizon = h
        return h

    # -- spill law --------------------------------------------------------------
    def _update_spill(self, tel: Telemetry) -> bool:
        cfg = self.cfg
        if cfg.spill_budget_bytes is not None:
            # Byte-accurate budget (preferred): resident probe bytes vs the
            # §6 memory budget, same hysteresis shape as the legacy law.
            if tel.resident_bytes > cfg.spill_budget_bytes:
                self._spilling = True
            elif tel.pending_bytes <= cfg.spill_budget_bytes * cfg.spill_low_water:
                self._spilling = False
            return self._spilling
        if cfg.spill_budget_objects is None:
            return False
        if tel.resident_objects > cfg.spill_budget_objects:
            self._spilling = True
        elif tel.pending_objects <= cfg.spill_budget_objects * cfg.spill_low_water:
            self._spilling = False
        return self._spilling

    # -- state snapshot -----------------------------------------------------------
    def state(self) -> dict:
        """Plain-data view of the loop's evolving law state (everything a
        future ``update`` depends on besides the telemetry), for the
        durability tier's replayed-state == live-state assertions."""
        return {
            "alpha": self._alpha,
            "fuse_k": self._fuse_k,
            "share_width": self._share_width,
            "horizon": self._horizon,
            "depth_ewma": self._depth_ewma,
            "spilling": self._spilling,
            "rounds": self.rounds,
            "rate": self.estimator.rate,
        }


# --------------------------------------------------------------------------
# Per-tenant admission control (ahead of the spill path)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdmissionQuota:
    """One tenant class's intake limits, checked at submit time — *before*
    work enters the workload manager.  §6 spill absorbs overload that is
    already admitted; admission control is the layer that refuses overload
    at the door (CasJobs-style: a batch service says 429, it does not
    queue unboundedly).  ``None`` disables a dimension."""

    max_queue_depth: Optional[int] = None  # pending objects, both sides
    max_pending_bytes: Optional[float] = None  # pending probe bytes


class AdmissionRejected(Exception):
    """429-style typed rejection raised by ``submit`` when a tenant's
    quota would be exceeded.  Carries enough to journal the decision and
    re-raise it bit-identically on replay."""

    status = 429

    def __init__(
        self, tenant: str, reason: str, observed: float, limit: float
    ) -> None:
        self.tenant = tenant
        self.reason = reason  # "queue_depth" | "pending_bytes"
        self.observed = observed
        self.limit = limit
        super().__init__(
            f"tenant {tenant!r} over {reason} quota: "
            f"{observed!r} + submission > {limit!r}"
        )


class AdmissionController:
    """Per-tenant-class quota check.  ``quotas`` maps tenant -> quota;
    ``default`` applies to unlisted tenants (``None``: unlisted tenants
    are unlimited).  Deterministic: the verdict is a pure function of the
    tenant's current pending state and the submission's size, so a
    journal replay reproduces every rejection exactly."""

    def __init__(
        self,
        quotas: Optional[Mapping[str, AdmissionQuota]] = None,
        default: Optional[AdmissionQuota] = None,
    ) -> None:
        self.quotas = dict(quotas or {})
        self.default = default

    def quota_for(self, tenant: str) -> Optional[AdmissionQuota]:
        return self.quotas.get(tenant, self.default)

    def check(
        self,
        tenant: str,
        pending_objects: int,
        pending_bytes: float,
        add_objects: int = 1,
        add_bytes: float = 0.0,
    ) -> None:
        """Raise :class:`AdmissionRejected` iff admitting a submission of
        ``add_objects``/``add_bytes`` would push the tenant past its
        quota.  Admission counts *total* pending state (resident +
        spilled): spilling must not launder quota headroom."""
        quota = self.quota_for(tenant)
        if quota is None:
            return
        if (
            quota.max_queue_depth is not None
            and pending_objects + add_objects > quota.max_queue_depth
        ):
            raise AdmissionRejected(
                tenant, "queue_depth", float(pending_objects),
                float(quota.max_queue_depth),
            )
        if (
            quota.max_pending_bytes is not None
            and pending_bytes + add_bytes > quota.max_pending_bytes
        ):
            raise AdmissionRejected(
                tenant, "pending_bytes", float(pending_bytes),
                float(quota.max_pending_bytes),
            )


def unspill_price(q, cost, now: Optional[float] = None) -> float:
    """The §6 wait-cost-per-byte of leaving queue ``q`` spilled — the
    arbiter's unspill-grant priority.

    Each service of a spilled queue pays ``T_spill * sigma`` on top of the
    bucket read (Eq. 1), with ``sigma = spilled_bytes / nbytes``; paging
    one byte back in therefore saves ``T_spill / nbytes`` seconds of
    read-back surcharge per future service.  Small queues clear their
    whole surcharge with few bytes, so they page in first — maximum
    surcharge relief per granted byte.

    With ``now`` the price is *deadline-aware*: the base rate is scaled by
    ``1 + age_ms / age_scale_ms``, the same normalization the Eq. 2 age
    term uses, so a spilled queue approaching the §6 starvation bound
    (age ~ ``age_scale_ms``) outbids a cheap young one for the grant —
    and, symmetrically, costs more to evict in the priced victim walk.
    ``now=None`` is the ageless historical price.

    Returns 0.0 (unpriced — walk falls back to oldest-first, which
    already favors the old) without a cost model or with ``T_spill == 0``.
    """
    if cost is None or getattr(cost, "T_spill", 0.0) <= 0.0:
        return 0.0
    base = cost.T_spill / q.nbytes if q.nbytes else 0.0
    if now is None:
        return base
    age_scale = getattr(cost, "age_scale_ms", 0.0)
    if age_scale <= 0.0:
        return base
    age_ms = max(0.0, (now - q.oldest_arrival) * 1e3)
    return base * (1.0 + age_ms / age_scale)


def apply_spill(
    wm,
    vector: ControlVector,
    config: ControlConfig,
    *,
    budget_bytes: Optional[float] = None,
    only: Optional[Callable[[int], bool]] = None,
    cost=None,
    now: Optional[float] = None,
) -> list[int]:
    """Enforce the §6 overflow budget on a workload manager.

    Byte mode (``config.spill_budget_bytes`` set, or ``budget_bytes``
    override from the TenantControlPlane arbiter): the budget is actual
    resident probe bytes.  When ``vector.spill``: walk victim queues
    youngest-first (their requesters have waited least; the age term
    reclaims them later) and spill *exactly* the deficit — whole queues
    while the deficit exceeds them, then a partial ``spill_bucket(b,
    frac)`` on the boundary victim, whose oldest units stay resident.  The
    oldest queue is never fully spilled, so resident work always remains.
    When disengaged: page spilled work back in *paged* — queues ordered
    by their ``T_spill`` wait-cost-per-byte (highest first; see
    ``unspill_price``, fed by ``cost`` — typically the scheduler's
    CostModel — and oldest-first when unpriced), each granted only the
    remaining low-water headroom via ``unspill_bucket(b, budget_bytes=…)``
    so the paged-in bytes can never re-exceed the budget
    (``config.wholesale_unspill`` restores the legacy whole-queue walk).
    ``only`` restricts the walk to one tenant's buckets (per-tenant
    enforcement under the shared loop).  ``now`` (the dispatch clock)
    makes both priced walks deadline-aware — see ``unspill_price``.

    Legacy object mode (``spill_budget_objects``): whole-queue spill on
    the object-count proxy, bit-for-bit the historical behavior.

    Returns the bucket ids whose spill state changed this round.
    """
    if not hasattr(wm, "spill_bucket"):
        return []
    if budget_bytes is not None or config.spill_budget_bytes is not None:
        budget = budget_bytes if budget_bytes is not None else config.spill_budget_bytes
        return _apply_spill_bytes(wm, vector, config, budget, only, cost, now)
    budget = config.spill_budget_objects
    if budget is None:
        return []
    changed: list[int] = []
    nonempty = [
        (q.oldest_arrival, q.bucket_id, q.size)
        for q in wm.nonempty_queues()
        if only is None or only(q.bucket_id)
    ]
    resident = [(t, b, n) for t, b, n in nonempty if not wm.is_spilled(b)]
    resident_total = sum(n for _, _, n in resident)
    if vector.spill:
        # Youngest first == largest oldest_arrival first.
        for t, b, n in sorted(resident, reverse=True):
            if resident_total <= budget or len(resident) - len(changed) <= 1:
                break
            if wm.spill_bucket(b):
                changed.append(b)
                resident_total -= n
    else:
        low = budget * config.spill_low_water
        spilled = sorted(
            (t, b, n) for t, b, n in nonempty if wm.is_spilled(b)
        )  # oldest first
        for t, b, n in spilled:
            if resident_total + n > low:
                break
            if wm.unspill_bucket(b):
                changed.append(b)
                resident_total += n
    return changed


def _apply_spill_bytes(
    wm, vector: ControlVector, config: ControlConfig, budget: float, only,
    cost=None, now: Optional[float] = None,
) -> list[int]:
    """Byte-accurate partial-spill enforcement (see apply_spill)."""
    changed: list[int] = []
    queues = [
        q for q in wm.nonempty_queues() if only is None or only(q.bucket_id)
    ]
    resident_total = sum(q.resident_bytes for q in queues)
    if vector.spill:
        deficit = resident_total - budget
        # Victims youngest-first == largest oldest_arrival first; the
        # oldest queue is walked last and only ever spilled partially.
        victims = sorted(
            (q for q in queues if q.resident_bytes > 0),
            key=lambda q: (q.oldest_arrival, q.bucket_id),
            reverse=True,
        )
        if config.price_spill_victims and victims:
            # Priced walk (mirrors the unspill-grant pricing): evict the
            # queue whose spilled state will cost the *least* future wait
            # per byte freed — lowest T_spill wait-cost-per-byte
            # (== largest nbytes) first, youngest-first on ties, so the
            # unpriced case (no cost model / T_spill == 0) degenerates to
            # the legacy order exactly.  The oldest queue still walks
            # last (and is only ever spilled partially): pricing must not
            # buy throughput with starvation.
            victims.sort(
                key=lambda q: (
                    unspill_price(q, cost, now), -q.oldest_arrival, -q.bucket_id
                )
            )
            oldest = min(victims, key=lambda q: (q.oldest_arrival, q.bucket_id))
            victims.remove(oldest)
            victims.append(oldest)
        for i, q in enumerate(victims):
            if deficit <= 0:
                break
            b = q.bucket_id
            is_last_resident = i == len(victims) - 1
            if q.resident_bytes <= deficit and not is_last_resident:
                frac = 1.0  # whole-queue victim
            else:
                # Boundary victim: spill only the deficit (unit granularity
                # rounds up inside spill_youngest; oldest units stay).
                frac = min(
                    (q.spilled_bytes + deficit) / q.nbytes if q.nbytes else 0.0,
                    1.0 - 1e-12,  # keep_oldest engages even on exact fits
                )
            before = q.resident_bytes
            if wm.spill_bucket(b, frac):
                changed.append(b)
                deficit -= before - q.resident_bytes
    else:
        low = budget * config.spill_low_water
        spilled = [q for q in queues if q.spilled_bytes > 0]
        if config.wholesale_unspill:
            # Legacy whole-queue walk, oldest first: a queue pages back
            # all-or-nothing while its whole suffix fits under low water.
            spilled.sort(key=lambda q: (q.oldest_arrival, q.bucket_id))
            for q in spilled:
                if resident_total + q.spilled_bytes > low:
                    break
                gain = q.spilled_bytes
                if wm.unspill_bucket(q.bucket_id):
                    changed.append(q.bucket_id)
                    resident_total += gain
            return changed
        # Paged unspill: grants priced by T_spill wait-cost-per-byte
        # (highest first; oldest-first tie-break doubles as the whole
        # order when unpriced).  Each queue pages back only the remaining
        # low-water headroom, oldest units first, so no single grant —
        # and no round — can push residency back over the budget.
        spilled.sort(
            key=lambda q: (
                -unspill_price(q, cost, now), q.oldest_arrival, q.bucket_id
            )
        )
        headroom = low - resident_total
        for q in spilled:
            if headroom <= 0.0:
                break
            before = q.resident_bytes
            if wm.unspill_bucket(
                q.bucket_id, budget_bytes=min(q.spilled_bytes, headroom)
            ):
                changed.append(q.bucket_id)
                headroom -= q.resident_bytes - before
    return changed


def waterfill(
    demand: Mapping, weights: Mapping, budget: float
) -> dict:
    """Weighted waterfill of a byte budget over demands — the one arbiter
    both arbitration axes share (tenants within a host, shards across the
    tier).

    Parties demanding less than their weighted share are granted their
    demand; the freed headroom is re-shared (by weight) among the
    still-unsatisfied parties until none remain, and any final slack is
    distributed (by weight) on top of the grants of parties with *nonzero*
    demand, so the grants always sum to *exactly* the budget.  The slack
    matters: it is the headroom that lets a previously spilling party's
    low-water disengage test (``pending <= grant * low_water``) pass once
    global pressure subsides — a grant capped at demand can never satisfy
    it.  Zero-demand parties are excluded from slack (their share is
    re-shared among the demanders): an idle shard/tenant granted phantom
    bytes would carry inflated low-water headroom into its next engaged
    round.  Only when *every* party is zero-demand does the slack fall
    back to all of them, preserving the sum invariant.  Invariants:
    sum(grants) == budget (work-conserving), every grant >= its party's
    satisfied demand.  Missing weights default to 1.0.
    """
    remaining = float(budget)
    # Insertion-ordered list, NOT a set: the float sums below depend on
    # iteration order, and set order over str tenant keys is salted by
    # PYTHONHASHSEED — a recovery replay in a fresh process would derive
    # different grants (det-set-order).  The caller's dict order is
    # deterministic.
    active = list(demand)
    grants: dict = {}
    while active:
        wsum = sum(weights.get(t, 1.0) for t in active)
        if wsum <= 0.0:  # degenerate zero weights: equal shares
            share = {t: remaining / len(active) for t in active}
        else:
            share = {
                t: remaining * weights.get(t, 1.0) / wsum for t in active
            }
        satisfied = [t for t in active if demand[t] <= share[t]]
        if not satisfied:
            grants.update(share)  # everyone over-demands: cap at share
            remaining = 0.0
            break
        for t in satisfied:
            grants[t] = demand[t]
            remaining -= demand[t]
        done = set(satisfied)
        active = [t for t in active if t not in done]
    if remaining > 0.0 and grants:
        takers = [t for t in grants if demand[t] > 0.0] or list(grants)
        wsum = sum(weights.get(t, 1.0) for t in takers)
        for t in takers:
            grants[t] += (
                remaining * weights.get(t, 1.0) / wsum
                if wsum > 0.0
                else remaining / len(takers)
            )
    return grants


# --------------------------------------------------------------------------
# Multi-tenant control plane
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """One tenant class's position on the throughput/response dial.

    ``config`` sets the tenant's own feedback laws (an interactive class
    pins ``alpha_min`` high so it never drifts into deep batching; a batch
    class pins ``alpha_max`` low and tolerates spill).  ``weight`` is the
    tenant's share of the *global* §6 byte budget under contention — the
    arbiter's waterfill unit.
    """

    tenant: str
    config: ControlConfig = ControlConfig()
    weight: float = 1.0


class TenantControlPlane:
    """One ControlLoop per tenant class + the §6 budget arbiter.

    CasJobs runs separate batch and interactive queues; SharedDB shows
    shared-work systems still owe per-class latency isolation.  This plane
    is that idea applied to LifeRaft's control loop: every tenant class
    (interactive vs batch — adapter class in the serving engine, query tag
    in the cross-match engine) runs its *own* alpha / fuse_k / spill laws
    over its own telemetry slice, while one shared ``SaturationEstimator``
    sees the global arrival stream (saturation is a property of the
    machine, not of one tenant).

    The **budget arbiter** reconciles per-tenant spill demands against the
    single global byte budget: tenants whose resident bytes fit their
    waterfilled share keep everything resident; surplus is redistributed
    by weight to over-demand tenants, who spill down to their grant.  The
    grants always sum to at most the global budget, so byte-accounted
    residency never exceeds it once enforcement converges (modulo the
    oldest-unit guards that prevent starvation).  Per-tenant hysteresis
    (each policy's ``spill_low_water``) keeps the spill bit from
    oscillating round to round.

    ``DispatchLoop`` consumes this exactly like a ControlLoop, except
    ``update`` takes one Telemetry per tenant and returns one
    ControlVector per tenant.
    """

    def __init__(
        self,
        policies: Sequence[TenantPolicy],
        global_budget_bytes: Optional[float] = None,
        halflife_s: float = 30.0,
    ) -> None:
        if not policies:
            raise ValueError("TenantControlPlane needs at least one policy")
        names = [p.tenant for p in policies]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant policies: {names}")
        self.policies: dict[str, TenantPolicy] = {p.tenant: p for p in policies}
        self.estimator = SaturationEstimator(halflife_s)
        self.loops: dict[str, ControlLoop] = {
            p.tenant: ControlLoop(p.config, estimator=self.estimator)
            for p in policies
        }
        self.global_budget_bytes = global_budget_bytes
        self.granted_bytes: dict[str, float] = {}
        self._engaged: dict[str, bool] = {t: False for t in self.policies}
        self.rounds = 0
        self.last: dict[str, ControlVector] = {}

    # -- sensors ----------------------------------------------------------------
    def observe_arrival(self, t: float) -> float:
        """All tenants' arrivals feed the one shared saturation signal."""
        return self.estimator.observe_arrival(t)

    @property
    def arrival_rate(self) -> float:
        return self.estimator.rate

    def tenants(self) -> list[str]:
        return list(self.policies)

    # -- the loop ---------------------------------------------------------------
    def register_tenant(self, tenant: str, policy: Optional[TenantPolicy] = None) -> None:
        """Add a tenant class at run time.  ``update`` calls this lazily
        for telemetry of unknown classes (default policy, weight 1.0) so
        that *every* observed tenant counts against the global byte budget
        and is spill-enforceable — an untagged class must not be able to
        grow resident state outside the arbiter's books."""
        if tenant in self.policies:
            return
        policy = policy or TenantPolicy(tenant)
        self.policies[tenant] = policy
        self.loops[tenant] = ControlLoop(policy.config, estimator=self.estimator)
        self._engaged[tenant] = False

    def update(self, tels: Mapping[str, Telemetry]) -> dict[str, ControlVector]:
        """One scheduling round: run every tenant's feedback laws on its
        telemetry slice, then arbitrate spill against the global budget."""
        for t in tels:
            self.register_tenant(t)  # unknown classes join the books
        vecs: dict[str, ControlVector] = {}
        for tenant, loop in self.loops.items():
            tel = tels.get(tenant)
            if tel is None:  # idle tenant: empty slice, laws still step
                tel = Telemetry(0.0, self.arrival_rate, 0, 0, 0, 0.0, 0.0, 0.0)
            vecs[tenant] = loop.update(tel)
        if self.global_budget_bytes is not None:
            resident = {
                t: (tels[t].resident_bytes if t in tels else 0.0)
                for t in self.policies
            }
            pending = {
                t: (tels[t].pending_bytes if t in tels else 0.0)
                for t in self.policies
            }
            # Demand is *pending* bytes — what the tenant needs to hold
            # everything resident.  (Using resident bytes here makes the
            # grant chase post-spill residency, so the low-water disengage
            # test `pending <= grant*lw` could never pass and spilled work
            # would stay on host until fully drained by service.)
            self.granted_bytes = self._waterfill(pending)
            for t, vec in vecs.items():
                grant = self.granted_bytes[t]
                low = grant * self.policies[t].config.spill_low_water
                if resident[t] > grant:
                    self._engaged[t] = True
                elif pending[t] <= low:
                    self._engaged[t] = False
                vecs[t] = dataclasses.replace(vec, spill=self._engaged[t])
        self.rounds += 1
        self.last = vecs
        return vecs

    # -- the arbiter -------------------------------------------------------------
    def _waterfill(self, demand: Mapping[str, float]) -> dict[str, float]:
        """Weighted waterfill of the global byte budget over tenant
        demands — the module-level :func:`waterfill` with this plane's
        policy weights (the same arbiter ``ShardControlPlane`` runs over
        shards)."""
        return waterfill(
            demand,
            {t: p.weight for t, p in self.policies.items()},
            float(self.global_budget_bytes or 0.0),
        )


# --------------------------------------------------------------------------
# Cross-shard control tier
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardGrant:
    """One shard's per-round byte grants from the global tier.

    ``spill_bytes`` overrides the shard-local §6 budget for this round
    (None: no global spill budget — the shard's own config governs);
    ``engaged`` is the tier's hysteresis bit for the shard (the local
    spill law is bypassed exactly as the tenant plane bypasses the
    per-loop law).  ``prefetch_bytes`` caps the bytes the shard's
    prefetch pipeline may commit to its staging channel this round
    (None: uncapped).
    """

    spill_bytes: Optional[float] = None
    engaged: bool = False
    prefetch_bytes: Optional[float] = None


class ShardControlPlane:
    """The global control tier over shard-local dispatch loops.

    Shards are an *outer* arbitration axis: exactly as the
    ``TenantControlPlane`` waterfills the §6 byte budget across tenant
    classes within one loop, this plane waterfills the global spill and
    prefetch byte budgets across shards, from per-shard ``Telemetry``
    slices.  Demand on both axes is the shard's *pending* probe bytes —
    what it needs to hold everything resident, and the best available
    proxy for how much staging its queues can absorb (a shard with no
    pending work needs neither residency nor lookahead).  Per-shard
    hysteresis mirrors the tenant plane's: residency above the grant
    engages spill; pending at or below the grant's low-water mark
    disengages it.

    The shard tier (``core/shard.py``) consumes grants by overriding each
    shard loop's spill budget/engagement for the round and capping its
    pipeline's staging bytes; with both budgets ``None`` the plane is
    inert and every shard runs its local laws untouched.
    """

    def __init__(
        self,
        n_shards: int,
        spill_budget_bytes: Optional[float] = None,
        prefetch_budget_bytes: Optional[float] = None,
        weights: Optional[Mapping[int, float]] = None,
        spill_low_water: float = 0.8,
    ) -> None:
        if n_shards < 1:
            raise ValueError(f"need at least one shard, got {n_shards}")
        self.n_shards = int(n_shards)
        self.spill_budget_bytes = spill_budget_bytes
        self.prefetch_budget_bytes = prefetch_budget_bytes
        self.weights = {
            s: (weights.get(s, 1.0) if weights else 1.0)
            for s in range(self.n_shards)
        }
        self.spill_low_water = float(spill_low_water)
        self._engaged: dict[int, bool] = {s: False for s in self.weights}
        self.granted_spill: dict[int, float] = {}
        self.granted_prefetch: dict[int, float] = {}
        self.rounds = 0
        self.last: dict[int, ShardGrant] = {}

    def update(self, tels: Mapping[int, Telemetry]) -> dict[int, ShardGrant]:
        """One global round: waterfill both budgets over the shards'
        telemetry slices and return a grant per shard."""
        pending = {
            s: (tels[s].pending_bytes if s in tels else 0.0)
            for s in self.weights
        }
        resident = {
            s: (tels[s].resident_bytes if s in tels else 0.0)
            for s in self.weights
        }
        grants: dict[int, ShardGrant] = {}
        if self.spill_budget_bytes is not None:
            self.granted_spill = waterfill(
                pending, self.weights, self.spill_budget_bytes
            )
        if self.prefetch_budget_bytes is not None:
            self.granted_prefetch = waterfill(
                pending, self.weights, self.prefetch_budget_bytes
            )
        for s in self.weights:
            spill_grant = (
                self.granted_spill.get(s, 0.0)
                if self.spill_budget_bytes is not None
                else None
            )
            if spill_grant is not None:
                if resident[s] > spill_grant:
                    self._engaged[s] = True
                elif pending[s] <= spill_grant * self.spill_low_water:
                    self._engaged[s] = False
            grants[s] = ShardGrant(
                spill_bytes=spill_grant,
                engaged=self._engaged[s],
                prefetch_bytes=(
                    self.granted_prefetch.get(s, 0.0)
                    if self.prefetch_budget_bytes is not None
                    else None
                ),
            )
        self.rounds += 1
        self.last = grants
        return grants

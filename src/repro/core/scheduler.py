"""Schedulers: LifeRaft (aged workload throughput), RR, NoShare (paper §5).

A scheduler's single decision is *which bucket to service next* given the
current workload queues, cache residency, and clock.  Batching (servicing a
bucket evaluates every pending work unit on it in one pass) is handled by
the caller — NoShare is the exception and is modeled by the simulator as
per-query evaluation in arrival order.

Two LifeRaft implementations share one contract:

* ``NaiveLifeRaftScheduler`` — the oracle: rescores every nonempty queue on
  every ``select()`` with ``aged_workload_throughput`` (O(B) per decision).
* ``LifeRaftScheduler`` — incremental: exploits the identity

      U_a(i) = U_t(i)*(1-alpha) + (now - oldest_i)*1e3*alpha
             = [U_t(i)*(1-alpha) - oldest_i*1e3*alpha] + now*1e3*alpha

  The bracketed *rebased priority* S(i) is independent of ``now`` and the
  trailing term is constant across candidates, so argmax_i U_a == argmax_i S
  and S only changes when a bucket's queue or residency changes.  A lazy
  max-heap over S, fed by change notifications from the WorkloadManager and
  BucketCache, makes a decision O(dirty * log B) instead of O(B).  To stay
  decision-identical to the oracle under floating point, the top of the heap
  is widened to a tolerance window and the finalists are re-ranked with the
  oracle's own arithmetic.

``normalized=True`` scoring rescales each term by a workload-independent
constant (U_t by 1/T_m, age by ``cost.age_scale_ms`` — see metrics.py), so
the same rebasing applies with scaled coefficients:

      S_n(i) = U_t(i)*T_m*(1-alpha) - oldest_i*1e3*(1/age_scale_ms)*alpha

and the incremental heap path covers the serving engine's default config
too (the historical O(B) fallback existed only because normalization used
to couple scores through candidate-set maxima).

Per-tenant alphas (``set_tenant_alphas``; the multi-tenant control plane)
break the rebase's one assumption: the dropped trailing term
``now*1e3*alpha`` is only candidate-constant when alpha is.  The index
therefore keeps ONE lazy max-heap per *tenant group* (buckets sharing an
alpha): within a group the rebase argument holds verbatim, and the
cross-group argmax compares the handful of group tops after adding each
group's own ``now``-correction — O(dirty·logB + T) per decision with T
tenant classes.  Scalar alpha is the one-group special case, running the
exact same code path as before.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Mapping, Optional, Protocol

from .cache import BucketCache
from .metrics import CostModel, aged_workload_throughput, workload_throughput
from .workload import WorkloadManager

__all__ = [
    "SchedulerDecision",
    "BucketScheduler",
    "LifeRaftScheduler",
    "NaiveLifeRaftScheduler",
    "RoundRobinScheduler",
    "OrderedScheduler",
]


@dataclasses.dataclass(frozen=True)
class SchedulerDecision:
    bucket_id: int
    score: float
    in_cache: bool
    queue_size: int  # total pending objects (|W_i|, resident + spilled)
    resident_size: Optional[int] = None  # §6 resident prefix (None: untracked)


class BucketScheduler(Protocol):
    def select(
        self, wm: WorkloadManager, cache: BucketCache, now: float
    ) -> Optional[SchedulerDecision]: ...


@dataclasses.dataclass
class _Entry:
    """Per-bucket incremental state (inputs to Eq. 1/2 + the rebased key)."""

    version: int
    key: float  # S(i) = ut*(1-alpha_i) - oldest_ms*alpha_i (scaled if norm.)
    ut: float
    oldest: float
    size: int  # total pending objects (resident + spilled)
    cached: bool
    sigma: float = 0.0  # §6 spilled byte fraction in [0, 1]
    resident: int = 0  # resident-prefix objects (== size unless spilled)
    group: str = ""  # tenant group whose heap holds the live key


class LifeRaftScheduler:
    """Greedy-by-U_a bucket selection (Eq. 2). alpha=0 greedy, alpha=1 aged.

    Incremental by default: subscribes to the WorkloadManager's queue
    changes and the BucketCache's residency changes, maintaining a lazy
    max-heap over the rebased priority (``normalized=True`` uses the same
    machinery with rescaled coefficients).  Falls back to the full rescan
    only when the workload/cache objects do not support ``subscribe``.

    External mutation of queue internals that bypasses
    ``WorkloadManager.submit/complete_bucket`` is invisible to the
    incremental index — call :meth:`rebuild` (or ``mark_dirty(bucket)``)
    after such surgery.
    """

    name = "liferaft"

    def __init__(
        self,
        cost_model: CostModel,
        alpha: float = 0.0,
        normalized: bool = False,
    ) -> None:
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0,1], got {alpha}")
        self.cost_model = cost_model
        self._alpha = float(alpha)
        self.normalized = normalized
        # -- per-tenant alpha (multi-tenant control plane) --------------------
        self._tenant_alphas: Optional[dict[str, float]] = None
        self._tenant_of: Optional[Callable[[int], str]] = None
        # -- incremental state ------------------------------------------------
        self._wm: Optional[WorkloadManager] = None
        self._cache: Optional[BucketCache] = None
        self._entries: dict[int, _Entry] = {}
        # One lazy max-heap of (-key, bucket, version) per tenant group
        # ("" = the scalar-alpha group; per-tenant groups only exist while
        # tenant alphas are set).
        self._heaps: dict[str, list[tuple[float, int, int]]] = {}
        self._dirty: set[int] = set()
        self._version = 0
        self._alpha_dirty = False

    # -- alpha is hot-swappable (adaptive controller) -------------------------
    @property
    def alpha(self) -> float:
        return self._alpha

    @alpha.setter
    def alpha(self, value: float) -> None:
        value = float(value)
        if not 0.0 <= value <= 1.0:
            raise ValueError(f"alpha must be in [0,1], got {value}")
        if value != self._alpha:
            self._alpha = value
            # Every rebased key embeds alpha; defer to a bulk O(B) re-key
            # (the stored ut/oldest inputs are alpha-independent).
            self._alpha_dirty = True

    # -- per-tenant alpha (hot-swappable, like the scalar) ----------------------
    def set_tenant_alphas(
        self,
        alphas: Optional[Mapping[str, float]],
        tenant_of: Optional[Callable[[int], str]] = None,
    ) -> None:
        """Per-tenant Eq. 2 blends: bucket b scores with
        ``alphas[tenant_of(b)]`` (scalar ``.alpha`` for unmapped tenants).
        ``tenant_of`` must be a pure function of workload state that only
        changes when the bucket's queue changes (which notifies the
        incremental index); the WorkloadManager's ``tenant_of_bucket`` —
        tenant of the oldest pending unit — satisfies this.  Passing
        ``None`` reverts to the scalar blend.  Changes trigger the bulk
        O(B) re-key, exactly like scalar alpha hot-swaps."""
        alphas = dict(alphas) if alphas is not None else None
        if alphas is not None:
            for t, a in alphas.items():
                if not 0.0 <= a <= 1.0:
                    raise ValueError(f"alpha[{t!r}] must be in [0,1], got {a}")
            if tenant_of is None:
                raise ValueError("tenant alphas require a tenant_of mapping")
        if alphas != self._tenant_alphas or tenant_of is not self._tenant_of:
            self._tenant_alphas = alphas
            self._tenant_of = tenant_of if alphas is not None else None
            self._alpha_dirty = True

    def _alpha_for(self, bucket_id: int) -> float:
        if self._tenant_alphas is not None and self._tenant_of is not None:
            return self._tenant_alphas.get(
                self._tenant_of(bucket_id), self._alpha
            )
        return self._alpha

    def _group_of(self, bucket_id: int) -> str:
        """Heap-group key: buckets sharing an alpha share a heap (the
        rebased-key comparison is only valid within one alpha)."""
        if self._tenant_alphas is not None and self._tenant_of is not None:
            t = self._tenant_of(bucket_id)
            if t in self._tenant_alphas:
                return t
        return ""

    def _group_alpha(self, group: str) -> float:
        if group and self._tenant_alphas is not None:
            return self._tenant_alphas[group]
        return self._alpha

    def heap_size(self) -> int:
        """Total live+stale heap entries across tenant groups (the
        compaction bound's subject)."""
        return sum(len(h) for h in self._heaps.values())

    # -- public maintenance hooks ---------------------------------------------
    def mark_dirty(self, bucket_id: int) -> None:
        self._dirty.add(bucket_id)

    def forget(self, bucket_id: int) -> None:
        """Drop a bucket from the incremental index *now* (shard work
        stealing: the bucket's queue left this manager wholesale via
        ``migrate_out``).  The queue-change notification already marks it
        dirty; this releases the live entry eagerly so a steal decision
        taken before the next flush cannot see the departed bucket."""
        self._entries.pop(bucket_id, None)
        self._dirty.add(bucket_id)

    def rebuild(self) -> None:
        """Drop the incremental index; it re-seeds on the next select()."""
        self._unbind()
        self._entries.clear()
        self._heaps.clear()
        self._dirty.clear()
        self._alpha_dirty = False

    # -- selection -------------------------------------------------------------
    def select(
        self, wm: WorkloadManager, cache: BucketCache, now: float
    ) -> Optional[SchedulerDecision]:
        if self._use_naive(wm, cache):
            return _naive_select(self, wm, cache, now)
        self._bind(wm, cache)
        self._flush_dirty()
        return self._select_one(now)

    def select_topk(
        self, wm: WorkloadManager, cache: BucketCache, now: float, k: int
    ) -> list[SchedulerDecision]:
        """Top-k distinct buckets by U_a, best first (fused multi-bucket
        execution services all k in one grouped device call)."""
        if k <= 1:
            d = self.select(wm, cache, now)
            return [] if d is None else [d]
        if self._use_naive(wm, cache):
            return _naive_topk(self, wm, cache, now, k)
        self._bind(wm, cache)
        self._flush_dirty()
        out: list[SchedulerDecision] = []
        suspended: list[int] = []
        for _ in range(k):
            d = self._select_one(now)
            if d is None:
                break
            out.append(d)
            # Invalidate the winner so the next pop yields the runner-up.
            self._entries.pop(d.bucket_id, None)
            suspended.append(d.bucket_id)
        self._dirty.update(suspended)  # restore on the next flush
        return out

    def peek_topk(
        self, wm: WorkloadManager, cache: BucketCache, now: float, k: int
    ) -> list[SchedulerDecision]:
        """Non-mutating preview of the next k distinct buckets by U_a,
        best first — the scan planner's lookahead.  Unlike
        :meth:`select_topk` it never suspends winners or touches heap
        entries beyond ordinary dirty-flush maintenance (which ``select``
        would perform identically), so peeking cannot move a decision.
        O(B) over the live entries: planning-rate work, not the select
        hot path, and ranked with the oracle's exact arithmetic so the
        incremental and naive schedulers commit identical horizons."""
        if k <= 0:
            return []
        if self._use_naive(wm, cache):
            return _naive_topk(self, wm, cache, now, k)
        self._bind(wm, cache)
        self._flush_dirty()
        uts, ags = self._key_coeffs()

        def scored():
            for b, e in self._entries.items():
                a = self._group_alpha(e.group)
                age = (now - e.oldest) * 1e3
                yield ((e.ut * uts) * (1.0 - a) + (age * ags) * a, -b, b, e)

        return [
            SchedulerDecision(
                bucket_id=b, score=ua, in_cache=e.cached, queue_size=e.size,
                resident_size=e.resident,
            )
            for ua, _, b, e in heapq.nlargest(k, scored())
        ]

    # -- incremental machinery --------------------------------------------------
    def _use_naive(self, wm, cache) -> bool:
        return not hasattr(wm, "subscribe") or not hasattr(cache, "subscribe")

    def _key_coeffs(self) -> tuple[float, float]:
        """(ut_scale, age_scale) multiplying U_t and age_ms in Eq. 2.

        ``normalized=True`` rescales by the fixed constants from metrics.py;
        both are 1.0 on the paper's raw scales.  The multiplications below
        mirror ``aged_workload_throughput`` term for term so the finalist
        re-rank stays bit-identical to the oracle."""
        if self.normalized:
            return self.cost_model.T_m, 1.0 / self.cost_model.age_scale_ms
        return 1.0, 1.0

    def _unbind(self) -> None:
        for src in (self._wm, self._cache):
            if src is not None and hasattr(src, "unsubscribe"):
                src.unsubscribe(self._on_change)
        self._wm = None
        self._cache = None

    def _bind(self, wm: WorkloadManager, cache: BucketCache) -> None:
        if self._wm is wm and self._cache is cache:
            return
        self._unbind()
        self._entries.clear()
        self._heaps.clear()
        self._dirty.clear()
        self._wm = wm
        self._cache = cache
        wm.subscribe(self._on_change)
        cache.subscribe(self._on_change)
        for q in wm.nonempty_queues():
            self._dirty.add(q.bucket_id)

    def _on_change(self, bucket_id: int) -> None:
        self._dirty.add(bucket_id)

    def _flush_dirty(self) -> None:
        uts, ags = self._key_coeffs()
        if self._alpha_dirty:
            # Bulk re-key: ut/oldest are alpha-independent, so this needs no
            # wm/cache reads — O(B) rebuild instead of B dirty heappushes.
            # (Per-tenant alphas re-key here too: tenant_of(b) only shifts
            # when b's queue changes, which marks b dirty below.)
            self._alpha_dirty = False
            self._heaps = {}
            for b, e in self._entries.items():
                group = self._group_of(b)
                alpha = self._group_alpha(group)
                self._version += 1
                e.version = self._version
                e.group = group
                e.key = e.ut * uts * (1.0 - alpha) - e.oldest * 1e3 * ags * alpha
                self._heaps.setdefault(group, []).append(
                    (-e.key, b, e.version)
                )
            for heap in self._heaps.values():
                heapq.heapify(heap)
        if not self._dirty:
            return
        wm, cache = self._wm, self._cache
        sigma_of = getattr(wm, "spilled_fraction", None)
        is_spilled = getattr(wm, "is_spilled", None)
        for b in self._dirty:
            q = wm.queues.get(b)
            if q is None or not q:
                self._entries.pop(b, None)  # heap entries go stale
                continue
            size = q.size
            cached = bool(cache.contains(b))
            if sigma_of is not None:
                sigma = float(sigma_of(b))
            elif is_spilled is not None:
                sigma = float(bool(is_spilled(b)))
            else:
                sigma = 0.0
            ut = workload_throughput(size, cached, self.cost_model, sigma)
            oldest = q.oldest_arrival
            group = self._group_of(b)
            alpha = self._group_alpha(group)
            key = ut * uts * (1.0 - alpha) - oldest * 1e3 * ags * alpha
            self._version += 1
            self._entries[b] = _Entry(
                self._version, key, ut, oldest, size, cached, sigma,
                getattr(q, "resident_size", size), group,
            )
            heapq.heappush(
                self._heaps.setdefault(group, []), (-key, b, self._version)
            )
        self._dirty.clear()
        if self.heap_size() > 4 * max(len(self._entries), 8):
            self._compact()

    def _compact(self) -> None:
        self._heaps = {}
        for b, e in self._entries.items():
            self._heaps.setdefault(e.group, []).append((-e.key, b, e.version))
        for heap in self._heaps.values():
            heapq.heapify(heap)

    def _pop_stale(self, group: str) -> None:
        heap = self._heaps.get(group, [])
        while heap:
            _, b, ver = heap[0]
            e = self._entries.get(b)
            if e is None or e.version != ver:
                heapq.heappop(heap)
            else:
                return

    def _select_one(self, now: float) -> Optional[SchedulerDecision]:
        groups = []
        for g in self._heaps:
            self._pop_stale(g)
            if self._heaps[g]:
                groups.append(g)
        if not groups:
            return None
        uts, ags = self._key_coeffs()
        # The rebased key S drops the trailing now*1e3*alpha term, which is
        # only constant *within* a group (one alpha); cross-group
        # comparison adds each group's correction back.  One group ==
        # scalar alpha == the historical single-heap path.
        corr = {
            g: (now * 1e3) * ags * self._group_alpha(g) for g in groups
        }
        best_est = max(-self._heaps[g][0][0] + corr[g] for g in groups)
        finalists: list[tuple[int, _Entry]] = []
        for g in groups:
            heap = self._heaps[g]
            alpha_g = self._group_alpha(g)
            s_max_g = -heap[0][0]
            # Widen to a tolerance window: the rebased key and the oracle's
            # U_a formula round differently, so any bucket within a few-ulp
            # band of the top could be the oracle argmax.  1e-9 relative is
            # ~4000x the double-precision rounding error of either formula.
            tol = 1e-9 * (abs(s_max_g) + abs(now) * 1e3 * ags * alpha_g + 1.0)
            popped: list[tuple[float, int, int]] = []
            while heap:
                negk, b, ver = heap[0]
                e = self._entries.get(b)
                if e is None or e.version != ver:
                    heapq.heappop(heap)
                    continue
                if -negk + corr[g] < best_est - tol:
                    break
                heapq.heappop(heap)
                popped.append((negk, b, ver))
                finalists.append((b, e))
            for item in popped:
                heapq.heappush(heap, item)
        # Re-rank finalists with the oracle's exact arithmetic + tie-break
        # (same multiply order as aged_workload_throughput; uts/ags are 1.0
        # on the raw scales, where x * 1.0 is an IEEE identity; the group
        # alpha IS the oracle's per-bucket alpha).
        def ua(be):
            b, e = be
            a = self._group_alpha(e.group)
            age = (now - e.oldest) * 1e3
            return ((e.ut * uts) * (1.0 - a) + (age * ags) * a, -b)

        b, e = max(finalists, key=ua)
        return SchedulerDecision(
            bucket_id=b,
            score=ua((b, e))[0],
            in_cache=e.cached,
            queue_size=e.size,
            resident_size=e.resident,
        )


class NaiveLifeRaftScheduler(LifeRaftScheduler):
    """The O(B)-per-decision oracle: full rescore on every select().

    Kept as the reference implementation the incremental scheduler is
    property-tested against, and as the baseline in BENCH_scheduler."""

    name = "liferaft-naive"

    def select(self, wm, cache, now):
        return _naive_select(self, wm, cache, now)

    def select_topk(self, wm, cache, now, k):
        if k <= 1:
            d = self.select(wm, cache, now)
            return [] if d is None else [d]
        return _naive_topk(self, wm, cache, now, k)

    def peek_topk(self, wm, cache, now, k):
        return _naive_topk(self, wm, cache, now, k) if k > 0 else []


def _naive_scores(sched, wm, cache, now):
    queues = wm.nonempty_queues()
    if not queues:
        return None
    sizes = {q.bucket_id: q.size for q in queues}
    resident = {
        q.bucket_id: getattr(q, "resident_size", q.size) for q in queues
    }
    cached = {q.bucket_id: cache.contains(q.bucket_id) for q in queues}
    sigma_of = getattr(wm, "spilled_fraction", None)
    is_spilled = getattr(wm, "is_spilled", None)
    if sigma_of is not None:
        spilled = {b: float(sigma_of(b)) for b in sizes}
    elif is_spilled is not None:
        spilled = {b: float(bool(is_spilled(b))) for b in sizes}
    else:
        spilled = None
    alpha_map = (
        {b: sched._alpha_for(b) for b in sizes}
        if sched._tenant_alphas is not None
        else None
    )
    ages = wm.ages_ms(now)
    ua = aged_workload_throughput(
        sizes, ages, cached, sched.cost_model, sched.alpha, sched.normalized,
        spilled, alpha_map,
    )
    return sizes, resident, cached, ua


def _naive_select(sched, wm, cache, now) -> Optional[SchedulerDecision]:
    scored = _naive_scores(sched, wm, cache, now)
    if scored is None:
        return None
    sizes, resident, cached, ua = scored
    # Deterministic tie-break on bucket id for reproducibility.
    best = max(ua, key=lambda b: (ua[b], -b))
    return SchedulerDecision(
        bucket_id=best,
        score=ua[best],
        in_cache=cached[best],
        queue_size=sizes[best],
        resident_size=resident[best],
    )


def _naive_topk(sched, wm, cache, now, k) -> list[SchedulerDecision]:
    scored = _naive_scores(sched, wm, cache, now)
    if scored is None:
        return []
    sizes, resident, cached, ua = scored
    order = sorted(ua, key=lambda b: (ua[b], -b), reverse=True)
    return [
        SchedulerDecision(
            bucket_id=b, score=ua[b], in_cache=cached[b], queue_size=sizes[b],
            resident_size=resident[b],
        )
        for b in order[:k]
    ]


class RoundRobinScheduler:
    """The paper's RR baseline: service buckets in increasing SFC/HTM id
    order, cycling; oblivious to queue length and age."""

    name = "rr"

    def __init__(self, cost_model: CostModel) -> None:
        self.cost_model = cost_model
        self._cursor = -1

    def select(
        self, wm: WorkloadManager, cache: BucketCache, now: float
    ) -> Optional[SchedulerDecision]:
        queues = sorted(q.bucket_id for q in wm.nonempty_queues())
        if not queues:
            return None
        nxt = next((b for b in queues if b > self._cursor), queues[0])
        self._cursor = nxt
        q = wm.queue(nxt)
        return SchedulerDecision(
            bucket_id=nxt,
            score=0.0,
            in_cache=cache.contains(nxt),
            queue_size=q.size,
        )

    def select_topk(self, wm, cache, now, k):
        decisions = []
        seen = set()
        for _ in range(max(k, 1)):
            d = self.select(wm, cache, now)
            if d is None or d.bucket_id in seen:
                break
            seen.add(d.bucket_id)
            decisions.append(d)
        return decisions


class OrderedScheduler:
    """Pure arrival-order bucket selection == LifeRaft(alpha=1).

    Kept as an explicit class for readability in benchmarks; batching/I-O
    sharing still applies (paper: 'even when evaluating queries in order,
    the system benefits from data sharing')."""

    name = "ordered"

    def __init__(self, cost_model: CostModel) -> None:
        self._inner = LifeRaftScheduler(cost_model, alpha=1.0)

    def select(self, wm, cache, now):
        return self._inner.select(wm, cache, now)

"""Schedulers: LifeRaft (aged workload throughput), RR, NoShare (paper §5).

A scheduler's single decision is *which bucket to service next* given the
current workload queues, cache residency, and clock.  Batching (servicing a
bucket evaluates every pending work unit on it in one pass) is handled by
the caller — NoShare is the exception and is modeled by the simulator as
per-query evaluation in arrival order.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

from .cache import BucketCache
from .metrics import CostModel, aged_workload_throughput
from .workload import WorkloadManager

__all__ = [
    "SchedulerDecision",
    "BucketScheduler",
    "LifeRaftScheduler",
    "RoundRobinScheduler",
    "OrderedScheduler",
]


@dataclasses.dataclass(frozen=True)
class SchedulerDecision:
    bucket_id: int
    score: float
    in_cache: bool
    queue_size: int


class BucketScheduler(Protocol):
    def select(
        self, wm: WorkloadManager, cache: BucketCache, now: float
    ) -> Optional[SchedulerDecision]: ...


class LifeRaftScheduler:
    """Greedy-by-U_a bucket selection (Eq. 2). alpha=0 greedy, alpha=1 aged."""

    name = "liferaft"

    def __init__(
        self,
        cost_model: CostModel,
        alpha: float = 0.0,
        normalized: bool = False,
    ) -> None:
        self.cost_model = cost_model
        self.alpha = float(alpha)
        self.normalized = normalized

    def select(
        self, wm: WorkloadManager, cache: BucketCache, now: float
    ) -> Optional[SchedulerDecision]:
        queues = wm.nonempty_queues()
        if not queues:
            return None
        sizes = {q.bucket_id: q.size for q in queues}
        cached = {q.bucket_id: cache.contains(q.bucket_id) for q in queues}
        ages = wm.ages_ms(now)
        ua = aged_workload_throughput(
            sizes, ages, cached, self.cost_model, self.alpha, self.normalized
        )
        # Deterministic tie-break on bucket id for reproducibility.
        best = max(ua, key=lambda b: (ua[b], -b))
        return SchedulerDecision(
            bucket_id=best,
            score=ua[best],
            in_cache=cached[best],
            queue_size=sizes[best],
        )


class RoundRobinScheduler:
    """The paper's RR baseline: service buckets in increasing SFC/HTM id
    order, cycling; oblivious to queue length and age."""

    name = "rr"

    def __init__(self, cost_model: CostModel) -> None:
        self.cost_model = cost_model
        self._cursor = -1

    def select(
        self, wm: WorkloadManager, cache: BucketCache, now: float
    ) -> Optional[SchedulerDecision]:
        queues = sorted(q.bucket_id for q in wm.nonempty_queues())
        if not queues:
            return None
        nxt = next((b for b in queues if b > self._cursor), queues[0])
        self._cursor = nxt
        q = wm.queue(nxt)
        return SchedulerDecision(
            bucket_id=nxt,
            score=0.0,
            in_cache=cache.contains(nxt),
            queue_size=q.size,
        )


class OrderedScheduler:
    """Pure arrival-order bucket selection == LifeRaft(alpha=1).

    Kept as an explicit class for readability in benchmarks; batching/I-O
    sharing still applies (paper: 'even when evaluating queries in order,
    the system benefits from data sharing')."""

    name = "ordered"

    def __init__(self, cost_model: CostModel) -> None:
        self._inner = LifeRaftScheduler(cost_model, alpha=1.0)

    def select(self, wm, cache, now):
        return self._inner.select(wm, cache, now)

"""Scan-horizon planning: commit a data-driven bucket order ahead of time.

LifeRaft's throughput win comes from executing queries "against an
ordering of the data that maximizes data sharing", and §6 frames the
scheduler as the disk-head-scheduling analogue of incremental batch
processing.  The reactive pieces already exist — the lazy-heap scheduler
picks argmax U_a every round — but a purely reactive system discovers
each bucket's I/O need only at the moment it dispatches, so every cache
miss is paid inline.  SharedDB-style shared-scan systems win precisely by
*committing* to a scan plan and streaming data past the batched queries;
CasJobs stages data before the batch window opens.

``ScanPlanner`` is that commitment: it peeks the scheduler's lazy heap
(:meth:`LifeRaftScheduler.peek_topk`, non-mutating) for the next ``H``
buckets the scheduler is about to want, and reorders *that set* into an
elevator sweep over the data layout — ascending layout positions from the
current head, then the stragglers on the way back — exactly how a disk
head (or a sequential bucket file, or an HBM DMA engine walking adapter
slabs) prefers its requests.  The horizon is therefore always a
permutation of the heap's own top-H ("prefix-consistent": no bucket is
invented, none of the top-H is dropped); only the *staging order* within
the horizon is layout-driven.  Dispatch order is untouched — the
scheduler still argmaxes U_a round by round, so decision traces (and the
incremental-vs-oracle bit-identity story) are unaffected by planning.

Horizons are recommitted every round, and arrivals or an alpha hot-swap
can reshuffle priorities so the new horizon drops buckets the old one
promised ("invalidation").  Unchecked, an unlucky bucket could be
promised and dropped forever — staged never, serviced late.  The planner
is starvation-safe: each commit that leaves a candidate bucket behind the
front bumps its deferral count, and once the *oldest pending* bucket has
been deferred ``starvation_deferrals`` times it is forced to the horizon
front regardless of the sweep, so its I/O stages next.  (Service resets
the count.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

__all__ = ["ScanPlanConfig", "ScanPlanner"]


@dataclasses.dataclass(frozen=True)
class ScanPlanConfig:
    """Scan-horizon planning knobs.

    ``horizon`` is the default lookahead H (the ControlLoop's AIMD law
    may override it per round — see ``ControlConfig.prefetch_horizon_*``).
    ``layout_of`` maps a bucket id to its position in the physical data
    layout (the elevator's track number); bucket ids are SFC-ordered by
    construction (§3.1), so identity is the right default for both
    engines.  ``starvation_deferrals`` bounds how many consecutive
    commits may leave the oldest pending bucket behind the front before
    it is forced there.
    """

    horizon: int = 4
    starvation_deferrals: int = 3
    layout_of: Optional[Callable[[int], float]] = None


class ScanPlanner:
    """Commits a lookahead horizon of the scheduler's next-H buckets in
    elevator-sweep order over the data layout."""

    def __init__(
        self, scheduler, config: ScanPlanConfig = ScanPlanConfig()
    ) -> None:
        if config.horizon < 1:
            raise ValueError("horizon must be >= 1")
        self.scheduler = scheduler
        self.cfg = config
        self._layout_of = config.layout_of or float
        self._head: Optional[float] = None  # layout position of the sweep head
        self._direction = 1  # +1: ascending sweep, -1: descending
        self._deferrals: dict[int, int] = {}  # bucket -> commits left behind
        self._committed: tuple[int, ...] = ()
        self.commits = 0
        self.invalidations = 0  # commits whose candidate set shifted

    # -- the commitment ---------------------------------------------------------
    def plan(self, wm, cache, now: float, horizon: Optional[int] = None) -> list[int]:
        """Commit the next horizon: the scheduler's top-H buckets (by
        U_a, via the non-mutating peek) in elevator-sweep staging order.
        Returns bucket ids, first-to-stage first; empty when the
        scheduler is idle or cannot be peeked."""
        h = int(horizon) if horizon else self.cfg.horizon
        peek = getattr(self.scheduler, "peek_topk", None)
        if peek is None or h < 1:
            self._committed = ()
            return []
        candidates = [d.bucket_id for d in peek(wm, cache, now, h)]
        if not candidates:
            self._committed = ()
            return []
        pending, oldest_b = self._pending_and_oldest(wm)
        plan = self._sweep(candidates)
        plan = self._apply_starvation_guard(plan, oldest_b)
        # Bookkeeping: a commit that reshuffles the previous promise is an
        # invalidation; every candidate left behind the front defers once,
        # and so does a previously-promised bucket dropped from the new
        # horizon while still pending — that drop IS the starvation
        # vector.  Counts survive a bucket oscillating in and out of the
        # top-H (they reset only on service or drain), so a bucket the
        # reshuffles keep bouncing at the horizon boundary still
        # accumulates deferrals and is fronted when it next qualifies.
        cand_set = set(candidates)
        if self._committed and set(self._committed) != cand_set:
            self.invalidations += 1
        for b in list(self._deferrals):
            if b not in pending:
                del self._deferrals[b]  # drained: nothing left to starve
        for b in plan[1:]:
            self._deferrals[b] = self._deferrals.get(b, 0) + 1
        for b in self._committed:
            if b in pending and b not in cand_set:
                self._deferrals[b] = self._deferrals.get(b, 0) + 1
        self._deferrals[plan[0]] = 0
        self._committed = tuple(plan)
        self.commits += 1
        return plan

    def note_serviced(self, bucket_ids: Sequence[int]) -> None:
        """Advance the sweep head past the buckets just serviced and reset
        their deferral counts (service is the strongest un-starving)."""
        for b in bucket_ids:
            self._deferrals.pop(b, None)
        if not bucket_ids:
            return
        pos = self._layout_of(bucket_ids[-1])
        if self._head is not None and pos < self._head:
            self._direction = -1
        elif self._head is not None and pos > self._head:
            self._direction = 1
        self._head = pos

    # -- internals ---------------------------------------------------------------
    def _sweep(self, candidates: list[int]) -> list[int]:
        """Elevator order: continue the current direction from the head,
        then turn around for the stragglers.  A permutation of the
        candidates — nothing added, nothing dropped."""
        pos = self._layout_of
        head = self._head if self._head is not None else pos(candidates[0])
        if self._direction >= 0:
            ahead = sorted(
                (b for b in candidates if pos(b) >= head), key=lambda b: (pos(b), b)
            )
            behind = sorted(
                (b for b in candidates if pos(b) < head),
                key=lambda b: (pos(b), b), reverse=True,
            )
        else:
            ahead = sorted(
                (b for b in candidates if pos(b) <= head),
                key=lambda b: (pos(b), b), reverse=True,
            )
            behind = sorted(
                (b for b in candidates if pos(b) > head), key=lambda b: (pos(b), b)
            )
        if not ahead:  # nothing left in this direction: turn the elevator
            self._direction = -self._direction
            return behind
        return ahead + behind

    def _apply_starvation_guard(
        self, plan: list[int], oldest_b: Optional[int]
    ) -> list[int]:
        """Force the oldest pending bucket to the horizon front once
        repeated invalidations have deferred it past the limit."""
        if (
            oldest_b is not None
            and oldest_b in plan
            and plan[0] != oldest_b
            and self._deferrals.get(oldest_b, 0) >= self.cfg.starvation_deferrals
        ):
            plan = [oldest_b] + [b for b in plan if b != oldest_b]
        return plan

    @staticmethod
    def _pending_and_oldest(wm) -> tuple[set[int], Optional[int]]:
        """One walk over the nonempty queues: the pending bucket set (the
        deferral books' domain) and the oldest pending bucket (the
        starvation guard's subject)."""
        pending: set[int] = set()
        best = None
        best_key = None
        for q in wm.nonempty_queues():
            pending.add(q.bucket_id)
            key = (q.oldest_arrival, q.bucket_id)
            if best_key is None or key < best_key:
                best_key = key
                best = q.bucket_id
        return pending, best

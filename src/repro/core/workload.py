"""Workload decomposition and per-bucket workload queues.

Paper §3.1: a query Q_i is pre-processed into sub-queries; the *workload*
W_j^i is the set of Q_i's objects that overlap bucket B_j.  The workload
queue of B_j is the union over queries — requests from many queries are
interleaved in the same queue and joined in one pass.

A query completes only when every one of its work units has been evaluated
(the paper's "last-mile bottleneck", §3.3).

§6 workload overflow is *partial* and *byte-accurate* in both directions:
a queue can spill only its youngest work units to host
(``spill_bucket(b, frac)``) while the oldest units stay resident — so the
age term A(i) keeps its monotone now-independent rebase (the oldest
pending arrival never moves on a spill) and the requesters who have
waited longest never pay the host round-trip — and it pages back *paged*,
oldest units first, never exceeding the arbiter's byte grant
(``unspill_bucket(b, budget_bytes=...)``), so an unspill can never
re-exceed the budget in one shot.  The mechanics live in the shared
``SpillQueue`` primitive (``core/spillq.py``), the same container the
serving engine's per-adapter queues run on.
Accounting is in actual probe bytes (``CostModel.probe_bytes`` stamped
onto each unit at submit), not the object-count proxy: the §6 budget is a
memory budget, and probe payloads — not abstract objects — are what
occupy it.
"""
from __future__ import annotations

import dataclasses
import operator
from collections import defaultdict
from typing import Any, Callable, Iterable

import numpy as np

from .spillq import SpillBookkeepingMixin, SpillQueue

__all__ = ["Query", "WorkUnit", "WorkloadQueue", "WorkloadManager", "DEFAULT_TENANT"]

DEFAULT_TENANT = "default"


@dataclasses.dataclass
class Query:
    """One incoming query: a set of objects to probe, with key ranges.

    ``keys_lo``/``keys_hi`` are per-object SFC bounding ranges (the paper's
    per-object HTM ID range covering all potential match regions).
    ``payload`` carries whatever the evaluator needs (e.g. unit vectors).
    ``meta['tenant']`` tags the query's tenant class (interactive vs batch)
    for the multi-tenant control plane; untagged queries are 'default'.
    """

    query_id: int
    arrival_time: float
    keys_lo: np.ndarray
    keys_hi: np.ndarray
    payload: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_objects(self) -> int:
        return len(self.keys_lo)

    @property
    def tenant(self) -> str:
        return self.meta.get("tenant", DEFAULT_TENANT)


@dataclasses.dataclass
class WorkUnit:
    """W_j^i: the part of query ``query_id`` overlapping bucket ``bucket_id``.

    ``nbytes`` is the unit's probe payload size (object count x the cost
    model's ``probe_bytes``), stamped at submit — the currency of the §6
    overflow budget.  ``tenant`` is the parent query's tenant class.
    """

    query_id: int
    bucket_id: int
    object_idx: np.ndarray  # indices into the parent query's object arrays
    arrival_time: float
    nbytes: float = 0.0
    tenant: str = DEFAULT_TENANT

    @property
    def size(self) -> int:
        return len(self.object_idx)


class WorkloadQueue(SpillQueue):
    """Pending work units for one bucket — the core instantiation of the
    shared ``SpillQueue`` primitive (resident-oldest prefix / spilled-
    youngest suffix; ``core/spillq.py`` owns the spill mechanics, shared
    with serving's per-adapter queue).

    Invariants the schedulers and the control plane rely on:
      * ``oldest_arrival`` spans both sides and is maintained O(1) on push
        (units leave only wholesale via ``drain``), so the incremental
        scheduler's rebased key stays now-independent;
      * spilling moves only the *youngest* units — for a partial spill the
        oldest unit is always resident — and a paged unspill
        (``unspill_oldest``) returns the *oldest* spilled units first,
        never exceeding its byte grant;
      * ``size``/``nbytes`` count all pending work (Eq. 1's |W_i| is
        unchanged by residency); ``resident_size``/``resident_bytes``
        count only the resident prefix (the §6 budget target).
    """

    __slots__ = ("_oldest", "_oldest_tenant")

    def __init__(self, bucket_id: int) -> None:
        super().__init__(
            bucket_id,
            bytes_of=operator.attrgetter("nbytes"),
            arrival_of=operator.attrgetter("arrival_time"),
            count_of=operator.attrgetter("size"),
        )
        self._oldest = np.inf
        self._oldest_tenant = DEFAULT_TENANT

    # Historical names for the two sides (tests and the cross-match
    # engine's probe gather read these directly).
    @property
    def units(self) -> list[WorkUnit]:
        """Resident prefix (the oldest pending work)."""
        return self.resident

    @property
    def spilled_units(self) -> list[WorkUnit]:
        """Spilled suffix (the youngest, on host)."""
        return self.spilled

    def push(self, unit: WorkUnit) -> None:
        super().push(unit)
        if unit.arrival_time < self._oldest:
            self._oldest = unit.arrival_time
            self._oldest_tenant = unit.tenant

    def drain(self) -> list[WorkUnit]:
        units = super().drain()
        self._oldest = np.inf
        self._oldest_tenant = DEFAULT_TENANT
        return units

    @property
    def oldest_arrival(self) -> float:
        """Arrival time of the oldest pending unit (either side), O(1)."""
        return self._oldest if self._size else np.inf

    @property
    def oldest_tenant(self) -> str:
        """Tenant class of the oldest pending unit — the bucket's tenant
        for per-tenant alpha (the oldest requester is who the age term is
        protecting)."""
        return self._oldest_tenant


class WorkloadManager(SpillBookkeepingMixin):
    """The paper's Workload Manager (Fig. 3).

    Maintains: per-bucket workload queues, the query -> outstanding-bucket
    map, and per-queue oldest-request age.  ``decompose`` is the Query
    Pre-Processor: it maps each query object to the buckets its key range
    overlaps.  ``probe_bytes`` (normally set from ``CostModel.probe_bytes``
    by the engine) prices each pending object's host-side state for the §6
    overflow budget; ``min_unit_bytes`` floors each unit's price so no
    pending unit is a zero-byte free-rider invisible to the budget and to
    sigma (``CostModel.min_unit_bytes``).
    """

    def __init__(
        self,
        bucket_of_range: Callable[[int, int], np.ndarray],
        bucket_of_keys: Callable[[np.ndarray], np.ndarray] | None = None,
        probe_bytes: float = 1.0,
        min_unit_bytes: float = 1.0,
    ):
        # bucket_of_range(key_lo, key_hi) -> array of overlapping bucket ids
        # bucket_of_keys(keys) -> bucket id per key (vectorized fast path)
        self._bucket_of_range = bucket_of_range
        self._bucket_of_keys = bucket_of_keys
        self.probe_bytes = float(probe_bytes)
        self.min_unit_bytes = float(min_unit_bytes)
        self.queues: dict[int, WorkloadQueue] = {}
        self.outstanding: dict[int, set[int]] = {}  # query_id -> bucket ids
        self.queries: dict[int, Query] = {}
        self.completed: dict[int, float] = {}  # query_id -> completion time
        self._listeners: list[Callable[[int], None]] = []
        self._spilled: set[int] = set()  # buckets with any spilled units

    # -- change notification -------------------------------------------------
    def subscribe(self, fn: Callable[[int], None]) -> Callable[[int], None]:
        """Register ``fn(bucket_id)`` to fire whenever a bucket's queue
        contents change (submit/drain/spill).  Incremental schedulers use
        this to rescore only touched buckets instead of rescanning every
        queue."""
        self._listeners.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[int], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def _notify(self, bucket_id: int) -> None:
        for fn in self._listeners:
            fn(bucket_id)

    def _decompose(self, query: Query) -> dict[int, list[int]]:
        per_bucket: dict[int, list[int]] = defaultdict(list)
        if self._bucket_of_keys is not None and query.n_objects:
            lo_b = self._bucket_of_keys(query.keys_lo)
            hi_b = self._bucket_of_keys(query.keys_hi)
            simple = lo_b == hi_b  # the common case: one bucket per object
            idx = np.nonzero(simple)[0]
            if len(idx):
                order = idx[np.argsort(lo_b[idx], kind="stable")]
                ub, starts = np.unique(lo_b[order], return_index=True)
                for b, grp in zip(ub, np.split(order, starts[1:])):
                    per_bucket[int(b)].extend(grp.tolist())
            for i in np.nonzero(~simple)[0]:
                for b in range(int(lo_b[i]), int(hi_b[i]) + 1):
                    per_bucket[int(b)].append(int(i))
            return per_bucket
        for i in range(query.n_objects):
            for b in self._bucket_of_range(
                int(query.keys_lo[i]), int(query.keys_hi[i])
            ):
                per_bucket[int(b)].append(i)
        return per_bucket

    # -- intake -------------------------------------------------------------
    def decompose(self, query: Query) -> dict[int, list[int]]:
        """Public face of the Query Pre-Processor: bucket -> object indices.

        Shard routers decompose once centrally and hand each shard only its
        owned slice via ``submit_decomposed`` — the object indices always
        refer to the *original* query arrays, so a sharded engine's probe
        gather stays valid without renumbering."""
        return self._decompose(query)

    def submit(self, query: Query) -> list[WorkUnit]:
        """Pre-process a query into work units and enqueue them."""
        return self.submit_decomposed(query, self._decompose(query))

    def submit_decomposed(
        self, query: Query, per_bucket: dict[int, list[int]]
    ) -> list[WorkUnit]:
        """Enqueue an already-decomposed query (possibly a shard-local
        subset of its buckets).  An empty ``per_bucket`` completes the
        query immediately — for a sharded run that means "this shard owns
        none of it" and the router must not have routed it here."""
        units = []
        self.queries[query.query_id] = query
        self.outstanding[query.query_id] = set(per_bucket)
        for b, idx in per_bucket.items():
            unit = WorkUnit(
                query_id=query.query_id,
                bucket_id=b,
                object_idx=np.asarray(idx, dtype=np.int64),
                arrival_time=query.arrival_time,
                nbytes=max(len(idx) * self.probe_bytes, self.min_unit_bytes),
                tenant=query.tenant,
            )
            self.queue(b).push(unit)
            units.append(unit)
            self._notify(b)
        if not per_bucket:  # degenerate empty query completes immediately
            self.completed[query.query_id] = query.arrival_time
            del self.outstanding[query.query_id]
        return units

    # -- shard migration (work stealing) --------------------------------------
    def migrate_out(self, bucket_id: int) -> list[WorkUnit]:
        """Remove a bucket's entire pending queue *without* completing it.

        The inverse of ``submit_decomposed`` for one bucket: every affected
        query's outstanding set drops the bucket here, and the thief's
        ``migrate_in`` re-adds it there — completion bookkeeping moves with
        the units instead of firing.  Queries whose local outstanding set
        empties are forgotten locally (their join lives in the shard tier,
        never in ``completed``).  Returns the drained units in arrival
        order (resident prefix then spilled suffix)."""
        q = self.queues.pop(bucket_id, None)
        if q is None:
            return []
        self._spilled.discard(bucket_id)
        units = q.drain()
        for unit in units:
            pending = self.outstanding.get(unit.query_id)
            if pending is None:
                continue
            pending.discard(bucket_id)
            if not pending:
                del self.outstanding[unit.query_id]
        if units:
            self._notify(bucket_id)
        return units

    def migrate_in(
        self, units: Iterable[WorkUnit], queries: dict[int, Query]
    ) -> list[WorkUnit]:
        """Accept work units stolen from another manager.

        ``queries`` maps query_id -> parent Query for any unit whose parent
        this manager has not seen (the thief needs the original payload
        arrays for its probe gather).  Units land *resident* — the thief
        pays their bytes against its own §6 budget on its next enforcement
        round — and keep their original arrival times, so the age term
        A(i) is preserved across the migration."""
        units = list(units)
        touched: set[int] = set()
        for unit in units:
            src = queries.get(unit.query_id)
            if src is not None:
                self.queries.setdefault(unit.query_id, src)
            self.outstanding.setdefault(unit.query_id, set()).add(unit.bucket_id)
            self.queue(unit.bucket_id).push(unit)
            touched.add(unit.bucket_id)
        for b in sorted(touched):
            self._notify(b)
        return units

    # -- scheduling support ---------------------------------------------------
    def nonempty_queues(self) -> list[WorkloadQueue]:
        return [q for q in self.queues.values() if q]

    def queue(self, bucket_id: int) -> WorkloadQueue:
        # get-or-create without constructing a throwaway queue per call
        # (this sits on the per-unit submit hot path).
        q = self.queues.get(bucket_id)
        if q is None:
            q = self.queues[bucket_id] = WorkloadQueue(bucket_id)
        return q

    def ages_ms(self, now: float) -> dict[int, float]:
        """A(i): age in milliseconds of the oldest pending request per bucket
        (§3.3).  Spilled units still age — overflow defers work, it never
        forgets it."""
        return {
            b: (now - q.oldest_arrival) * 1e3
            for b, q in self.queues.items()
            if q
        }

    def tenant_of_bucket(self, bucket_id: int) -> str:
        """The bucket's tenant class for per-tenant alpha: the tenant of
        its oldest pending unit (whoever the age term is protecting).
        Changes only on push/drain, both of which notify subscribers."""
        q = self.queues.get(bucket_id)
        return q.oldest_tenant if q else DEFAULT_TENANT

    # -- §6 workload overflow (spill to host) ----------------------------------
    # is_spilled / spilled_fraction / spill_bucket / unspill_bucket /
    # spilled_buckets come from SpillBookkeepingMixin — ONE copy of the
    # §6 bucket protocol, shared with serving's AdapterWorkload.

    def resident_objects(self) -> int:
        """Pending objects NOT spilled to host."""
        return sum(q.resident_size for q in self.queues.values() if q)

    def resident_bytes(self) -> float:
        """Pending probe bytes NOT spilled to host (the §6 budget target)."""
        return sum(q.resident_bytes for q in self.queues.values() if q)

    def pending_bytes(self) -> float:
        return sum(q.nbytes for q in self.queues.values() if q)

    def spilled_bytes(self) -> float:
        return sum(q.spilled_bytes for q in self.queues.values() if q)

    def tenant_pending(self, tenant: str) -> tuple[int, float]:
        """(pending objects, pending probe bytes) attributable to one
        tenant class — the admission controller's view of how much of the
        workload a tenant already occupies, counted over BOTH residency
        sides (admission guards total pending state, not just the resident
        prefix; spilling must not launder quota headroom)."""
        objs, nbytes = 0, 0.0
        for q in self.queues.values():
            for unit in q.resident:
                if unit.tenant == tenant:
                    objs += unit.size
                    nbytes += unit.nbytes
            for unit in q.spilled:
                if unit.tenant == tenant:
                    objs += unit.size
                    nbytes += unit.nbytes
        return objs, nbytes

    # -- state snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-data view of the manager's full scheduling state (queue
        contents + order on both residency sides, outstanding joins,
        completions, spill marks) for the durability tier's replayed-state
        == live-state assertions."""

        def unit(u: WorkUnit) -> list:
            return [
                int(u.query_id), int(u.bucket_id), int(u.size),
                float(u.arrival_time), float(u.nbytes), u.tenant,
            ]

        return {
            "queues": {
                int(b): q.snapshot(unit)
                for b, q in sorted(self.queues.items())
                if q
            },
            "outstanding": {
                int(qid): sorted(int(b) for b in pending)
                for qid, pending in sorted(self.outstanding.items())
            },
            "completed": {
                int(qid): float(t) for qid, t in sorted(self.completed.items())
            },
            "spilled": sorted(int(b) for b in self._spilled),
        }

    # -- completion ------------------------------------------------------------
    def complete_bucket(self, bucket_id: int, now: float) -> list[int]:
        """Drain bucket's queue (both sides — servicing pages the spilled
        suffix back in); return ids of queries that fully completed."""
        done = []
        q = self.queues.get(bucket_id)
        if q is None:
            return done
        self._spilled.discard(bucket_id)
        if q:
            self._notify(bucket_id)
        for unit in q.drain():
            pending = self.outstanding.get(unit.query_id)
            if pending is None:
                continue
            pending.discard(bucket_id)
            if not pending:
                self.completed[unit.query_id] = now
                del self.outstanding[unit.query_id]
                done.append(unit.query_id)
        return done

    # -- introspection ----------------------------------------------------------
    @property
    def n_pending_queries(self) -> int:
        return len(self.outstanding)

    def pending_objects(self) -> int:
        return sum(q.size for q in self.queues.values())

    def response_times(self) -> dict[int, float]:
        return {
            qid: t - self.queries[qid].arrival_time
            for qid, t in self.completed.items()
        }

    def tenant_of_query(self, query_id: int) -> str:
        q = self.queries.get(query_id)
        return q.tenant if q is not None else DEFAULT_TENANT

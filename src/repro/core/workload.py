"""Workload decomposition and per-bucket workload queues.

Paper §3.1: a query Q_i is pre-processed into sub-queries; the *workload*
W_j^i is the set of Q_i's objects that overlap bucket B_j.  The workload
queue of B_j is the union over queries — requests from many queries are
interleaved in the same queue and joined in one pass.

A query completes only when every one of its work units has been evaluated
(the paper's "last-mile bottleneck", §3.3).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Iterable

import numpy as np

__all__ = ["Query", "WorkUnit", "WorkloadQueue", "WorkloadManager"]


@dataclasses.dataclass
class Query:
    """One incoming query: a set of objects to probe, with key ranges.

    ``keys_lo``/``keys_hi`` are per-object SFC bounding ranges (the paper's
    per-object HTM ID range covering all potential match regions).
    ``payload`` carries whatever the evaluator needs (e.g. unit vectors).
    """

    query_id: int
    arrival_time: float
    keys_lo: np.ndarray
    keys_hi: np.ndarray
    payload: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_objects(self) -> int:
        return len(self.keys_lo)


@dataclasses.dataclass
class WorkUnit:
    """W_j^i: the part of query ``query_id`` overlapping bucket ``bucket_id``."""

    query_id: int
    bucket_id: int
    object_idx: np.ndarray  # indices into the parent query's object arrays
    arrival_time: float

    @property
    def size(self) -> int:
        return len(self.object_idx)


class WorkloadQueue:
    """Pending work units for one bucket."""

    __slots__ = ("bucket_id", "units", "_size", "_oldest")

    def __init__(self, bucket_id: int) -> None:
        self.bucket_id = bucket_id
        self.units: list[WorkUnit] = []
        self._size = 0
        self._oldest = np.inf

    def push(self, unit: WorkUnit) -> None:
        self.units.append(unit)
        self._size += unit.size
        if unit.arrival_time < self._oldest:
            self._oldest = unit.arrival_time

    def drain(self) -> list[WorkUnit]:
        units, self.units, self._size = self.units, [], 0
        self._oldest = np.inf
        return units

    @property
    def size(self) -> int:
        """Total pending objects — |W_i| in Eq. 1."""
        return self._size

    @property
    def oldest_arrival(self) -> float:
        """Arrival time of the oldest pending unit, O(1) (maintained on
        push; units are only removed wholesale by drain)."""
        return self._oldest if self.units else np.inf

    def __len__(self) -> int:
        return len(self.units)

    def __bool__(self) -> bool:
        return bool(self.units)


class WorkloadManager:
    """The paper's Workload Manager (Fig. 3).

    Maintains: per-bucket workload queues, the query -> outstanding-bucket
    map, and per-queue oldest-request age.  ``decompose`` is the Query
    Pre-Processor: it maps each query object to the buckets its key range
    overlaps.
    """

    def __init__(
        self,
        bucket_of_range: Callable[[int, int], np.ndarray],
        bucket_of_keys: Callable[[np.ndarray], np.ndarray] | None = None,
    ):
        # bucket_of_range(key_lo, key_hi) -> array of overlapping bucket ids
        # bucket_of_keys(keys) -> bucket id per key (vectorized fast path)
        self._bucket_of_range = bucket_of_range
        self._bucket_of_keys = bucket_of_keys
        self.queues: dict[int, WorkloadQueue] = {}
        self.outstanding: dict[int, set[int]] = {}  # query_id -> bucket ids
        self.queries: dict[int, Query] = {}
        self.completed: dict[int, float] = {}  # query_id -> completion time
        self._listeners: list[Callable[[int], None]] = []
        self._spilled: set[int] = set()  # §6 workload overflow: queues on host

    # -- change notification -------------------------------------------------
    def subscribe(self, fn: Callable[[int], None]) -> Callable[[int], None]:
        """Register ``fn(bucket_id)`` to fire whenever a bucket's queue
        contents change (submit/drain).  Incremental schedulers use this to
        rescore only touched buckets instead of rescanning every queue."""
        self._listeners.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[int], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def _notify(self, bucket_id: int) -> None:
        for fn in self._listeners:
            fn(bucket_id)

    def _decompose(self, query: Query) -> dict[int, list[int]]:
        per_bucket: dict[int, list[int]] = defaultdict(list)
        if self._bucket_of_keys is not None and query.n_objects:
            lo_b = self._bucket_of_keys(query.keys_lo)
            hi_b = self._bucket_of_keys(query.keys_hi)
            simple = lo_b == hi_b  # the common case: one bucket per object
            idx = np.nonzero(simple)[0]
            if len(idx):
                order = idx[np.argsort(lo_b[idx], kind="stable")]
                ub, starts = np.unique(lo_b[order], return_index=True)
                for b, grp in zip(ub, np.split(order, starts[1:])):
                    per_bucket[int(b)].extend(grp.tolist())
            for i in np.nonzero(~simple)[0]:
                for b in range(int(lo_b[i]), int(hi_b[i]) + 1):
                    per_bucket[int(b)].append(int(i))
            return per_bucket
        for i in range(query.n_objects):
            for b in self._bucket_of_range(
                int(query.keys_lo[i]), int(query.keys_hi[i])
            ):
                per_bucket[int(b)].append(i)
        return per_bucket

    # -- intake -------------------------------------------------------------
    def submit(self, query: Query) -> list[WorkUnit]:
        """Pre-process a query into work units and enqueue them."""
        per_bucket = self._decompose(query)
        units = []
        self.queries[query.query_id] = query
        self.outstanding[query.query_id] = set(per_bucket)
        for b, idx in per_bucket.items():
            unit = WorkUnit(
                query_id=query.query_id,
                bucket_id=b,
                object_idx=np.asarray(idx, dtype=np.int64),
                arrival_time=query.arrival_time,
            )
            self.queues.setdefault(b, WorkloadQueue(b)).push(unit)
            units.append(unit)
            self._notify(b)
        if not per_bucket:  # degenerate empty query completes immediately
            self.completed[query.query_id] = query.arrival_time
            del self.outstanding[query.query_id]
        return units

    # -- scheduling support ---------------------------------------------------
    def nonempty_queues(self) -> list[WorkloadQueue]:
        return [q for q in self.queues.values() if q]

    def queue(self, bucket_id: int) -> WorkloadQueue:
        return self.queues.setdefault(bucket_id, WorkloadQueue(bucket_id))

    def ages_ms(self, now: float) -> dict[int, float]:
        """A(i): age in milliseconds of the oldest request per bucket (§3.3)."""
        return {
            b: (now - q.oldest_arrival) * 1e3
            for b, q in self.queues.items()
            if q
        }

    # -- §6 workload overflow (spill to host) ----------------------------------
    def is_spilled(self, bucket_id: int) -> bool:
        return bucket_id in self._spilled

    def spill_bucket(self, bucket_id: int) -> bool:
        """Mark a bucket's pending workload as overflowed to host.  The queue
        stays schedulable but pays the cost model's ``T_spill`` read-back
        surcharge, so the scheduler deprioritizes it until its age term
        reclaims it (no starvation).  Returns True if the state changed."""
        q = self.queues.get(bucket_id)
        if bucket_id in self._spilled or q is None or not q:
            return False
        self._spilled.add(bucket_id)
        self._notify(bucket_id)
        return True

    def unspill_bucket(self, bucket_id: int) -> bool:
        """Page a spilled workload queue back into the resident set."""
        if bucket_id not in self._spilled:
            return False
        self._spilled.discard(bucket_id)
        self._notify(bucket_id)
        return True

    def spilled_buckets(self) -> list[int]:
        return sorted(self._spilled)

    def resident_objects(self) -> int:
        """Pending objects NOT spilled to host (the overflow budget target)."""
        return sum(
            q.size for b, q in self.queues.items() if q and b not in self._spilled
        )

    # -- completion ------------------------------------------------------------
    def complete_bucket(self, bucket_id: int, now: float) -> list[int]:
        """Drain bucket's queue; return ids of queries that fully completed."""
        done = []
        q = self.queues.get(bucket_id)
        if q is None:
            return done
        self._spilled.discard(bucket_id)  # servicing pages the workload back in
        if q:
            self._notify(bucket_id)
        for unit in q.drain():
            pending = self.outstanding.get(unit.query_id)
            if pending is None:
                continue
            pending.discard(bucket_id)
            if not pending:
                self.completed[unit.query_id] = now
                del self.outstanding[unit.query_id]
                done.append(unit.query_id)
        return done

    # -- introspection ----------------------------------------------------------
    @property
    def n_pending_queries(self) -> int:
        return len(self.outstanding)

    def pending_objects(self) -> int:
        return sum(q.size for q in self.queues.values())

    def response_times(self) -> dict[int, float]:
        return {
            qid: t - self.queries[qid].arrival_time
            for qid, t in self.completed.items()
        }

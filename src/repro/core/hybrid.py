"""Hybrid join strategy (paper §3.4, Fig. 2).

Per scheduled bucket, choose between:
  * ``scan``    — one sequential pass over the whole bucket, cost
                  T_b*phi + T_m*|W|   (amortized, wins for big queues);
  * ``indexed`` — random index probes, cost |W| * T_probe
                  (wins for tiny queues; no bucket read at all).

The paper observes the break-even near |W| ~ 3% of the bucket size and up
to a 20x gap for 40 MB buckets.  We expose the analytic break-even and let
engines pick per-batch.  On the TPU side the same dichotomy is
dense-batched kernel vs sparse gather (``kernels/grouped_matmul`` hybrid
path).
"""
from __future__ import annotations

import dataclasses

from .metrics import CostModel

__all__ = ["HybridCostModel", "HybridPlanner", "JoinPlan"]


@dataclasses.dataclass(frozen=True)
class HybridCostModel(CostModel):
    """Extends the paper's (T_b, T_m) with an indexed-probe cost.

    ``T_probe`` is the per-object cost of an index lookup: a disk seek +
    small read in the paper; a sparse gather + small matmul on TPU.
    Defaults put the break-even at |W| = 3% * objects_per_bucket for the
    paper's SDSS constants (T_b=1.2s, 10k-object buckets):
        scan(W) = indexed(W)  =>  T_b + T_m*W = T_probe*W
        W* = T_b / (T_probe - T_m);  3% of 10k = 300 => T_probe ~ 4.13 ms.
    """

    T_probe: float = 4.13e-3
    # Fixed per-device-call overhead (kernel launch + host sync).  Zero by
    # default so single-bucket plans are unchanged; a shared plan amortizes
    # it across every scan member of the group (the third break-even axis).
    T_dispatch: float = 0.0

    def indexed_cost(self, queue_size: int) -> float:
        return self.T_probe * queue_size

    def scan_cost(self, queue_size: int, in_cache: bool) -> float:
        return self.batch_cost(queue_size, in_cache)

    def break_even_queue(self) -> float:
        """|W| above which a scan wins (cache-cold)."""
        denom = self.T_probe - self.T_m
        return float("inf") if denom <= 0 else self.T_b / denom


@dataclasses.dataclass(frozen=True)
class JoinPlan:
    strategy: str  # "scan" | "indexed"
    est_cost: float
    queue_size: int
    in_cache: bool


class HybridPlanner:
    """Chooses the per-bucket plan; optionally pinned by a fixed threshold.

    ``threshold_frac``: if given, mimic the paper's pre-determined threshold
    (fraction of bucket object count); otherwise use the analytic costs.
    """

    def __init__(
        self,
        cost: HybridCostModel,
        objects_per_bucket: int,
        threshold_frac: float | None = None,
    ) -> None:
        self.cost = cost
        self.objects_per_bucket = objects_per_bucket
        self.threshold_frac = threshold_frac

    def plan(self, queue_size: int, in_cache: bool) -> JoinPlan:
        scan = self.cost.scan_cost(queue_size, in_cache)
        idx = self.cost.indexed_cost(queue_size)
        if self.threshold_frac is not None:
            use_scan = queue_size >= self.threshold_frac * self.objects_per_bucket
        else:
            # A cached bucket's scan has no T_b term and nearly always wins.
            use_scan = scan <= idx
        return JoinPlan(
            strategy="scan" if use_scan else "indexed",
            est_cost=scan if use_scan else idx,
            queue_size=queue_size,
            in_cache=in_cache,
        )

    def plan_group(
        self, members: list[tuple[int, bool]]
    ) -> list[JoinPlan]:
        """Shared-plan break-even: plan a whole fuse group at once.

        ``members`` is [(queue_size, in_cache), ...] for the buckets a
        shared device call would cover.  Scan members split ONE kernel
        launch, so each one's scan cost carries only ``T_dispatch / s``
        (s = number of scan members) while an indexed member pays the full
        ``T_dispatch`` for its private probe call — batching the query
        axis moves the scan-vs-indexed break-even toward scan as the group
        grows.  This is the plan's third axis: queue size, cache
        residency, and now group size.  With ``T_dispatch == 0`` (the
        default cost model) every decision matches per-member ``plan()``.

        Fixed point in one descending pass: members are ranked by how much
        scan beats indexed; a member joins the scan set only if it still
        prefers scan with the launch overhead split s ways *including
        itself*, and each join only further cheapens scan for the rest.
        """
        overhead = getattr(self.cost, "T_dispatch", 0.0)
        base = [
            (self.cost.scan_cost(qs, ic), self.cost.indexed_cost(qs), qs, ic)
            for qs, ic in members
        ]
        if overhead <= 0.0:
            return [self.plan(qs, ic) for qs, ic in members]
        order = sorted(range(len(base)), key=lambda i: base[i][0] - base[i][1])
        plans: list[JoinPlan | None] = [None] * len(base)
        scan_set: list[int] = []
        for i in order:
            scan, idx, qs, ic = base[i]
            s = len(scan_set) + 1
            if self.threshold_frac is not None:
                use_scan = qs >= self.threshold_frac * self.objects_per_bucket
            else:
                use_scan = scan + overhead / s <= idx + overhead
            if use_scan:
                scan_set.append(i)
        s = max(len(scan_set), 1)
        for i, (scan, idx, qs, ic) in enumerate(base):
            if i in scan_set:
                plans[i] = JoinPlan("scan", scan + overhead / s, qs, ic)
            else:
                plans[i] = JoinPlan("indexed", idx + overhead, qs, ic)
        return plans

"""The single scheduling inner loop shared by both engines and the simulator.

Before this abstraction existed, ``crossmatch/engine.py``,
``serving/engine.py`` and ``core/simulate.py`` each re-implemented the
select -> execute -> complete round with their own (divergent) handling of
fuse_k, clocks and dispatch counting, and the adaptive controller was only
consulted by one benchmark.  ``DispatchLoop`` owns that round now:

    round():
      1. snapshot Telemetry (queues, cache, occupancy, arrival EWMA)
      2. vector = ControlLoop.update(telemetry)     # the ONE consult point
      3. apply vector.alpha to the scheduler (hot-swap re-key)
      4. apply_spill: enforce the §6 overflow budget on the workload
      5. select the top vector.fuse_k buckets (incremental heap path)
      6. cost = execute(decisions, vector)          # engine-specific compute
      7. advance the clock, run completion, count batches/dispatches

Engines supply only ``execute`` (the device call + result routing) and
optionally ``complete`` (defaults to ``wm.complete_bucket`` per decision).
Without a ControlLoop the loop emits a static vector from the scheduler's
current alpha and the configured fuse_k — the adaptive and static paths
run the same code.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from .control import ControlLoop, ControlVector, Telemetry, apply_spill
from .scheduler import SchedulerDecision

__all__ = ["DispatchOutcome", "DispatchLoop"]


@dataclasses.dataclass(frozen=True)
class DispatchOutcome:
    """What one scheduling round did."""

    decisions: tuple[SchedulerDecision, ...]
    cost: float
    vector: ControlVector
    spill_changed: tuple[int, ...] = ()


class DispatchLoop:
    def __init__(
        self,
        scheduler,
        wm,
        cache,
        execute: Callable[[Sequence[SchedulerDecision], ControlVector], float],
        *,
        control: Optional[ControlLoop] = None,
        fuse_k: int = 1,
        complete: Optional[Callable[[Sequence[SchedulerDecision], float], None]] = None,
        batch_capacity: Optional[int] = None,
        clock: float = 0.0,
    ) -> None:
        self.scheduler = scheduler
        self.wm = wm
        self.cache = cache
        self.control = control
        self._execute = execute
        self._complete = complete
        self._static_fuse_k = max(1, int(fuse_k))
        self.batch_capacity = batch_capacity  # per-bucket batch cap (serving)
        self.clock = clock
        self.batches = 0  # buckets serviced
        self.dispatches = 0  # device calls / scheduling rounds
        self.busy = 0.0  # total execute() cost
        self.last_vector: Optional[ControlVector] = None
        self._occupancy = 0.0  # last round's batch fill fraction

    # -- intake-side sensor -----------------------------------------------------
    def observe_arrival(self, t: float) -> None:
        """Feed one arrival to the controller's saturation estimator."""
        if self.control is not None:
            self.control.observe_arrival(t)

    # -- telemetry ---------------------------------------------------------------
    def telemetry(self) -> Telemetry:
        # One pass over the nonempty queues (still O(B) per round — the
        # select itself stays O(dirty·logB); push these into subscription-
        # maintained counters if B ever dominates the round).
        wm = self.wm
        queues = wm.nonempty_queues()
        is_spilled = getattr(wm, "is_spilled", None)
        pending = resident = 0
        oldest = self.clock
        for q in queues:
            pending += q.size
            if is_spilled is None or not is_spilled(q.bucket_id):
                resident += q.size
            if q.oldest_arrival < oldest:
                oldest = q.oldest_arrival
        return Telemetry(
            now=self.clock,
            arrival_rate=self.control.arrival_rate if self.control else 0.0,
            pending_objects=pending,
            resident_objects=resident,
            n_queues=len(queues),
            oldest_age_ms=max(0.0, (self.clock - oldest) * 1e3),
            cache_hit_rate=self.cache.stats.hit_rate
            if hasattr(self.cache, "stats")
            else 0.0,
            occupancy=self._occupancy,
        )

    # -- one scheduling round ----------------------------------------------------
    def round(self) -> Optional[DispatchOutcome]:
        if self.control is not None:
            vector = self.control.update(self.telemetry())
            if hasattr(self.scheduler, "alpha"):
                self.scheduler.alpha = vector.alpha
            spill_changed = apply_spill(self.wm, vector, self.control.cfg)
        else:
            vector = ControlVector(
                alpha=getattr(self.scheduler, "alpha", 0.0),
                fuse_k=self._static_fuse_k,
                spill=False,
            )
            spill_changed = []

        k = vector.fuse_k
        if k > 1 and hasattr(self.scheduler, "select_topk"):
            decisions = self.scheduler.select_topk(self.wm, self.cache, self.clock, k)
        else:
            d = self.scheduler.select(self.wm, self.cache, self.clock)
            decisions = [] if d is None else [d]
        if not decisions:
            return None

        cost = self._execute(decisions, vector)
        self.clock += cost
        self.busy += cost
        if self._complete is not None:
            self._complete(decisions, self.clock)
        else:
            for d in decisions:
                self.wm.complete_bucket(d.bucket_id, self.clock)
        self.batches += len(decisions)
        self.dispatches += 1
        self._occupancy = self._measure_occupancy(decisions)
        self.last_vector = vector
        return DispatchOutcome(tuple(decisions), cost, vector, tuple(spill_changed))

    def _measure_occupancy(self, decisions: Sequence[SchedulerDecision]) -> float:
        """Fill fraction of the dispatch just executed, the fuse_k feedback
        signal.  With a per-bucket batch cap (serving): serviced work over
        k * cap.  Without one (crossmatch/simulate): the share of pending
        work this dispatch covered — many shallow queues read as underfull,
        pushing k up to amortize dispatch."""
        serviced = sum(d.queue_size for d in decisions)
        if self.batch_capacity:
            cap = self.batch_capacity * len(decisions)
            serviced = sum(min(d.queue_size, self.batch_capacity) for d in decisions)
            return min(1.0, serviced / max(cap, 1))
        remaining = sum(q.size for q in self.wm.nonempty_queues())
        return min(1.0, serviced / max(serviced + remaining, 1))

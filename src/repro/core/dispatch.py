"""The single scheduling inner loop shared by both engines and the simulator.

Before this abstraction existed, ``crossmatch/engine.py``,
``serving/engine.py`` and ``core/simulate.py`` each re-implemented the
select -> execute -> complete round with their own (divergent) handling of
fuse_k, clocks and dispatch counting, and the adaptive controller was only
consulted by one benchmark.  ``DispatchLoop`` owns that round now:

    round():
      1. snapshot Telemetry (queues, cache, occupancy, arrival EWMA,
         prefetch stall/waste signals)
      2. vector = ControlLoop.update(telemetry)     # the ONE consult point
      3. apply vector.alpha to the scheduler (hot-swap re-key)
      4. apply_spill: enforce the §6 overflow budget on the workload
      5. select the top vector.fuse_k buckets (incremental heap path)
      5b. prefetch stage (when a PrefetchPipeline is wired): harvest
          completed stages, pay residual stall for demanded in-flight
          buckets, recommit the scan horizon (H from vector.horizon when
          the ControlLoop sizes it) and issue the next stages
      6. cost = stall + execute(decisions, vector)  # engine-specific compute
      7. advance the clock, run completion, count batches/dispatches

Engines supply only ``execute`` (the device call + result routing) and
optionally ``complete`` (defaults to ``wm.complete_bucket`` per decision).
Without a ControlLoop the loop emits a static vector from the scheduler's
current alpha and the configured fuse_k — the adaptive and static paths
run the same code.

With a ``TenantControlPlane`` the round goes multi-tenant: telemetry is
sliced per tenant class (``tenant_of`` maps bucket -> class), every
tenant's feedback laws run on their own slice, the resulting per-tenant
alphas are threaded into the shared scheduler as per-bucket Eq. 2 blends
(``set_tenant_alphas``), and §6 spill is enforced per tenant against the
arbiter's byte grants.  Selection stays ONE shared argmax over all
buckets — tenants are isolated in *policy*, not partitioned in data.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Sequence, Union

from .control import (
    ControlLoop,
    ControlVector,
    ShardGrant,
    Telemetry,
    TenantControlPlane,
    apply_spill,
)
from .scheduler import SchedulerDecision
from .workload import DEFAULT_TENANT

__all__ = ["DispatchOutcome", "DispatchLoop"]


@dataclasses.dataclass(frozen=True)
class DispatchOutcome:
    """What one scheduling round did.

    Under the multi-tenant plane, ``vector`` is the merged round vector
    actually applied to the dispatch mechanics (fuse_k = max over
    tenants; alpha is informational — scoring used per-bucket tenant
    alphas) and ``tenant_vectors`` carries each tenant's own decision.
    """

    decisions: tuple[SchedulerDecision, ...]
    cost: float
    vector: ControlVector
    spill_changed: tuple[int, ...] = ()
    tenant_vectors: Optional[Mapping[str, ControlVector]] = None
    # Residual prefetch stall included in ``cost`` (0.0 without a pipeline
    # or when every demanded bucket was already staged).
    stall: float = 0.0


class DispatchLoop:
    def __init__(
        self,
        scheduler,
        wm,
        cache,
        execute: Callable[[Sequence[SchedulerDecision], ControlVector], float],
        *,
        control: Optional[Union[ControlLoop, TenantControlPlane]] = None,
        tenant_of: Optional[Callable[[int], str]] = None,
        fuse_k: int = 1,
        complete: Optional[Callable[[Sequence[SchedulerDecision], float], None]] = None,
        batch_capacity: Optional[int] = None,
        clock: float = 0.0,
        on_round: Optional[Callable[[DispatchOutcome], None]] = None,
        prefetch=None,  # Optional[PrefetchPipeline] (core/prefetch.py)
    ) -> None:
        self.scheduler = scheduler
        self.wm = wm
        self.cache = cache
        self.control = control
        self.tenant_of = tenant_of or (lambda b: DEFAULT_TENANT)
        self._plane = control if isinstance(control, TenantControlPlane) else None
        self._execute = execute
        self._complete = complete
        self._static_fuse_k = max(1, int(fuse_k))
        self.batch_capacity = batch_capacity  # per-bucket batch cap (serving)
        self.clock = clock
        self.batches = 0  # buckets serviced
        self.dispatches = 0  # scheduling rounds
        self.device_dispatches = 0  # device calls issued by the executor
        self.busy = 0.0  # total execute() cost
        self.last_vector: Optional[ControlVector] = None
        self.last_tenant_vectors: Optional[dict[str, ControlVector]] = None
        self.on_round = on_round  # decision-log tap (tests/replay.py)
        self._occupancy = 0.0  # last round's batch fill fraction
        self._occ_by_tenant: dict[str, float] = {}
        self._shared_occ = 0.0  # last shared-plan round's query fill
        self._shared_occ_sum = 0.0  # occupancy-weighted shared-call total
        self._shared_calls = 0  # shared-plan device calls (occupancy known)
        self._dev_noted = False  # executor reported its own device calls
        self.prefetch = prefetch
        # Set by the shard tier (core/shard.py) before a round: the global
        # ShardControlPlane's byte grant for this shard.  None (the
        # default, and the whole story for unsharded loops) leaves the
        # local spill law untouched — the off-path is bit-identical.
        self.shard_grant: Optional[ShardGrant] = None
        self._stall_frac = 0.0  # last round's stall share of round time
        self._wasted_last = 0  # prefetched fills evicted untouched last round
        self._wasted_base = 0
        if prefetch is not None and hasattr(cache, "set_demand_probe"):
            # Demand-aware eviction: a resident bucket with zero pending
            # work is a strictly better victim than one queries wait on.
            cache.set_demand_probe(
                lambda b: q.size if (q := wm.queues.get(b)) else 0
            )

    # -- decision-log taps --------------------------------------------------------
    def add_round_tap(
        self, fn: Callable[[DispatchOutcome], None]
    ) -> Callable[[DispatchOutcome], None]:
        """Chain a second ``on_round`` consumer.  The write-ahead journal
        tap (serving/daemon.py) rides alongside a golden-trace recorder
        this way — neither clobbers the other; existing taps fire first,
        in installation order.  Returns ``fn``."""
        prev = self.on_round
        if prev is None:
            self.on_round = fn
        else:
            def chained(outcome, _prev=prev, _fn=fn):
                _prev(outcome)
                _fn(outcome)

            self.on_round = chained
        return fn

    # -- executor-side sensor ----------------------------------------------------
    def note_device_dispatches(
        self, n: int, shared_occupancy: Optional[float] = None
    ) -> None:
        """Executor callback: the round just executed issued ``n`` device
        calls (a shared plan issues fewer than one per bucket or per
        predicate class).  ``shared_occupancy`` is the query fill of those
        calls — queries / (chunks * share_width) — and feeds the
        share_width AIMD law via telemetry.  Executors that never call
        this get the legacy accounting of one device call per round."""
        self.device_dispatches += max(0, int(n))
        self._dev_noted = True
        if shared_occupancy is not None:
            self._shared_occ = min(1.0, max(0.0, shared_occupancy))
            self._shared_occ_sum += self._shared_occ * max(0, int(n))
            self._shared_calls += max(0, int(n))

    @property
    def shared_batch_occupancy(self) -> float:
        """Mean query fill across all shared-plan device calls (0.0 when
        the executor never reported one)."""
        if self._shared_calls <= 0:
            return 0.0
        return self._shared_occ_sum / self._shared_calls

    # -- intake-side sensor -----------------------------------------------------
    def observe_arrival(self, t: float) -> None:
        """Feed one arrival to the controller's saturation estimator."""
        if self.control is not None:
            self.control.observe_arrival(t)

    # -- telemetry ---------------------------------------------------------------
    def telemetry(self) -> Telemetry:
        tels = self._tenant_telemetry(split=False)
        return tels.get(DEFAULT_TENANT) or Telemetry(
            now=self.clock,
            arrival_rate=self.control.arrival_rate if self.control else 0.0,
            pending_objects=0,
            resident_objects=0,
            n_queues=0,
            oldest_age_ms=0.0,
            cache_hit_rate=self._hit_rate(),
            occupancy=self._occupancy,
        )

    def _hit_rate(self) -> float:
        return (
            self.cache.stats.hit_rate if hasattr(self.cache, "stats") else 0.0
        )

    def _tenant_telemetry(self, split: bool = True) -> dict[str, Telemetry]:
        """One pass over the nonempty queues, sliced per tenant class when
        ``split`` (the multi-tenant plane) and aggregated under the default
        tenant otherwise.  Still O(B) per round — the select itself stays
        O(dirty·logB); push these into subscription-maintained counters if
        B ever dominates the round."""
        wm = self.wm
        tenant_of = self.tenant_of if split else (lambda b: DEFAULT_TENANT)
        # per tenant: [pending, resident, pending_bytes, resident_bytes,
        #             n_queues, oldest]
        agg: dict[str, list] = {}
        for q in wm.nonempty_queues():
            t = tenant_of(q.bucket_id)
            a = agg.setdefault(t, [0, 0, 0.0, 0.0, 0, self.clock])
            size = q.size
            a[0] += size
            a[1] += getattr(q, "resident_size", size)
            a[2] += getattr(q, "nbytes", float(size))
            a[3] += getattr(q, "resident_bytes", float(size))
            a[4] += 1
            if q.oldest_arrival < a[5]:
                a[5] = q.oldest_arrival
        rate = self.control.arrival_rate if self.control else 0.0
        hit = self._hit_rate()
        inflight = self.prefetch.inflight if self.prefetch is not None else 0
        return {
            t: Telemetry(
                now=self.clock,
                arrival_rate=rate,
                pending_objects=a[0],
                resident_objects=a[1],
                n_queues=a[4],
                oldest_age_ms=max(0.0, (self.clock - a[5]) * 1e3),
                cache_hit_rate=hit,
                occupancy=self._occ_by_tenant.get(t, self._occupancy)
                if split
                else self._occupancy,
                pending_bytes=a[2],
                resident_bytes=a[3],
                # Pipeline signals are machine-global (one staging channel),
                # not per tenant: every slice sees the same values.
                prefetch_stall_frac=self._stall_frac,
                prefetch_wasted=self._wasted_last,
                prefetch_inflight=inflight,
                # Shared-plan fill is machine-global like the pipeline
                # signals: one shared executor, every slice sees it.
                shared_occupancy=self._shared_occ,
            )
            for t, a in agg.items()
        }

    # -- one scheduling round ----------------------------------------------------
    def round(self) -> Optional[DispatchOutcome]:
        tenant_vectors: Optional[dict[str, ControlVector]] = None
        if self._plane is not None:
            vector, spill_changed, tenant_vectors = self._consult_plane()
        elif self.control is not None:
            vector = self.control.update(self.telemetry())
            if hasattr(self.scheduler, "alpha"):
                self.scheduler.alpha = vector.alpha
            grant = self.shard_grant
            if grant is not None and grant.spill_bytes is not None:
                # Global tier overrides the local law: the shard spills
                # against its cross-shard byte grant, engagement decided
                # by the tier's hysteresis (exactly how the tenant plane
                # overrides per-loop spill bits with arbiter grants).
                vector = dataclasses.replace(vector, spill=grant.engaged)
            spill_changed = apply_spill(
                self.wm, vector, self.control.cfg,
                budget_bytes=(
                    grant.spill_bytes if grant is not None else None
                ),
                cost=getattr(self.scheduler, "cost_model", None),
                now=self.clock,
            )
        else:
            vector = ControlVector(
                alpha=getattr(self.scheduler, "alpha", 0.0),
                fuse_k=self._static_fuse_k,
                spill=False,
            )
            spill_changed = []

        k = vector.fuse_k
        if k > 1 and hasattr(self.scheduler, "select_topk"):
            decisions = self.scheduler.select_topk(self.wm, self.cache, self.clock, k)
        else:
            d = self.scheduler.select(self.wm, self.cache, self.clock)
            decisions = [] if d is None else [d]
        if not decisions:
            return None

        stall = 0.0
        self._dev_noted = False
        if self.prefetch is not None:
            # Between select and execute: harvest due stages, pay residual
            # stall for demanded in-flight buckets (the executor then sees
            # them resident and charges no read), recommit the horizon and
            # issue the next stages to overlap this round's compute.
            stall = self.prefetch.stage(
                self.wm, self.clock, decisions,
                horizon=vector.horizon or None,
            )
        cost = stall + self._execute(decisions, vector)
        self.clock += cost
        self.busy += cost
        if self.prefetch is not None:
            self.prefetch.note_serviced(decisions)
            self._stall_frac = stall / cost if cost > 0 else 0.0
            unused = self.cache.stats.prefetch_unused
            self._wasted_last = unused - self._wasted_base
            self._wasted_base = unused
        if self._complete is not None:
            self._complete(decisions, self.clock)
        else:
            for d in decisions:
                self.wm.complete_bucket(d.bucket_id, self.clock)
        self.batches += len(decisions)
        self.dispatches += 1
        if not self._dev_noted:
            # Legacy executors issue exactly one device call per round.
            self.device_dispatches += 1
        self._occupancy = self._measure_occupancy(decisions)
        if self._plane is not None:
            self._measure_tenant_occupancy(decisions)
        self.last_vector = vector
        self.last_tenant_vectors = tenant_vectors
        outcome = DispatchOutcome(
            tuple(decisions), cost, vector, tuple(spill_changed),
            tenant_vectors, stall,
        )
        if self.on_round is not None:
            self.on_round(outcome)
        return outcome

    # -- multi-tenant consult -----------------------------------------------------
    def _consult_plane(self):
        """Per-tenant control: slice telemetry by tenant class, run every
        tenant's feedback laws, thread per-tenant alphas into the shared
        scheduler (per-bucket blends), and enforce spill per tenant against
        the arbiter's byte grants.  Returns the merged round vector (what
        the dispatch mechanics use), the spill transitions, and the
        per-tenant vectors."""
        plane = self._plane
        vecs = plane.update(self._tenant_telemetry())
        if hasattr(self.scheduler, "set_tenant_alphas"):
            self.scheduler.set_tenant_alphas(
                {t: v.alpha for t, v in vecs.items()}, self.tenant_of
            )
        changed: list[int] = []
        cost = getattr(self.scheduler, "cost_model", None)
        for t, v in vecs.items():
            grant = (
                plane.granted_bytes.get(t)
                if plane.global_budget_bytes is not None
                else None
            )
            changed += apply_spill(
                self.wm, v, plane.policies[t].config,
                budget_bytes=grant,
                only=lambda b, _t=t: self.tenant_of(b) == _t,
                cost=cost,
                now=self.clock,
            )
        merged = ControlVector(
            # alpha is informational here — scoring used per-bucket tenant
            # alphas; fuse_k must cover the hungriest tenant's breadth,
            # and the horizon the deepest lookahead any tenant asked for.
            alpha=sum(v.alpha for v in vecs.values()) / max(len(vecs), 1),
            fuse_k=max((v.fuse_k for v in vecs.values()), default=1),
            spill=any(v.spill for v in vecs.values()),
            horizon=max((v.horizon for v in vecs.values()), default=0),
            share_width=max((v.share_width for v in vecs.values()), default=0),
        )
        return merged, changed, dict(vecs)

    def _measure_tenant_occupancy(self, decisions: Sequence[SchedulerDecision]) -> None:
        """Per-tenant fuse_k feedback: each tenant's AIMD law sees the fill
        fraction of its own slice of the fused dispatch.  Tenants absent
        from this round keep their previous signal.  One pass over the
        queues total (not per tenant)."""
        by_tenant: dict[str, list[SchedulerDecision]] = {}
        for d in decisions:
            by_tenant.setdefault(self.tenant_of(d.bucket_id), []).append(d)
        if self.batch_capacity:
            for t, ds in by_tenant.items():
                cap = self.batch_capacity * len(ds)
                serviced = sum(
                    min(d.queue_size, self.batch_capacity) for d in ds
                )
                self._occ_by_tenant[t] = min(1.0, serviced / max(cap, 1))
            return
        remaining_by_tenant: dict[str, int] = {}
        for q in self.wm.nonempty_queues():
            t = self.tenant_of(q.bucket_id)
            remaining_by_tenant[t] = remaining_by_tenant.get(t, 0) + q.size
        for t, ds in by_tenant.items():
            serviced = sum(d.queue_size for d in ds)
            remaining = remaining_by_tenant.get(t, 0)
            self._occ_by_tenant[t] = min(
                1.0, serviced / max(serviced + remaining, 1)
            )

    def _measure_occupancy(self, decisions: Sequence[SchedulerDecision]) -> float:
        """Fill fraction of the dispatch just executed, the fuse_k feedback
        signal.  With a per-bucket batch cap (serving): serviced work over
        k * cap.  Without one (crossmatch/simulate): the share of pending
        work this dispatch covered — many shallow queues read as underfull,
        pushing k up to amortize dispatch."""
        serviced = sum(d.queue_size for d in decisions)
        if self.batch_capacity:
            cap = self.batch_capacity * len(decisions)
            serviced = sum(min(d.queue_size, self.batch_capacity) for d in decisions)
            return min(1.0, serviced / max(cap, 1))
        remaining = sum(q.size for q in self.wm.nonempty_queues())
        return min(1.0, serviced / max(serviced + remaining, 1))

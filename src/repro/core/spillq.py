"""Shared §6 spill-queue primitive: resident-oldest prefix, spilled-youngest
suffix.

LifeRaft §6 trades arrival-order processing against data-driven batching
by spilling overflow workload to secondary storage and paging it back as
memory allows.  Two subsystems need exactly this container: the core
``WorkloadQueue`` (pending work units per bucket) and the serving
engine's per-adapter request queue.  They used to hand-mirror each
other's spill mechanics (push boundary rule, youngest-first eviction,
O(1) byte counters) — policed by a property suite but still two copies.
``SpillQueue`` is the one implementation both rebase on.

The container holds two lists of opaque items:

* ``resident`` — the *oldest* pending items, in memory (the §6 budget
  target);
* ``spilled``  — the *youngest* items, paged to host.

and is parameterized by accessors instead of item types:

* ``bytes_of(item)``   — the item's spillable payload bytes (the budget
  currency; clamp at the call site — see ``CostModel.min_unit_bytes``);
* ``arrival_of(item)`` — the item's arrival time (drives every age cut);
* ``count_of(item)``   — optional object count per item (|W_i| units for
  the core queue; defaults to 1 per item, the serving request case);
* ``order_of(item)``   — optional total-order key used when merging paged
  items back into the resident prefix (defaults to ``arrival_of``; the
  serving queue adds the request id as a tie-break).

Invariants every consumer relies on (property-tested in
``tests/test_partial_spill.py``):

* **conservation** — ``resident_bytes + spilled_bytes == nbytes`` and the
  same for counts, under any interleaving of push/spill/unspill/prune;
* **age cut** — no resident item is younger than any spilled item, so the
  oldest pending item is always resident after a *partial* spill and the
  scheduler's monotone age rebase is untouched by overflow;
* **paged unspill never overshoots** — ``unspill_oldest(budget_bytes=g)``
  pages items back strictly oldest-first and stops *before* the item that
  would exceed ``g`` (the wholesale ``unspill_all`` re-exceeding the §6
  budget in one shot is exactly the thrash §6's incremental
  head-scheduling analogy is designed to avoid);
* while anything is spilled, new (youngest) work lands on the spilled
  side, so an overflowing queue cannot grow its resident footprint behind
  the budget's back — but a late out-of-order arrival older than the
  spill boundary still joins the resident prefix.
"""
from __future__ import annotations

from typing import Callable, Generic, Iterable, Optional, TypeVar

__all__ = ["SpillQueue", "SpillBookkeepingMixin"]

T = TypeVar("T")

_INF = float("inf")


def _one(_item) -> int:
    return 1


class SpillQueue(Generic[T]):
    """Resident-oldest-prefix / spilled-youngest-suffix item queue.

    Byte and count tallies are maintained O(1) on push; spill/unspill are
    O(n log n) in the side they walk (enforcement-rate operations, not
    per-item ones).
    """

    __slots__ = (
        "bucket_id", "resident", "spilled",
        "_size", "_spilled_size", "_bytes", "_spilled_bytes",
        "_spilled_oldest",
        "_bytes_of", "_arrival_of", "_count_of", "_order_of",
    )

    def __init__(
        self,
        bucket_id: int,
        *,
        bytes_of: Callable[[T], float],
        arrival_of: Callable[[T], float],
        count_of: Optional[Callable[[T], int]] = None,
        order_of: Optional[Callable[[T], object]] = None,
    ) -> None:
        self.bucket_id = bucket_id
        self.resident: list[T] = []  # oldest pending work, in memory
        self.spilled: list[T] = []  # youngest, on host
        self._size = 0
        self._spilled_size = 0
        self._bytes = 0.0
        self._spilled_bytes = 0.0
        self._spilled_oldest = _INF  # oldest arrival on the spilled side
        self._bytes_of = bytes_of
        self._arrival_of = arrival_of
        self._count_of = count_of or _one
        self._order_of = order_of or arrival_of

    # -- intake -----------------------------------------------------------------
    def push(self, item: T) -> bool:
        """Enqueue one item.  While any of the queue is spilled, new
        (youngest) work lands on the spilled side so the resident prefix
        stays an age-contiguous cut; an item older than the spill boundary
        (late out-of-order arrival) still joins the resident prefix.
        Returns True iff the item landed spilled."""
        landed_spilled = bool(self.spilled) and (
            self._arrival_of(item) >= self._spilled_oldest
        )
        if landed_spilled:
            self.spilled.append(item)
            self._spilled_size += self._count_of(item)
            self._spilled_bytes += self._bytes_of(item)
        else:
            self.resident.append(item)
        self._size += self._count_of(item)
        self._bytes += self._bytes_of(item)
        return landed_spilled

    def drain(self) -> list[T]:
        """Remove and return everything (both sides; servicing pages the
        spilled suffix back in)."""
        items = self.resident + self.spilled
        self.resident, self.spilled = [], []
        self._size = self._spilled_size = 0
        self._bytes = self._spilled_bytes = 0.0
        self._spilled_oldest = _INF
        return items

    def prune_resident(self, keep: Callable[[T], bool]) -> int:
        """Drop resident items failing ``keep`` (retired work) and rebase
        the tallies.  The spilled side is untouched — spilled items leave
        only by being paged back in or drained.  Returns items dropped."""
        before = len(self.resident)
        self.resident = [x for x in self.resident if keep(x)]
        self._bytes = (
            sum(self._bytes_of(x) for x in self.resident) + self._spilled_bytes
        )
        self._size = (
            sum(self._count_of(x) for x in self.resident) + self._spilled_size
        )
        return before - len(self.resident)

    # -- §6 spill ----------------------------------------------------------------
    def spill_youngest(self, frac: float = 1.0) -> int:
        """Move the youngest resident items to host until the spilled byte
        fraction reaches ``frac`` of the queue's total bytes.  Item
        granularity rounds *up* (spill at least the requested bytes); for
        ``frac < 1`` the oldest item always stays resident.  Stable on
        arrival ties, so repeated partial spills are deterministic.
        Returns the number of items moved."""
        if not self.resident:
            return 0
        target = min(max(frac, 0.0), 1.0) * self._bytes
        keep_oldest = frac < 1.0
        # Youngest == largest arrival time; index tie-break keeps it stable.
        order = sorted(
            range(len(self.resident)),
            key=lambda i: (self._arrival_of(self.resident[i]), i),
        )
        moved = 0
        while self._spilled_bytes < target and order:
            if keep_oldest and len(order) == 1:
                break
            i = order.pop()  # youngest remaining
            item = self.resident[i]
            self._spilled_size += self._count_of(item)
            self._spilled_bytes += self._bytes_of(item)
            moved += 1
        if moved:
            keep = set(order)
            victims = [x for i, x in enumerate(self.resident) if i not in keep]
            self.resident = [self.resident[i] for i in sorted(keep)]
            # Spilled suffix stays youngest-last like the resident list.
            victims.sort(key=self._arrival_of)
            self.spilled.extend(victims)
            self._spilled_oldest = min(
                self._spilled_oldest, self._arrival_of(victims[0])
            )
        return moved

    # -- §6 unspill --------------------------------------------------------------
    def unspill_all(self) -> int:
        """Page every spilled item back into the resident prefix (the
        legacy wholesale mode).  Idempotent.  Returns items restored."""
        moved = len(self.spilled)
        if moved:
            merged = self.resident + self.spilled
            merged.sort(key=self._order_of)
            self.resident = merged
            self.spilled = []
            self._spilled_size = 0
            self._spilled_bytes = 0.0
            self._spilled_oldest = _INF
        return moved

    def unspill_oldest(
        self,
        budget_bytes: Optional[float] = None,
        max_items: Optional[int] = None,
    ) -> int:
        """Page spilled items back into the resident prefix **oldest
        first**, stopping *before* the item that would push the paged-in
        bytes past ``budget_bytes`` (strict: a grant is never overshot —
        the §6 budget-overshoot fix) or past ``max_items``.  Oldest-first
        is also strict: a younger item is never paged in ahead of an older
        one that does not fit.  ``None`` bounds are unlimited (both
        ``None`` == ``unspill_all``).  Returns items restored."""
        if not self.spilled:
            return 0
        if budget_bytes is None and max_items is None:
            return self.unspill_all()
        if max_items is None and budget_bytes >= self._spilled_bytes:
            # A grant covering the whole tracked suffix pages it all in.
            # Comparing against the tally the granter itself read avoids
            # stranding the last item on an ULP difference between the
            # incrementally-accumulated tally and the per-item re-sum.
            return self.unspill_all()
        # The spilled side is *mostly* arrival-ordered, but pushes landing
        # on it only respect the boundary, not the suffix order — sort.
        order = sorted(
            range(len(self.spilled)),
            key=lambda i: (self._arrival_of(self.spilled[i]), i),
        )
        take: list[int] = []
        paged = 0.0
        for i in order:
            if max_items is not None and len(take) >= max_items:
                break
            b = self._bytes_of(self.spilled[i])
            if budget_bytes is not None and paged + b > budget_bytes:
                break  # strict oldest-first: do not skip ahead
            paged += b
            take.append(i)
        if not take:
            return 0
        if len(take) == len(self.spilled):
            return self.unspill_all()
        chosen = set(take)
        moved = [x for i, x in enumerate(self.spilled) if i in chosen]
        self.spilled = [x for i, x in enumerate(self.spilled) if i not in chosen]
        return self._page_in(moved)

    def unspill_items(self, items: Iterable[T]) -> int:
        """Page back exactly the given items (matched by identity) if they
        are on the spilled side — the 'these requests were just serviced'
        path: servicing pages in only what it touched, not the whole
        suffix.  Returns items restored."""
        if not self.spilled:
            return 0
        ids = {id(x) for x in items}
        if not ids:
            return 0
        moved = [x for x in self.spilled if id(x) in ids]
        if not moved:
            return 0
        if len(moved) == len(self.spilled):
            return self.unspill_all()
        self.spilled = [x for x in self.spilled if id(x) not in ids]
        return self._page_in(moved)

    def _page_in(self, moved: list[T]) -> int:
        """Merge paged-in items into the resident prefix and rebuild the
        spilled tallies from what remains (deterministic values independent
        of spill history, so replayed traces stay bit-stable)."""
        merged = self.resident + moved
        merged.sort(key=self._order_of)
        self.resident = merged
        self._spilled_size = sum(self._count_of(x) for x in self.spilled)
        self._spilled_bytes = sum(self._bytes_of(x) for x in self.spilled)
        self._spilled_oldest = min(
            self._arrival_of(x) for x in self.spilled
        )
        return len(moved)

    # -- accounting ---------------------------------------------------------------
    @property
    def size(self) -> int:
        """Total pending count (resident + spilled) — |W_i| in Eq. 1 is
        unchanged by residency."""
        return self._size

    @property
    def resident_size(self) -> int:
        return self._size - self._spilled_size

    @property
    def nbytes(self) -> float:
        """Total pending payload bytes (resident + spilled)."""
        return self._bytes

    @property
    def resident_bytes(self) -> float:
        return self._bytes - self._spilled_bytes

    @property
    def spilled_bytes(self) -> float:
        return self._spilled_bytes

    @property
    def spilled_fraction(self) -> float:
        """sigma(i) in Eq. 1: spilled share of the queue's payload bytes.
        Exactly 0.0 / 1.0 at the ends (a fully spilled queue pays exactly
        T_spill, bit-identical to the legacy boolean semantics)."""
        if not self.spilled or not self._size:
            return 0.0
        if not self.resident:
            return 1.0
        return self._spilled_bytes / self._bytes if self._bytes else 0.0

    @property
    def oldest_arrival(self) -> float:
        """Arrival of the oldest pending item, either side.  O(n) here;
        subclasses that can maintain it O(1) (core WorkloadQueue) override."""
        if not self.resident and not self.spilled:
            return _INF
        return min(
            self._arrival_of(x) for x in self.resident + self.spilled
        )

    def __len__(self) -> int:
        return len(self.resident) + len(self.spilled)

    def __bool__(self) -> bool:
        return self._size > 0

    # -- state snapshot -----------------------------------------------------------
    def snapshot(self, describe: Optional[Callable[[T], object]] = None) -> dict:
        """Plain-data view of the queue's full state — both sides in
        stored order plus the O(1) tallies.  ``describe`` maps an item to
        a JSON-comparable key (defaults to ``repr``).  Used by the
        durability tier to assert journal-replayed state equals live state
        (resident/spilled membership AND order matter: the spill boundary
        and the paged-unspill merge order are part of the decision
        state)."""
        describe = describe or repr
        return {
            "bucket": self.bucket_id,
            "resident": [describe(x) for x in self.resident],
            "spilled": [describe(x) for x in self.spilled],
            "size": self._size,
            "bytes": self._bytes,
            "spilled_size": self._spilled_size,
            "spilled_bytes": self._spilled_bytes,
        }


class SpillBookkeepingMixin:
    """Manager-side §6 bookkeeping over a dict of SpillQueue buckets —
    the spilled-mark set, change notification, and the spill/unspill
    bucket protocol, shared by ``WorkloadManager`` and the serving
    engine's ``AdapterWorkload`` (one copy, like the queue mechanics).

    Host classes provide ``self.queues`` (bucket id -> SpillQueue),
    ``self._spilled`` (set of bucket ids with any spilled work) and
    ``self._notify(bucket_id)`` (incremental-scheduler change tap).
    """

    def is_spilled(self, bucket_id: int) -> bool:
        """True if any of the bucket's pending workload is on host."""
        return bucket_id in self._spilled

    def spilled_fraction(self, bucket_id: int) -> float:
        """sigma(i): the bucket's spilled byte fraction, in [0, 1]."""
        q = self.queues.get(bucket_id)
        return q.spilled_fraction if q else 0.0

    def spilled_buckets(self) -> list[int]:
        return sorted(self._spilled)

    def spill_bucket(self, bucket_id: int, frac: float = 1.0) -> bool:
        """Spill the youngest ``frac`` of the bucket's pending payload
        bytes to host (unit granularity, rounding up; ``frac=1`` spills
        the whole queue — the legacy semantics).  The queue stays
        schedulable but pays a sigma-pro-rated ``T_spill`` read-back
        surcharge in the scheduler score, so it is deprioritized until
        its age term reclaims it (no starvation).  Returns True if any
        unit moved."""
        q = self.queues.get(bucket_id)
        if q is None or not q:
            return False
        if not q.spill_youngest(frac):
            return False
        self._spilled.add(bucket_id)
        self._notify(bucket_id)
        return True

    def unspill_bucket(
        self, bucket_id: int, budget_bytes: Optional[float] = None
    ) -> bool:
        """Page a bucket's spilled workload back into the resident set.
        Idempotent: unspilling an unspilled bucket is a no-op.

        ``budget_bytes`` switches to the *paged* protocol: only the
        grant's worth pages back, oldest units first, never exceeding the
        grant (unit granularity rounds *down* — a grant is a budget, not
        a target).  The bucket stays marked spilled while any suffix
        remains, so sigma keeps pro-rating ``T_spill`` in Eq. 1 and the
        incremental scheduler re-keys it through the change notification.
        """
        if bucket_id not in self._spilled:
            return False
        q = self.queues.get(bucket_id)
        if q is None:
            self._spilled.discard(bucket_id)
            self._notify(bucket_id)
            return True
        if budget_bytes is None:
            q.unspill_all()
            self._spilled.discard(bucket_id)
            self._notify(bucket_id)
            return True
        moved = q.unspill_oldest(budget_bytes=budget_bytes)
        if not q.spilled:  # fully paged back in
            self._spilled.discard(bucket_id)
        if not moved:
            return False
        self._notify(bucket_id)
        return True

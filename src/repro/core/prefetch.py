"""Prefetch pipeline: stage the committed scan horizon ahead of compute.

The reactive loop pays every bucket miss inline: select, discover the
miss, read for ``T_b`` seconds while the device idles, compute.  The
paper's data-driven ordering makes the *next* reads predictable, so this
module overlaps them with the current round's compute — CasJobs' "stage
the data before the batch window" discipline driven by LifeRaft's own
priority heap.

``PrefetchPipeline`` sits between select and execute in the
``DispatchLoop`` round:

1. **harvest** — stages whose I/O completed by ``now`` land in the
   ``BucketCache`` via ``insert_prefetched`` (a fill, not an access — the
   hit-rate split in ``CacheStats`` stays honest);
2. **resolve demand** — a bucket selected *this* round while still in
   flight is force-completed; the round pays only the *residual* stall
   (``eta - now``), not the full ``T_b`` — the partial win of a prefetch
   that started early but not early enough;
3. **recommit** — the ``ScanPlanner`` commits a fresh horizon from the
   scheduler's top-H peek, the first ``depth`` non-resident horizon
   buckets are issued on the staging channel (double-buffered by
   default: the next bucket loads while the current one computes), and
   the horizon is eviction-protected in the cache.

The staging channel is modeled as ONE serial device (the disk head / the
host->HBM DMA engine): stages queue behind each other on a virtual I/O
clock (``eta = max(channel_free, now) + t_stage``), entirely
deterministic, so decision traces with prefetch on are replayable and
golden-pinnable.  With a real ``fetch`` callable (the cross-match
engine's bucket reads), payload I/O additionally runs on a thread pool —
the *cost accounting* stays on the virtual clock while the bytes move in
the background; harvesting blocks on the future only when the virtual
clock says the stage is due, so threading never perturbs the trace.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional, Sequence, Union

from .scanplan import ScanPlanConfig, ScanPlanner

__all__ = [
    "PrefetchConfig", "PrefetchPipeline", "build_pipeline", "prefetch_stats",
]


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    """Prefetch knobs, shared by both engines and the simulator.

    ``horizon`` seeds the planner's lookahead H (the ControlLoop's AIMD
    law may resize it per round); ``depth`` bounds stages in flight on
    the serial channel (2 == classic double buffering); ``t_stage``
    overrides the virtual seconds per staged bucket (default: the cost
    model's ``T_b``); ``workers`` sizes the thread pool when a real
    ``fetch`` is wired in.  ``layout_of`` maps bucket id -> physical file
    position for the planner's elevator sweep (default: the id itself,
    i.e. logical order == physical order).
    """

    horizon: int = 4
    depth: int = 2
    starvation_deferrals: int = 3
    t_stage: Optional[float] = None
    workers: int = 2
    layout_of: Optional[Callable[[int], float]] = None


@dataclasses.dataclass
class _Stage:
    bucket_id: int
    eta: float  # virtual completion time on the serial staging channel
    future: Optional[Future] = None  # real payload read (engines only)
    t_stage: float = 0.0  # service time — the channel interval is [eta - t, eta]

    def payload(self) -> object:
        return self.future.result() if self.future is not None else None


class PrefetchPipeline:
    """Asynchronous bucket staging driven by the committed scan horizon."""

    def __init__(
        self,
        cache,
        planner: ScanPlanner,
        t_stage: Union[float, Callable[[int], float]],
        *,
        fetch: Optional[Callable[[int], object]] = None,
        depth: int = 2,
        workers: int = 2,
    ) -> None:
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.cache = cache
        self.planner = planner
        self._t_stage = t_stage if callable(t_stage) else (lambda b: float(t_stage))
        self._fetch = fetch
        self.depth = depth
        self._workers = max(1, workers)
        self._pool: Optional[ThreadPoolExecutor] = None  # lazy (see _submit)
        self._inflight: dict[int, _Stage] = {}
        self._io_free = 0.0  # virtual time the staging channel frees up
        self.last_horizon: tuple[int, ...] = ()
        # Per-round staging byte cap from the cross-shard arbiter (None:
        # uncapped — the default, and the whole story off the shard tier).
        # Needs ``nbytes_of`` to price a stage; without one the cap is
        # ignored rather than guessed.
        self.grant_bytes: Optional[float] = None
        self.nbytes_of: Optional[Callable[[int], float]] = None
        # -- telemetry ----------------------------------------------------------
        self.stall_s = 0.0  # cumulative residual stall paid on demand
        self.last_stall = 0.0
        self.staged = 0  # stages issued
        self.fills = 0  # stages landed in the cache
        self.refused = 0  # fills the cache refused (no evictable slot)
        self.demand_waits = 0  # rounds that hit an in-flight stage
        self.canceled = 0  # in-flight stages abandoned (demand disappeared)
        self.reclaimed_s = 0.0  # channel seconds returned by cancels

    # -- the per-round stage (DispatchLoop: between select and execute) ---------
    def stage(
        self, wm, now: float, decisions: Sequence, horizon: Optional[int] = None
    ) -> float:
        """One prefetch round.  Returns the residual stall (seconds) the
        round must pay for decision buckets still in flight; the executor
        then sees them resident and charges no ``T_b``."""
        self._harvest(now)
        stall = 0.0
        demanded = {d.bucket_id for d in decisions}
        waited = False
        for b in list(self._inflight):
            if b in demanded:
                st = self._inflight.pop(b)
                # Charge the residual stall only when the fill actually
                # lands; a refused landing (admission control) means the
                # executor pays its ordinary inline miss — charging the
                # stall on top would bill the round twice for one read.
                if self._land(st):
                    stall = max(stall, st.eta - now)
                    waited = True
        stall = max(0.0, stall)
        if waited:
            self.demand_waits += 1
            self.stall_s += stall
        self.last_stall = stall
        # Recommit the horizon and top up the staging channel.  H counts
        # buckets *beyond* the current dispatch: the peek must reach past
        # the demanded buckets (already being serviced — their I/O is this
        # round's demand read, not lookahead) or a fused round would
        # swallow the whole lookahead and nothing would ever stage.
        h = int(horizon) if horizon else self.planner.cfg.horizon
        plan = self.planner.plan(wm, self.cache, now, h + len(demanded))
        plan = [b for b in plan if b not in demanded]
        self.last_horizon = tuple(plan)
        can_admit = getattr(self.cache, "can_admit_prefetch", None)
        grant = self.grant_bytes if self.nbytes_of is not None else None
        issued_bytes = 0.0
        for b in plan:
            if len(self._inflight) >= self.depth:
                break
            if b in self._inflight or self.cache.contains(b):
                continue
            if can_admit is not None and not can_admit():
                break  # a refused fill would waste the serial channel
            if grant is not None:
                nb = float(self.nbytes_of(b))
                if issued_bytes + nb > grant:
                    break  # arbiter grant exhausted for this round
                issued_bytes += nb
            t = self._t_stage(b)
            eta = max(self._io_free, now) + t
            fut = self._submit(b)
            self._inflight[b] = _Stage(b, eta, fut, t)
            self._io_free = eta
            self.staged += 1
        self.cache.protect(list(plan) + list(self._inflight))
        return stall

    def cancel(self, bucket_id: int, now: float) -> float:
        """Abandon an in-flight stage whose demand disappeared (a stolen
        bucket's pending units left this shard — the fill would land in a
        dead slot).  Charges only the channel time already *spent*: the
        residual service (the part of ``[eta - t_stage, eta]`` after
        ``now``, capped at the full service time if the stage had not yet
        reached the channel head) is reclaimed — every later stage's eta,
        and the channel's free time, shift earlier by it.  Returns the
        reclaimed seconds (0.0 when the bucket is not in flight or its
        I/O already completed)."""
        st = self._inflight.pop(bucket_id, None)
        if st is None or st.eta <= now:
            if st is not None:
                # I/O already done: land it anyway — paid in full, and a
                # resident fill is still a fill (the thief may never come,
                # or the bucket may return).
                self._land(st)
            return 0.0
        reclaimed = min(st.t_stage, st.eta - now)
        if st.future is not None:
            st.future.cancel()
        for other in self._inflight.values():
            if other.eta > st.eta:
                other.eta -= reclaimed
        self._io_free = max(now, self._io_free - reclaimed)
        self.canceled += 1
        self.reclaimed_s += reclaimed
        return reclaimed

    def note_serviced(self, decisions: Sequence) -> None:
        """Forward serviced buckets to the planner (sweep head advance +
        deferral resets)."""
        self.planner.note_serviced([d.bucket_id for d in decisions])

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def close(self) -> None:
        """Reap the worker threads.  Idempotent, and not terminal: the
        pool respawns lazily if more staging arrives (an engine reused
        after ``run()`` keeps working) — callers that drive ``round()``
        directly should close when done rather than leak workers for the
        engine's lifetime."""
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def _submit(self, bucket_id: int) -> Optional[Future]:
        if self._fetch is None:
            return None
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=self._workers)
        return self._pool.submit(self._fetch, bucket_id)

    # -- internals ---------------------------------------------------------------
    def _harvest(self, now: float) -> None:
        due = sorted(
            (st for st in self._inflight.values() if st.eta <= now),
            key=lambda st: st.eta,
        )
        for st in due:
            del self._inflight[st.bucket_id]
            self._land(st)

    def _land(self, st: _Stage) -> bool:
        result = self.cache.insert_prefetched(st.bucket_id, st.payload())
        if result is None:
            self.refused += 1
            return False
        self.fills += 1
        return True


def prefetch_stats(pipe: "PrefetchPipeline", cache) -> dict:
    """Rollup of one run's prefetch activity + the honest hit split
    (``SimResult.prefetch`` / the serving ``summary()['prefetch']``)."""
    return {
        "staged": pipe.staged,
        "fills": pipe.fills,
        "refused": pipe.refused,
        "demand_waits": pipe.demand_waits,
        "stall_s": pipe.stall_s,
        "canceled": pipe.canceled,
        "prefetch_hits": cache.stats.prefetch_hits,
        "demand_hits": cache.stats.demand_hits,
        "prefetch_unused": cache.stats.prefetch_unused,
    }


def build_pipeline(
    prefetch: Union[bool, PrefetchConfig],
    scheduler,
    cache,
    default_t_stage: Union[float, Callable[[int], float]],
    *,
    fetch: Optional[Callable[[int], object]] = None,
    layout_of: Optional[Callable[[int], float]] = None,
) -> Optional[PrefetchPipeline]:
    """Coerce an engine's ``prefetch=`` config value — ``False`` (off, the
    default everywhere), ``True`` (defaults), or a ``PrefetchConfig`` —
    into a wired planner + pipeline.  ``default_t_stage`` is the engine's
    staging cost (normally its cost model's ``T_b``; the serving engine
    passes a per-adapter callable); a config ``t_stage`` overrides it.

    Raises ``ValueError`` for a scheduler without ``peek_topk`` (e.g.
    round-robin): the planner would silently commit empty horizons every
    round — prefetch configured but staging nothing is a
    misconfiguration, not a mode."""
    if not prefetch:
        return None
    if not hasattr(scheduler, "peek_topk"):
        raise ValueError(
            f"prefetch requires a scheduler with peek_topk; "
            f"{type(scheduler).__name__} cannot be peeked"
        )
    cfg = prefetch if isinstance(prefetch, PrefetchConfig) else PrefetchConfig()
    planner = ScanPlanner(
        scheduler,
        ScanPlanConfig(
            horizon=cfg.horizon,
            starvation_deferrals=cfg.starvation_deferrals,
            # A config-level layout wins; the engine's catalog-derived
            # layout (caller kwarg) is the default sweep geometry.
            layout_of=cfg.layout_of or layout_of,
        ),
    )
    t_stage = cfg.t_stage if cfg.t_stage is not None else default_t_stage
    return PrefetchPipeline(
        cache, planner, t_stage, fetch=fetch, depth=cfg.depth,
        workers=cfg.workers,
    )

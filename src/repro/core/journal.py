"""Write-ahead decision journal + the shared trace-entry codec.

Two things live here because they share one schema:

* **Entry codec** (``encode_outcome`` / ``encode_steal`` / ``format_entry``
  / ``diff_entries`` / ``save_trace`` / ``load_trace``) — the plain-data
  serialization of a ``DispatchOutcome`` that the golden-trace harness
  (``tests/replay.py``) has recorded since PR 3.  Scores are float64 and
  survive JSON round-trips exactly (``repr`` shortest-round-trip), so a
  diff is a *bit* diff, not an approx one.  Promoting the codec out of the
  test tree means the goldens and the recovery journal are literally the
  same format: a journal segment's ``entry`` records can be diffed against
  a golden with the same ``diff_entries`` call the replay tests use.

* **``Journal``** — an append-only, segmented, JSON-lines write-ahead log
  of a service daemon's externally visible decisions: acked submissions,
  admission rejections, and per-round dispatch entries.  Appends flush to
  the OS on every record (a ``kill -9`` of the process loses at most the
  one record currently being written); submission acks additionally
  ``fsync`` so an ack implies durability.  Segments are fsync'd and closed
  at ``segment_bytes``; a restart never appends into an old segment, so a
  torn tail can only ever be the final line of the final segment — the
  reader drops exactly that line and raises ``JournalCorrupt`` on damage
  anywhere else.

Record shapes (one JSON object per line)::

    {"type": "open",   "schema": 1, "kind": "..."}          # segment header
    {"type": "submit", "key": "...", "item": {...}}         # durable ack
    {"type": "reject", "key": "...", "tenant": "...",
     "reason": "...", "observed": ..., "limit": ...}        # admission 429
    {"type": "entry",  "entry": {...}}                      # round or steal

``entry`` payloads are exactly the codec format, including the conditional
``stall`` / ``share_width`` / ``shard`` / ``steal`` keys.
"""
from __future__ import annotations

import json
import os
import pathlib
from time import perf_counter
from typing import Callable, Iterable, Optional

__all__ = [
    "TRACE_SCHEMA_VERSION",
    "encode_outcome",
    "encode_steal",
    "format_entry",
    "diff_entries",
    "save_trace",
    "load_trace",
    "Journal",
    "JournalCorrupt",
]

TRACE_SCHEMA_VERSION = 1


# --------------------------------------------------------------- entry codec
def encode_outcome(outcome, shard: Optional[int] = None) -> dict:
    """Serialize one ``DispatchOutcome`` into a plain-data trace entry.

    This is the golden-trace format: decisions (bucket id, score,
    residency, queue size), the applied ControlVector, the round cost, and
    spill transitions.  ``shard`` tags the entry with its originating
    shard id (sharded coordinators interleave rounds across shard-local
    loops, so the tag pins the interleaving)."""
    entry = {
        "decisions": [
            [
                int(d.bucket_id),
                float(d.score),
                bool(d.in_cache),
                int(d.queue_size),
            ]
            for d in outcome.decisions
        ],
        "cost": float(outcome.cost),
        "vector": [
            float(outcome.vector.alpha),
            int(outcome.vector.fuse_k),
            bool(outcome.vector.spill),
        ],
        "spill_changed": [int(b) for b in outcome.spill_changed],
    }
    # Residual prefetch stall: only emitted when nonzero, so goldens
    # recorded before the pipeline existed replay byte-identically (their
    # rounds never stall) while prefetch-on goldens pin it.
    stall = float(getattr(outcome, "stall", 0.0))
    if stall:
        entry["stall"] = stall
    # Shared-plan width: same conditional-emit discipline as ``stall`` —
    # goldens recorded before shared plans existed (share_width == 0 on
    # every round) replay byte-identically, while shared-plan-on goldens
    # pin the AIMD width trajectory.
    share_width = int(getattr(outcome.vector, "share_width", 0))
    if share_width:
        entry["share_width"] = share_width
    if shard is not None:
        entry["shard"] = int(shard)
    return entry


def encode_steal(ev) -> dict:
    """Serialize one ``StealEvent`` into its in-order trace entry."""
    return {
        "steal": [
            int(ev.bucket_id),
            int(ev.victim),
            int(ev.thief),
            int(ev.n_units),
        ]
    }


def format_entry(entry: dict) -> str:
    if "steal" in entry:
        b, v, t, n = entry["steal"]
        return f"steal b{b}: shard {v} -> shard {t} ({n} units)"
    ds = ", ".join(
        f"b{b}:s={s!r}:c={int(c)}:n={n}" for b, s, c, n in entry["decisions"]
    )
    a, k, sp = entry["vector"]
    shard = f" shard={entry['shard']}" if "shard" in entry else ""
    return (
        f"[{ds}] cost={entry['cost']!r}"
        f" vec=(a={a!r},k={k},spill={int(sp)}){shard}"
    )


def diff_entries(expect: list, got: list) -> list:
    """Structural diff of two decision logs.  Empty list == bit-identical.

    Each divergence names the round, the field, and both sides, so a
    regression reads as 'round 17: decisions expect [...] got [...]'
    instead of a bare assert."""
    out: list[str] = []
    if len(expect) != len(got):
        out.append(f"length: expect {len(expect)} rounds, got {len(got)}")
    for i, (e, g) in enumerate(zip(expect, got)):
        for field in (
            "decisions", "cost", "vector", "spill_changed", "stall",
            "share_width", "shard", "steal",
        ):
            if e.get(field) != g.get(field):
                out.append(
                    f"round {i} {field}:\n  expect {format_entry(e)}"
                    f"\n  got    {format_entry(g)}"
                )
                break
        if len(out) >= 5:  # enough context; don't flood
            out.append("... (further divergence suppressed)")
            break
    return out


def save_trace(path, entries: list, meta: Optional[dict] = None) -> None:
    doc = {
        "schema": TRACE_SCHEMA_VERSION,
        "meta": meta or {},
        "rounds": entries,
    }
    pathlib.Path(path).write_text(json.dumps(doc, indent=1) + "\n")


def load_trace(path) -> list:
    doc = json.loads(pathlib.Path(path).read_text())
    assert doc["schema"] == TRACE_SCHEMA_VERSION, doc["schema"]
    return doc["rounds"]


# --------------------------------------------------------------- journal WAL
class JournalCorrupt(RuntimeError):
    """A journal segment is damaged somewhere other than the final line of
    the final segment (which is the only place a crash can tear)."""


class Journal:
    """Append-only segmented JSON-lines write-ahead log.

    ``append`` writes one record and flushes it to the OS; pass
    ``sync=True`` on records whose durability is acked to a client (the
    submit/reject barrier) to force ``fsync``.  A fresh ``Journal`` over an
    existing directory never appends to prior segments — it opens a new
    one — so replay's torn-tail tolerance stays confined to the last line
    on disk at crash time."""

    _SEG_FMT = "seg-{:08d}.jsonl"

    def __init__(self, path, *, segment_bytes: int = 1 << 20,
                 kind: str = "journal") -> None:
        self.dir = pathlib.Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = int(segment_bytes)
        self.kind = kind
        segs = self.segments()
        self._seq = (
            int(segs[-1].stem.split("-", 1)[1]) + 1 if segs else 0
        )
        self._fh = None  # opened lazily on first append
        self.appended = 0
        # Optional latency tap: ``fn(record_type, total_s, fsync_s)`` with
        # ``fsync_s is None`` on unsynced appends.  Purely observational —
        # installed by ``repro.obs`` (Observability.attach_journal); when
        # None (the default) append takes no timestamps at all.
        self.obs_tap: Optional[Callable[[str, float, Optional[float]], None]] = None

    def segments(self) -> list:
        return sorted(self.dir.glob("seg-*.jsonl"))

    # -- writing -----------------------------------------------------------
    def append(self, record: dict, *, sync: bool = False) -> None:
        tap = self.obs_tap
        t0 = perf_counter() if tap is not None else 0.0
        line = json.dumps(record, separators=(",", ":")) + "\n"
        if self._fh is None or self._fh.tell() >= self.segment_bytes:
            self._rotate()
        self._fh.write(line)
        self._fh.flush()
        tf = perf_counter() if (tap is not None and sync) else 0.0
        if sync:
            os.fsync(self._fh.fileno())
        self.appended += 1
        if tap is not None:
            t1 = perf_counter()
            tap(record.get("type", ""), t1 - t0, (t1 - tf) if sync else None)

    def _rotate(self) -> None:
        self._close_segment()
        path = self.dir / self._SEG_FMT.format(self._seq)
        self._seq += 1
        self._fh = open(path, "a", encoding="utf-8")
        header = {"type": "open", "schema": TRACE_SCHEMA_VERSION,
                  "kind": self.kind}
        self._fh.write(json.dumps(header, separators=(",", ":")) + "\n")
        self._fh.flush()

    def _close_segment(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None

    def sync(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        self._close_segment()

    # -- reading -----------------------------------------------------------
    def replay(self) -> list:
        """All records across segments, in append order.

        The final line of the final segment may be torn by a crash
        mid-write; it is silently dropped (its record was never acked —
        ``append`` returns only after the full line is flushed).  Damage
        anywhere else raises :class:`JournalCorrupt`."""
        records: list[dict] = []
        segs = self.segments()
        for si, seg in enumerate(segs):
            text = seg.read_text(encoding="utf-8")
            lines = text.split("\n")
            if lines and lines[-1] == "":
                lines.pop()  # trailing newline, not a torn record
            for li, line in enumerate(lines):
                torn_ok = si == len(segs) - 1 and li == len(lines) - 1
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    if torn_ok:
                        break
                    raise JournalCorrupt(
                        f"{seg.name} line {li + 1}: undecodable record "
                        f"mid-journal"
                    ) from None
                if rec.get("type") == "open":
                    if rec.get("schema") != TRACE_SCHEMA_VERSION:
                        raise JournalCorrupt(
                            f"{seg.name}: schema {rec.get('schema')!r} != "
                            f"{TRACE_SCHEMA_VERSION}"
                        )
                    continue
                records.append(rec)
        return records

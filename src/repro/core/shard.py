"""Multi-shard execution tier: SFC-range partitioning, routing, stealing.

The paper's SkyQuery setting is a federation (§7 discusses scaling query
throughput across the data), and the production descendants (CasJobs, the
SDSS/NVO grid extension) partition multi-TB batch work across contexts.
Bucket scans are independent once routing is solved, so the data-driven
order parallelizes near-linearly.  This module is the tier that solves
routing:

* ``ShardMap`` — partitions the bucket space into S contiguous **SFC
  ranges** (bucket ids are the Partitioner's SFC-run order, so contiguous
  id ranges ARE contiguous HTM/Morton key ranges), balanced by a greedy
  heuristic over bucket *bytes* rather than bucket count.  Work stealing
  moves a bucket between shards via per-bucket overrides on top of the
  range map.
* ``ShardedDispatch`` — the coordinator: decomposes each query once
  (object indices stay valid against the original query arrays), routes
  the per-bucket slices to their owning shards
  (``WorkloadManager.submit_decomposed``), and joins per-shard
  completions — a query spanning shards completes at the **max** of its
  local completion clocks.  Each shard runs its own scheduler + cache +
  ``DispatchLoop`` over a pluggable in-process transport: the simulator
  drives shards on virtual clocks in deterministic (clock, shard_id)
  order; the cross-match engine wraps the same coordinator protocol with
  threads (``crossmatch.ShardedCrossMatch``).
* **Work stealing** — when a shard's pending bytes drain to the
  ``StealConfig`` low-water mark, it steals the victim's highest-utility
  *unstarted* bucket (the victim scheduler's own top pick): pending units
  migrate with their arrival times intact (the age term survives), the
  thief's clock advances to the newest stolen arrival (no acausal
  service), the victim's in-flight prefetch stage for the bucket cancels
  for its *residual* channel time, and the payload is cache-cold on the
  thief — the next service pays the full ``T_b`` read.  Completion
  bookkeeping moves with the units, so nothing is lost or double-counted.
* The **global control tier** (``ShardControlPlane``, core/control.py)
  waterfills the spill and prefetch byte budgets across shards from
  per-shard telemetry slices, exactly as the ``TenantControlPlane``
  waterfills across tenants; grants land as each loop's
  ``shard_grant`` override and each pipeline's staging byte cap.

The S=1 configuration is a pure refactor of the single-loop path — same
admit/idle-jump/round sequence, same executor arithmetic — which the
golden harness proves bit-identically (``tests/test_shard.py``).
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Callable, Mapping, Optional, Sequence

from .control import ShardControlPlane
from .workload import Query, WorkloadManager

__all__ = [
    "ShardMap",
    "StealConfig",
    "StealEvent",
    "ShardRuntime",
    "ShardedDispatch",
    "split_slots",
]


def split_slots(total: int, n_shards: int) -> list[int]:
    """Split ``total`` capacity slots across ``n_shards``, conserving the
    aggregate: the first ``total % n_shards`` shards get one extra slot
    (plain ``total // n_shards`` silently drops the remainder).  Each
    share is floored at 1 so every shard stays runnable — when
    ``total < n_shards`` the aggregate is inflated to ``n_shards``, the
    minimum that keeps all shards live."""
    n_shards = max(1, int(n_shards))
    total = int(total)
    base, rem = divmod(total, n_shards)
    return [max(1, base + (1 if s < rem else 0)) for s in range(n_shards)]


class ShardMap:
    """Bucket -> shard assignment: S contiguous SFC ranges + steal overrides.

    ``cuts`` holds the *last bucket id* of each shard but the final one
    (ascending); ``shard_of`` is a bisect over them, overridden per bucket
    for stolen buckets.  Bucket ids are the Partitioner's SFC-run order,
    so a contiguous id range is a contiguous HTM/Morton key range — the
    natural shard key the ROADMAP names.
    """

    def __init__(self, cuts: Sequence[int], n_shards: int) -> None:
        if n_shards < 1:
            raise ValueError(f"need at least one shard, got {n_shards}")
        if list(cuts) != sorted(cuts) or len(cuts) >= n_shards:
            raise ValueError(f"cuts must be < n_shards ascending ids: {cuts}")
        self.cuts = list(cuts)
        self.n_shards = int(n_shards)
        self.overrides: dict[int, int] = {}  # stolen buckets

    @classmethod
    def from_bucket_bytes(
        cls, bucket_bytes: Mapping[int, float], n_shards: int
    ) -> "ShardMap":
        """Greedy byte-balance heuristic: walk buckets in SFC order
        accumulating bytes, cutting each shard when the running total
        reaches its cumulative share ``(s+1) * total / S`` (or when
        exactly enough buckets remain to keep later shards nonempty).
        One pass, and each shard's byte load lands within one bucket of
        the even split."""
        ids = sorted(bucket_bytes)
        if n_shards < 1:
            raise ValueError(f"need at least one shard, got {n_shards}")
        total = float(sum(bucket_bytes.values()))
        target = total / n_shards if total > 0 else 0.0
        cuts: list[int] = []
        acc = 0.0
        s = 0
        for j, b in enumerate(ids):
            acc += float(bucket_bytes[b])
            remaining_buckets = len(ids) - j - 1
            remaining_shards = n_shards - s - 1
            if s < n_shards - 1 and (
                acc >= target * (s + 1) or remaining_buckets == remaining_shards
            ):
                cuts.append(b)
                s += 1
        return cls(cuts, n_shards)

    @classmethod
    def from_partitioner(cls, partitioner, n_shards: int) -> "ShardMap":
        """Byte-balanced map straight from a catalog ``Partitioner``."""
        return cls.from_bucket_bytes(
            {sp.bucket_id: float(sp.nbytes) for sp in partitioner.specs},
            n_shards,
        )

    @classmethod
    def uniform(cls, n_buckets: int, n_shards: int) -> "ShardMap":
        """Equal-count split (every bucket weighs 1.0)."""
        return cls.from_bucket_bytes({b: 1.0 for b in range(n_buckets)}, n_shards)

    def shard_of(self, bucket_id: int) -> int:
        override = self.overrides.get(bucket_id)
        if override is not None:
            return override
        return bisect.bisect_left(self.cuts, bucket_id)

    def reassign(self, bucket_id: int, shard: int) -> None:
        """Record a steal: the bucket now lives on ``shard`` — future
        query slices for it route there."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        if bisect.bisect_left(self.cuts, bucket_id) == shard:
            # Back on its home range: the override would be redundant.
            self.overrides.pop(bucket_id, None)
        else:
            self.overrides[bucket_id] = shard

    def shards(self) -> range:
        return range(self.n_shards)


@dataclasses.dataclass(frozen=True)
class StealConfig:
    """Work-stealing knobs.

    ``low_water_bytes`` — a shard whose pending probe bytes are at or
    below this attempts a steal (0.0: only when fully drained).
    ``min_victim_queues`` — a victim must keep at least this many
    nonempty queues *before* the steal (2 means the victim is never
    emptied by one).
    """

    low_water_bytes: float = 0.0
    min_victim_queues: int = 2


@dataclasses.dataclass(frozen=True)
class StealEvent:
    """One migration, as recorded in ``ShardedDispatch.steals`` and the
    golden traces' conditional ``"steals"`` key."""

    bucket_id: int
    victim: int
    thief: int
    n_units: int
    nbytes: float
    reclaimed_stage_s: float  # victim channel time returned by the cancel
    clock: float  # thief clock after the causality advance


@dataclasses.dataclass
class ShardRuntime:
    """One shard's local execution stack: its own scheduler + cache +
    WorkloadManager behind one shard-local DispatchLoop."""

    shard_id: int
    wm: WorkloadManager
    cache: object
    scheduler: object
    loop: object  # DispatchLoop


class ShardedDispatch:
    """The coordinator: routing, per-query joins, stealing, global grants.

    Construction order (the completion callbacks close over the
    coordinator): build the coordinator first, then each shard's
    ``DispatchLoop`` with ``complete=coord.make_complete(shard_id)``, then
    ``add_shard``.  ``run_virtual`` is the simulator transport — shards
    advance on their own virtual clocks, processed in deterministic
    (clock, shard_id) order; an engine transport (threads) drives the
    same ``deliver``/``maybe_steal``/round protocol itself.
    """

    def __init__(
        self,
        shard_map: ShardMap,
        decompose: Callable[[Query], dict[int, list[int]]],
        *,
        steal: Optional[StealConfig] = None,
        plane: Optional[ShardControlPlane] = None,
        on_steal: Optional[Callable[[StealEvent], None]] = None,
        on_round: Optional[Callable[[int, object], None]] = None,
    ) -> None:
        self.shard_map = shard_map
        self.decompose = decompose
        self.steal = steal
        self.plane = plane
        self.on_steal = on_steal
        self.on_round = on_round
        self.shards: dict[int, ShardRuntime] = {}
        self.queries: dict[int, Query] = {}
        self.owners: dict[int, set[int]] = {}  # qid -> shards still pending
        self.completed: dict[int, float] = {}  # qid -> global completion
        self._local_done: dict[int, float] = {}  # qid -> max local clock
        self._undelivered: dict[int, deque] = {}  # shard -> (query, slice)
        self.steals: list[StealEvent] = []

    # -- shard registration ------------------------------------------------------
    def add_shard(self, rt: ShardRuntime) -> None:
        if rt.shard_id in self.shards:
            raise ValueError(f"duplicate shard id {rt.shard_id}")
        self.shards[rt.shard_id] = rt
        self._undelivered[rt.shard_id] = deque()

    def make_complete(self, shard_id: int):
        """The ``DispatchLoop(complete=...)`` callback for one shard:
        complete each serviced bucket locally, then feed the queries whose
        *local* outstanding set emptied into the global join."""

        def complete(decisions, clock: float) -> None:
            rt = self.shards[shard_id]
            for d in decisions:
                for qid in rt.wm.complete_bucket(d.bucket_id, clock):
                    self._on_local_complete(shard_id, qid, clock)

        return complete

    def _on_local_complete(self, shard_id: int, qid: int, clock: float) -> None:
        owners = self.owners.get(qid)
        if owners is None:
            return
        owners.discard(shard_id)
        t = max(self._local_done.get(qid, clock), clock)
        self._local_done[qid] = t
        if not owners:
            # The join: done everywhere — the query's completion time is
            # the LAST shard's local completion (max over local clocks).
            self.completed[qid] = t

    # -- intake ------------------------------------------------------------------
    def route(self, query: Query) -> None:
        """Decompose once, slice by owning shard, queue the slices for
        delivery when each shard's clock reaches the arrival time."""
        per_bucket = self.decompose(query)
        slices: dict[int, dict[int, list]] = {}
        for b, idx in per_bucket.items():
            slices.setdefault(self.shard_map.shard_of(b), {})[b] = idx
        self.queries[query.query_id] = query
        if not slices:  # degenerate empty query completes on arrival
            self.completed[query.query_id] = query.arrival_time
            return
        self.owners[query.query_id] = set(slices)
        for sid, sl in slices.items():
            self._undelivered[sid].append((query, sl))

    def deliver(self, rt: ShardRuntime) -> None:
        """Hand the shard every routed slice that has arrived by its
        clock — the shard-local ``admit`` of the single-loop harness."""
        dq = self._undelivered[rt.shard_id]
        while dq and dq[0][0].arrival_time <= rt.loop.clock:
            q, sl = dq.popleft()
            rt.wm.submit_decomposed(q, sl)
            rt.loop.observe_arrival(q.arrival_time)

    # -- work stealing -----------------------------------------------------------
    def maybe_steal(self) -> list[StealEvent]:
        """One steal sweep: every shard at/below the low-water mark
        (ascending id — deterministic) steals the best victim's top
        bucket.  Returns the events (empty when nothing moved)."""
        cfg = self.steal
        if cfg is None or len(self.shards) < 2:
            return []
        events: list[StealEvent] = []
        for sid in sorted(self.shards):
            thief = self.shards[sid]
            self.deliver(thief)  # count anything already due first
            if thief.wm.pending_bytes() > cfg.low_water_bytes:
                continue
            victims = [
                v
                for v in self.shards.values()
                if v.shard_id != sid
                and len(v.wm.nonempty_queues()) >= cfg.min_victim_queues
            ]
            if not victims:
                continue
            victim = max(
                victims, key=lambda v: (v.wm.pending_bytes(), -v.shard_id)
            )
            bucket_id = self._victim_top_bucket(victim)
            if bucket_id is None:
                continue
            ev = self.steal_bucket(bucket_id, victim, thief)
            if ev is not None:
                events.append(ev)
        return events

    @staticmethod
    def _victim_top_bucket(victim: ShardRuntime) -> Optional[int]:
        """The victim's highest-utility unstarted bucket — its own
        scheduler's top pick (peeked, never suspended), falling back to
        the byte-heaviest queue for unpeekable schedulers."""
        peek = getattr(victim.scheduler, "peek_topk", None)
        if peek is not None:
            top = peek(victim.wm, victim.cache, victim.loop.clock, 1)
            return top[0].bucket_id if top else None
        queues = victim.wm.nonempty_queues()
        if not queues:
            return None
        return max(queues, key=lambda q: (q.nbytes, -q.bucket_id)).bucket_id

    def steal_bucket(
        self, bucket_id: int, victim: ShardRuntime, thief: ShardRuntime
    ) -> Optional[StealEvent]:
        """Migrate one bucket's pending units victim -> thief, honestly:

        * the victim's in-flight prefetch stage for the bucket cancels,
          reclaiming only the *residual* channel time (the spent part
          stays charged);
        * the thief's clock advances to the newest stolen arrival — it
          cannot service units before they arrived;
        * the payload is cache-cold on the thief: its next service pays
          the full ``T_b`` read (no residency teleports);
        * owner sets move with the units, so the join neither loses nor
          double-counts a completion.
        """
        units = victim.wm.migrate_out(bucket_id)
        if not units:
            return None
        if hasattr(victim.scheduler, "forget"):
            victim.scheduler.forget(bucket_id)
        reclaimed = 0.0
        pipe = getattr(victim.loop, "prefetch", None)
        if pipe is not None:
            reclaimed = pipe.cancel(bucket_id, victim.loop.clock)
        qids = sorted({u.query_id for u in units})
        qmap = {q: self.queries[q] for q in qids if q in self.queries}
        thief.wm.migrate_in(units, qmap)
        self.shard_map.reassign(bucket_id, thief.shard_id)
        newest = max(u.arrival_time for u in units)
        thief.loop.clock = max(thief.loop.clock, newest)
        for qid in qids:
            owners = self.owners.get(qid)
            if owners is None:
                continue
            owners.add(thief.shard_id)
            if qid not in victim.wm.outstanding and not self._qid_undelivered(
                victim.shard_id, qid
            ):
                owners.discard(victim.shard_id)
        ev = StealEvent(
            bucket_id=bucket_id,
            victim=victim.shard_id,
            thief=thief.shard_id,
            n_units=len(units),
            nbytes=float(sum(u.nbytes for u in units)),
            reclaimed_stage_s=reclaimed,
            clock=thief.loop.clock,
        )
        self.steals.append(ev)
        if self.on_steal is not None:
            self.on_steal(ev)
        return ev

    def _qid_undelivered(self, shard_id: int, qid: int) -> bool:
        return any(
            q.query_id == qid for q, _ in self._undelivered[shard_id]
        )

    # -- global control tier ------------------------------------------------------
    def apply_grants(self) -> None:
        """One arbitration round: waterfill the global spill/prefetch byte
        budgets over per-shard telemetry slices and park each shard's
        grant on its loop (consumed by the loop's next round) and its
        pipeline (staging byte cap)."""
        if self.plane is None:
            return
        tels = {
            sid: rt.loop.telemetry() for sid, rt in self.shards.items()
        }
        grants = self.plane.update(tels)
        for sid, rt in self.shards.items():
            g = grants.get(sid)
            rt.loop.shard_grant = g
            pipe = getattr(rt.loop, "prefetch", None)
            if pipe is not None:
                pipe.grant_bytes = g.prefetch_bytes if g is not None else None

    # -- the virtual-clock transport (simulator) ----------------------------------
    def run_virtual(self) -> None:
        """Drive every shard to completion on virtual clocks.

        Deterministic: the runnable shard with the smallest (clock,
        shard_id) rounds next.  With S=1 (and stealing/plane off) this
        reduces exactly to the single-loop harness's sequence — idle-jump
        to the next arrival, admit, round — which is the tentpole's
        bit-identity proof obligation.
        """
        shards = [self.shards[s] for s in sorted(self.shards)]
        while True:
            if self.steal is not None:
                self.maybe_steal()
            runnable = [rt for rt in shards if rt.wm.nonempty_queues()]
            if not runnable:
                waiting = [rt for rt in shards if self._undelivered[rt.shard_id]]
                if not waiting:
                    break  # drained everywhere, nothing left to route
                for rt in waiting:
                    # Idle: jump to the shard's next arrival (same move as
                    # the single-loop harness) and deliver it.
                    rt.loop.clock = max(
                        rt.loop.clock,
                        self._undelivered[rt.shard_id][0][0].arrival_time,
                    )
                    self.deliver(rt)
                continue
            rt = min(runnable, key=lambda r: (r.loop.clock, r.shard_id))
            self.deliver(rt)
            self.apply_grants()
            outcome = rt.loop.round()
            if outcome is not None and self.on_round is not None:
                self.on_round(rt.shard_id, outcome)

    # -- introspection -------------------------------------------------------------
    @property
    def n_pending_queries(self) -> int:
        return len(self.queries) - len(self.completed)

    def response_times(self) -> dict[int, float]:
        return {
            qid: t - self.queries[qid].arrival_time
            for qid, t in self.completed.items()
        }

    def makespan(self) -> float:
        return max((rt.loop.clock for rt in self.shards.values()), default=0.0)

"""repro.core — the paper's contribution: data-driven batch scheduling.

Public surface:
  * space-filling curves (``sfc``): HTM trixel ids, Morton codes
  * ``Partitioner``/``BucketStore``: equal-count bucket partitioning
  * ``WorkloadManager``: query pre-processing into per-bucket work units
  * ``SpillQueue``: the shared §6 resident-prefix/spilled-suffix queue
    primitive both engines' workload queues are built on (``spillq``)
  * ``CostModel`` + Eq.1/Eq.2 metrics
  * ``BucketCache``: LRU residency (phi in Eq. 1)
  * schedulers: ``LifeRaftScheduler`` (alpha in [0,1]), ``RoundRobinScheduler``
  * ``HybridPlanner``: scan-vs-indexed per-batch plan (paper §3.4)
  * ``AlphaController``: workload-adaptive alpha (paper §4)
  * ``ControlLoop``/``ControlVector``: the closed-loop control plane that
    drives alpha, fuse_k and §6 spill from live telemetry (``control``)
  * ``DispatchLoop``: the one scheduling inner loop shared by both engines
    and the simulator (``dispatch``)
  * ``ScanPlanner``/``PrefetchPipeline``: the scan-horizon prefetch
    subsystem — commit the scheduler's next-H buckets in elevator-sweep
    order and stage their I/O ahead of compute (``scanplan``/``prefetch``)
  * ``ShardMap``/``ShardedDispatch``: the multi-shard execution tier —
    SFC-range bucket partitioning, shard-local dispatch loops, work
    stealing, and the ``ShardControlPlane`` global byte arbiter
    (``shard``)
  * ``simulate``: the event-driven harness behind Figs. 7/8
"""
from .bucket import BucketSpec, BucketStore, Partitioner
from .cache import BucketCache, CacheOverflowError, CacheStats
from .hybrid import HybridCostModel, HybridPlanner, JoinPlan
from .metrics import (
    PAPER_COST_MODEL,
    CostModel,
    aged_workload_throughput,
    dispatch_stats,
    per_tenant_latency,
    workload_throughput,
)
from .adaptive import AlphaController, SaturationEstimator, TradeoffPoint, TradeoffTable
from .control import (
    AdmissionController,
    AdmissionQuota,
    AdmissionRejected,
    ControlConfig,
    ControlLoop,
    ControlVector,
    ShardControlPlane,
    ShardGrant,
    Telemetry,
    TenantControlPlane,
    TenantPolicy,
    apply_spill,
    unspill_price,
    waterfill,
)
from .journal import (
    TRACE_SCHEMA_VERSION,
    Journal,
    JournalCorrupt,
    diff_entries,
    encode_outcome,
    encode_steal,
    format_entry,
    load_trace,
    save_trace,
)
from .dispatch import DispatchLoop, DispatchOutcome
from .prefetch import PrefetchConfig, PrefetchPipeline, build_pipeline
from .scanplan import ScanPlanConfig, ScanPlanner
from .scheduler import (
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
    OrderedScheduler,
    RoundRobinScheduler,
    SchedulerDecision,
)
from .shard import (
    ShardMap,
    ShardRuntime,
    ShardedDispatch,
    StealConfig,
    StealEvent,
    split_slots,
)
from .simulate import (
    SimResult,
    run_policy,
    simulate_batched,
    simulate_noshare,
    simulate_sharded,
)
from .spillq import SpillQueue
from .workload import Query, WorkloadManager, WorkloadQueue, WorkUnit
from . import sfc

__all__ = [
    "BucketSpec",
    "BucketStore",
    "Partitioner",
    "BucketCache",
    "CacheOverflowError",
    "CacheStats",
    "HybridCostModel",
    "HybridPlanner",
    "JoinPlan",
    "PAPER_COST_MODEL",
    "CostModel",
    "aged_workload_throughput",
    "dispatch_stats",
    "per_tenant_latency",
    "workload_throughput",
    "AlphaController",
    "SaturationEstimator",
    "TradeoffPoint",
    "TradeoffTable",
    "AdmissionController",
    "AdmissionQuota",
    "AdmissionRejected",
    "ControlConfig",
    "ControlLoop",
    "ControlVector",
    "Telemetry",
    "ShardControlPlane",
    "ShardGrant",
    "TenantControlPlane",
    "TenantPolicy",
    "apply_spill",
    "unspill_price",
    "waterfill",
    "SpillQueue",
    "DispatchLoop",
    "DispatchOutcome",
    "PrefetchConfig",
    "PrefetchPipeline",
    "build_pipeline",
    "ScanPlanConfig",
    "ScanPlanner",
    "LifeRaftScheduler",
    "NaiveLifeRaftScheduler",
    "OrderedScheduler",
    "RoundRobinScheduler",
    "SchedulerDecision",
    "ShardMap",
    "ShardRuntime",
    "ShardedDispatch",
    "StealConfig",
    "StealEvent",
    "split_slots",
    "TRACE_SCHEMA_VERSION",
    "Journal",
    "JournalCorrupt",
    "diff_entries",
    "encode_outcome",
    "encode_steal",
    "format_entry",
    "load_trace",
    "save_trace",
    "SimResult",
    "run_policy",
    "simulate_batched",
    "simulate_noshare",
    "simulate_sharded",
    "Query",
    "WorkloadManager",
    "WorkloadQueue",
    "WorkUnit",
    "sfc",
]

"""Workload-throughput and aged-workload-throughput metrics (paper §3.2-3.3).

Eq. 1:  U_t(i) = |W_i| / (T_b * phi(i) + T_m * |W_i| + T_spill * sigma(i))
Eq. 2:  U_a(i) = U_t(i) * (1 - alpha) + A(i) * alpha

with |W_i| the bucket's pending-object count, T_b the bucket read cost,
T_m the per-object match cost, phi(i) = 0 iff the bucket is cached,
sigma(i) = 1 iff the bucket's workload has been spilled to host (§6
workload overflow: spilled queues pay a read-back surcharge, so they are
deprioritized until their age term reclaims them), and A(i) the age (ms)
of the oldest pending request.

The paper combines U_t (objects/sec) and A (ms) on raw scales; we reproduce
that faithfully (``normalized=False``) and additionally offer a
scale-normalized blend (``normalized=True``).  Normalization used to divide
each term by its max over the candidate set, which coupled every score
through two global maxima and forced the scheduler back to O(B) rescans.
It is now *monotone rebased*: U_t is divided by its supremum 1/T_m (so the
throughput term lands in (0, 1]) and A by the fixed ``age_scale_ms``
horizon — both are per-bucket quantities, so argmax U_a still admits a
now-independent rebased key and the incremental heap path applies
(docs/perf.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

__all__ = ["CostModel", "workload_throughput", "aged_workload_throughput", "PAPER_COST_MODEL"]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Empirical cost constants (paper §5: T_b=1.2 s, T_m=0.13 ms on SDSS).

    For the TPU serving instantiation these are derived from the roofline:
    T_b = bucket_bytes / HBM_bw (state residency cost) and
    T_m = max(flops/peak, bytes/bw) per request.

    ``T_spill`` is the §6 overflow read-back surcharge a spilled workload
    queue pays on top of the bucket read (0 disables the score effect).
    ``age_scale_ms`` is the fixed age-normalization horizon used by
    ``normalized=True`` scoring.
    """

    T_b: float = 1.2  # seconds to read one bucket from backing store
    T_m: float = 0.13e-3  # seconds to match one object in memory
    T_spill: float = 0.0  # seconds to page a spilled workload queue back in
    age_scale_ms: float = 1e3  # normalized=True age horizon (ms)

    def batch_cost(
        self, queue_size: int, in_cache: bool, spilled: bool = False
    ) -> float:
        """Wall-clock cost of servicing one bucket batch (denominator of Eq. 1)."""
        cost = self.T_b * (0.0 if in_cache else 1.0) + self.T_m * queue_size
        if spilled:
            cost += self.T_spill
        return cost


PAPER_COST_MODEL = CostModel(T_b=1.2, T_m=0.13e-3)


def workload_throughput(
    queue_size: int, in_cache: bool, cost: CostModel, spilled: bool = False
) -> float:
    """Eq. 1 — objects consumed per second if this bucket is scheduled now."""
    if queue_size <= 0:
        return 0.0
    return queue_size / cost.batch_cost(queue_size, in_cache, spilled)


def aged_workload_throughput(
    queue_sizes: Mapping[int, int],
    ages_ms: Mapping[int, float],
    cached: Mapping[int, bool],
    cost: CostModel,
    alpha: float,
    normalized: bool = False,
    spilled: Optional[Mapping[int, bool]] = None,
) -> dict[int, float]:
    """Eq. 2 for every candidate bucket; returns {bucket_id: U_a}.

    ``alpha`` = 0 -> pure greedy (most contentious data first);
    ``alpha`` = 1 -> arrival order (oldest request first), I/O sharing intact.

    NOTE: the ``normalized=True`` arithmetic below (multiply by ``cost.T_m``
    and by the reciprocal of ``cost.age_scale_ms``, then blend) is the
    oracle expression the incremental scheduler's finalist re-rank
    reproduces term for term — keep them in lockstep or decision
    bit-identity breaks (see ``LifeRaftScheduler._select_one``).
    """
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0,1], got {alpha}")
    ut = {
        b: workload_throughput(
            n,
            bool(cached.get(b, False)),
            cost,
            bool(spilled.get(b, False)) if spilled else False,
        )
        for b, n in queue_sizes.items()
    }
    age = {b: float(ages_ms.get(b, 0.0)) for b in queue_sizes}
    if normalized:
        inv_age = 1.0 / cost.age_scale_ms
        ut = {b: v * cost.T_m for b, v in ut.items()}
        age = {b: v * inv_age for b, v in age.items()}
    return {b: ut[b] * (1.0 - alpha) + age[b] * alpha for b in queue_sizes}

"""Workload-throughput and aged-workload-throughput metrics (paper §3.2-3.3).

Eq. 1:  U_t(i) = |W_i| / (T_b * phi(i) + T_m * |W_i| + T_spill * sigma(i))
Eq. 2:  U_a(i) = U_t(i) * (1 - alpha_i) + A(i) * alpha_i

with |W_i| the bucket's pending-object count, T_b the bucket read cost,
T_m the per-object match cost, phi(i) = 0 iff the bucket is cached,
sigma(i) in [0, 1] the *fraction* of the bucket's workload bytes spilled
to host (§6 workload overflow: a spilled workload pays a pro-rated
read-back surcharge, so it is deprioritized until its age term reclaims
it; whole-queue spill is the sigma = 1 special case and reproduces the
historical boolean semantics bit for bit), and A(i) the age (ms) of the
oldest pending request.  ``alpha_i`` is per-bucket when the multi-tenant
control plane is active (each tenant class runs its own alpha law) and
the scalar Eq. 2 blend otherwise.

The paper combines U_t (objects/sec) and A (ms) on raw scales; we reproduce
that faithfully (``normalized=False``) and additionally offer a
scale-normalized blend (``normalized=True``).  Normalization used to divide
each term by its max over the candidate set, which coupled every score
through two global maxima and forced the scheduler back to O(B) rescans.
It is now *monotone rebased*: U_t is divided by its supremum 1/T_m (so the
throughput term lands in (0, 1]) and A by the fixed ``age_scale_ms``
horizon — both are per-bucket quantities, so argmax U_a still admits a
now-independent rebased key and the incremental heap path applies
(docs/perf.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping, Optional, Union

__all__ = [
    "CostModel",
    "workload_throughput",
    "aged_workload_throughput",
    "per_tenant_latency",
    "dispatch_stats",
    "PAPER_COST_MODEL",
]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Empirical cost constants (paper §5: T_b=1.2 s, T_m=0.13 ms on SDSS).

    For the TPU serving instantiation these are derived from the roofline:
    T_b = bucket_bytes / HBM_bw (state residency cost) and
    T_m = max(flops/peak, bytes/bw) per request.

    ``T_spill`` is the §6 overflow read-back surcharge a *fully* spilled
    workload queue pays on top of the bucket read (0 disables the score
    effect); a partially spilled queue pays it pro-rated by its spilled
    byte fraction sigma.  ``age_scale_ms`` is the fixed age-normalization
    horizon used by ``normalized=True`` scoring.  ``probe_bytes`` is the
    size of one pending probe object's host-side state — the §6 overflow
    budget is denominated in these actual bytes, not object counts — and
    ``min_unit_bytes`` floors each pending unit's price (>= 1 byte by
    default) so degenerate units (e.g. zero-length serving prompts)
    cannot free-ride the budget and sigma at zero cost.
    """

    T_b: float = 1.2  # seconds to read one bucket from backing store
    T_m: float = 0.13e-3  # seconds to match one object in memory
    T_spill: float = 0.0  # seconds to page a fully spilled queue back in
    age_scale_ms: float = 1e3  # normalized=True age horizon (ms)
    probe_bytes: float = 1.0  # bytes of spillable state per pending object
    min_unit_bytes: float = 1.0  # floor per pending unit (§6 budget currency)

    def batch_cost(
        self, queue_size: int, in_cache: bool,
        spilled: Union[bool, float] = False,
    ) -> float:
        """Wall-clock cost of servicing one bucket batch (denominator of
        Eq. 1).  ``spilled`` is the spilled byte fraction sigma in [0, 1];
        booleans are accepted for the legacy whole-queue semantics (True
        multiplies by exactly 1.0, so scores are bit-identical)."""
        cost = self.T_b * (0.0 if in_cache else 1.0) + self.T_m * queue_size
        if spilled:
            cost += self.T_spill * float(spilled)
        return cost


PAPER_COST_MODEL = CostModel(T_b=1.2, T_m=0.13e-3)


def workload_throughput(
    queue_size: int, in_cache: bool, cost: CostModel,
    spilled: Union[bool, float] = False,
) -> float:
    """Eq. 1 — objects consumed per second if this bucket is scheduled now.

    ``spilled`` is the spilled byte fraction sigma (bool == legacy whole-
    queue semantics, numerically identical to sigma = 1.0)."""
    if queue_size <= 0:
        return 0.0
    return queue_size / cost.batch_cost(queue_size, in_cache, spilled)


def aged_workload_throughput(
    queue_sizes: Mapping[int, int],
    ages_ms: Mapping[int, float],
    cached: Mapping[int, bool],
    cost: CostModel,
    alpha: float,
    normalized: bool = False,
    spilled: Optional[Mapping[int, Union[bool, float]]] = None,
    alpha_by_bucket: Optional[Mapping[int, float]] = None,
) -> dict[int, float]:
    """Eq. 2 for every candidate bucket; returns {bucket_id: U_a}.

    ``alpha`` = 0 -> pure greedy (most contentious data first);
    ``alpha`` = 1 -> arrival order (oldest request first), I/O sharing intact.
    ``alpha_by_bucket`` overrides the scalar per bucket — the multi-tenant
    control plane's per-tenant alpha laws land here (a bucket owned by the
    interactive tenant class blends with that tenant's alpha while a batch
    bucket in the same candidate set blends with its own).
    ``spilled`` maps bucket -> sigma, the spilled byte fraction (bools
    accepted for whole-queue legacy semantics).

    NOTE: the ``normalized=True`` arithmetic below (multiply by ``cost.T_m``
    and by the reciprocal of ``cost.age_scale_ms``, then blend) is the
    oracle expression the incremental scheduler's finalist re-rank
    reproduces term for term — keep them in lockstep or decision
    bit-identity breaks (see ``LifeRaftScheduler._select_one``).
    """
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0,1], got {alpha}")
    ut = {
        b: workload_throughput(
            n,
            bool(cached.get(b, False)),
            cost,
            spilled.get(b, False) if spilled else False,
        )
        for b, n in queue_sizes.items()
    }
    age = {b: float(ages_ms.get(b, 0.0)) for b in queue_sizes}
    if normalized:
        inv_age = 1.0 / cost.age_scale_ms
        ut = {b: v * cost.T_m for b, v in ut.items()}
        age = {b: v * inv_age for b, v in age.items()}
    if alpha_by_bucket is None:
        return {b: ut[b] * (1.0 - alpha) + age[b] * alpha for b in queue_sizes}
    out = {}
    for b in queue_sizes:
        a = float(alpha_by_bucket.get(b, alpha))
        if not 0.0 <= a <= 1.0:
            raise ValueError(f"alpha[{b}] must be in [0,1], got {a}")
        out[b] = ut[b] * (1.0 - a) + age[b] * a
    return out


def dispatch_stats(loop) -> dict[str, float]:
    """Device-dispatch rollup for a DispatchLoop — the shared-plan win
    surface: ``device_dispatches`` counts actual kernel launches (a shared
    plan issues fewer than one per bucket or per predicate class) and
    ``shared_batch_occupancy`` the mean query fill of the shared calls."""
    return {
        "batches": int(loop.batches),
        "dispatches": int(loop.dispatches),
        "device_dispatches": int(getattr(loop, "device_dispatches", 0)),
        "shared_batch_occupancy": float(
            getattr(loop, "shared_batch_occupancy", 0.0)
        ),
    }


def per_tenant_latency(
    response_s: Mapping[int, float],
    tenant_of: Union[Mapping[int, str], Callable[[int], str]],
    makespan: float,
    tenants: Iterable[str] = (),
) -> dict[str, dict]:
    """Per-tenant-class latency/throughput rollup over completed queries.

    ``response_s`` maps query/request id -> response seconds;
    ``tenant_of`` maps the id to its tenant class (mapping or callable).
    Returns ``{tenant: {n, p50_response, p95_response, mean_response,
    throughput}}`` — the per-class SLO surface the multi-tenant control
    plane is steering (interactive p95 vs batch throughput).  ``tenants``
    seeds classes that should appear even with zero completions.

    A tenant with **no completed queries** reports ``n=0`` and ``None``
    for every latency stat — a slice with nothing in it has no latency,
    and reporting 0.0 made it indistinguishable from true zero latency
    (summaries must skip or surface it, never average it in).
    """
    import numpy as np

    lookup = tenant_of if callable(tenant_of) else (
        lambda qid: tenant_of.get(qid, "default")  # type: ignore[union-attr]
    )
    groups: dict[str, list[float]] = {t: [] for t in tenants}
    for qid, resp in response_s.items():
        groups.setdefault(lookup(qid), []).append(float(resp))
    makespan = max(makespan, 1e-9)
    out = {}
    for tenant, resp in sorted(groups.items()):
        if not resp:
            out[tenant] = {
                "n": 0,
                "p50_response": None,
                "p95_response": None,
                "max_response": None,
                "mean_response": None,
                "throughput": 0.0,
            }
            continue
        arr = np.asarray(sorted(resp), dtype=np.float64)
        out[tenant] = {
            "n": int(len(arr)),
            "p50_response": float(np.percentile(arr, 50)),
            "p95_response": float(np.percentile(arr, 95)),
            "max_response": float(arr[-1]),
            "mean_response": float(arr.mean()),
            "throughput": len(arr) / makespan,
        }
    return out

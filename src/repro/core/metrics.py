"""Workload-throughput and aged-workload-throughput metrics (paper §3.2-3.3).

Eq. 1:  U_t(i) = |W_i| / (T_b * phi(i) + T_m * |W_i|)
Eq. 2:  U_a(i) = U_t(i) * (1 - alpha) + A(i) * alpha

with |W_i| the bucket's pending-object count, T_b the bucket read cost,
T_m the per-object match cost, phi(i) = 0 iff the bucket is cached, and
A(i) the age (ms) of the oldest pending request.

The paper combines U_t (objects/sec) and A (ms) on raw scales; we reproduce
that faithfully (``normalized=False``) and additionally offer a
scale-normalized blend (``normalized=True``) that divides each term by its
max over the candidate set — useful when T_b/T_m differ by orders of
magnitude from the paper's disk constants (e.g. HBM-derived costs).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

__all__ = ["CostModel", "workload_throughput", "aged_workload_throughput", "PAPER_COST_MODEL"]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Empirical cost constants (paper §5: T_b=1.2 s, T_m=0.13 ms on SDSS).

    For the TPU serving instantiation these are derived from the roofline:
    T_b = bucket_bytes / HBM_bw (state residency cost) and
    T_m = max(flops/peak, bytes/bw) per request.
    """

    T_b: float = 1.2  # seconds to read one bucket from backing store
    T_m: float = 0.13e-3  # seconds to match one object in memory

    def batch_cost(self, queue_size: int, in_cache: bool) -> float:
        """Wall-clock cost of servicing one bucket batch (denominator of Eq. 1)."""
        return self.T_b * (0.0 if in_cache else 1.0) + self.T_m * queue_size


PAPER_COST_MODEL = CostModel(T_b=1.2, T_m=0.13e-3)


def workload_throughput(queue_size: int, in_cache: bool, cost: CostModel) -> float:
    """Eq. 1 — objects consumed per second if this bucket is scheduled now."""
    if queue_size <= 0:
        return 0.0
    return queue_size / cost.batch_cost(queue_size, in_cache)


def aged_workload_throughput(
    queue_sizes: Mapping[int, int],
    ages_ms: Mapping[int, float],
    cached: Mapping[int, bool],
    cost: CostModel,
    alpha: float,
    normalized: bool = False,
) -> dict[int, float]:
    """Eq. 2 for every candidate bucket; returns {bucket_id: U_a}.

    ``alpha`` = 0 -> pure greedy (most contentious data first);
    ``alpha`` = 1 -> arrival order (oldest request first), I/O sharing intact.
    """
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0,1], got {alpha}")
    ut = {
        b: workload_throughput(n, bool(cached.get(b, False)), cost)
        for b, n in queue_sizes.items()
    }
    age = {b: float(ages_ms.get(b, 0.0)) for b in queue_sizes}
    if normalized:
        mu = max(ut.values(), default=0.0) or 1.0
        ma = max(age.values(), default=0.0) or 1.0
        ut = {b: v / mu for b, v in ut.items()}
        age = {b: v / ma for b, v in age.items()}
    return {b: ut[b] * (1.0 - alpha) + age[b] * alpha for b in queue_sizes}

"""Workload-adaptive alpha selection (paper §4, Figs. 4 & 8).

The paper derives throughput-vs-response trade-off curves offline for a set
of saturation levels (queries/sec), then at run time: (1) estimate current
saturation, (2) look up the nearest curve, (3) pick the alpha that minimizes
response time subject to throughput >= (1 - tolerance) * max_throughput.

``SaturationEstimator`` is an EWMA over inter-arrival gaps;
``TradeoffTable`` stores the offline curves (built by
``benchmarks/fig8_tradeoff.py`` or user traces); ``AlphaController`` glues
them together and is what the engines consult between batches.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Sequence

__all__ = ["SaturationEstimator", "TradeoffPoint", "TradeoffTable", "AlphaController"]


class SaturationEstimator:
    """EWMA arrival-rate estimator (queries/second)."""

    def __init__(self, halflife_s: float = 60.0, initial_rate: float = 0.0):
        self.halflife_s = halflife_s
        self._rate = initial_rate
        self._last: float | None = None

    def observe_arrival(self, t: float) -> float:
        if self._last is not None:
            gap = max(t - self._last, 1e-9)
            inst = 1.0 / gap
            w = 1.0 - math.exp(-math.log(2.0) * gap / self.halflife_s)
            self._rate += w * (inst - self._rate)
        self._last = t
        return self._rate

    @property
    def rate(self) -> float:
        return self._rate


@dataclasses.dataclass(frozen=True)
class TradeoffPoint:
    alpha: float
    throughput: float  # queries/sec (absolute, normalized internally)
    response: float  # mean response seconds


class TradeoffTable:
    """{saturation -> [TradeoffPoint...]} measured offline (Fig. 4/8)."""

    def __init__(self) -> None:
        self._curves: dict[float, list[TradeoffPoint]] = {}

    def add(self, saturation: float, points: Sequence[TradeoffPoint]) -> None:
        self._curves[float(saturation)] = sorted(points, key=lambda p: p.alpha)

    def saturations(self) -> list[float]:
        return sorted(self._curves)

    def curve(self, saturation: float) -> list[TradeoffPoint]:
        """Curve at the nearest measured saturation."""
        sats = self.saturations()
        if not sats:
            raise ValueError("empty trade-off table")
        i = bisect.bisect_left(sats, saturation)
        if i == 0:
            return self._curves[sats[0]]
        if i == len(sats):
            return self._curves[sats[-1]]
        lo, hi = sats[i - 1], sats[i]
        return self._curves[lo if saturation - lo <= hi - saturation else hi]

    def select_alpha(self, saturation: float, tolerance: float) -> float:
        """Paper §4: min response s.t. throughput >= (1-tol)*max_throughput."""
        pts = self.curve(saturation)
        tmax = max(p.throughput for p in pts)
        ok = [p for p in pts if p.throughput >= (1.0 - tolerance) * tmax]
        best = min(ok, key=lambda p: (p.response, p.alpha))
        return best.alpha


class AlphaController:
    """Run-time alpha adaptation: saturation EWMA -> table lookup.

    ``update_on_arrival`` is O(1); the chosen alpha changes incrementally
    (rate-limited by ``max_step``) so the scheduler shifts *gradually*
    between in-order and data-driven processing, per the paper's
    "adaptively and incrementally trades-off" framing.
    """

    def __init__(
        self,
        table: TradeoffTable,
        tolerance: float = 0.2,
        halflife_s: float = 60.0,
        initial_alpha: float = 0.5,
        max_step: float = 0.1,
    ) -> None:
        self.table = table
        self.tolerance = tolerance
        self.estimator = SaturationEstimator(halflife_s)
        self.alpha = initial_alpha
        self.max_step = max_step

    def update_on_arrival(self, t: float) -> float:
        rate = self.estimator.observe_arrival(t)
        try:
            target = self.table.select_alpha(rate, self.tolerance)
        except ValueError:
            return self.alpha
        delta = max(-self.max_step, min(self.max_step, target - self.alpha))
        self.alpha += delta
        return self.alpha

"""Bucket cache (paper §4: LRU, fixed capacity — 20 buckets in §5).

The cache is managed by the framework, independent of any lower-level
buffer pool, exactly as the paper flushes SQL Server's buffers and manages
bucket residency itself.  phi(i) in Eq. 1 is ``0 if cache.contains(i)``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Hashable, Iterable, Optional

__all__ = ["CacheStats", "BucketCache"]


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0


class BucketCache:
    """LRU cache over bucket ids (payloads optional).

    ``capacity`` counts buckets (uniform size by construction, §3.1), so
    LRU over ids is exact.  ``pin``/``unpin`` support batches in flight.
    """

    def __init__(self, capacity: int = 20) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: OrderedDict[Hashable, object] = OrderedDict()
        self._pinned: set[Hashable] = set()
        self.stats = CacheStats()
        self._listeners: list[Callable[[Hashable], None]] = []

    # -- change notification -------------------------------------------------
    def subscribe(self, fn: Callable[[Hashable], None]) -> Callable[[Hashable], None]:
        """Register ``fn(bucket_id)`` to fire whenever a bucket's *residency*
        changes (insert or eviction) — phi(i) in Eq. 1 flipped for that id."""
        self._listeners.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[Hashable], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def _notify(self, bucket_id: Hashable) -> None:
        for fn in self._listeners:
            fn(bucket_id)

    def contains(self, bucket_id: Hashable) -> bool:
        """Residency probe — does NOT count as an access or touch LRU."""
        return bucket_id in self._entries

    def access(self, bucket_id: Hashable, payload: object = None) -> list[Hashable]:
        """Record an access; insert on miss. Returns ids evicted (if any)."""
        evicted: list[Hashable] = []
        if bucket_id in self._entries:
            self.stats.hits += 1
            self._entries.move_to_end(bucket_id)
            if payload is not None:
                self._entries[bucket_id] = payload
            return evicted
        self.stats.misses += 1
        self._entries[bucket_id] = payload
        self._entries.move_to_end(bucket_id)
        self._notify(bucket_id)
        while len(self._entries) > self.capacity:
            victim = self._pick_victim()
            if victim is None:  # everything pinned; allow overflow
                break
            self._entries.pop(victim)
            self.stats.evictions += 1
            evicted.append(victim)
            self._notify(victim)
        return evicted

    def _pick_victim(self) -> Optional[Hashable]:
        for k in self._entries:  # OrderedDict: LRU first
            if k not in self._pinned:
                return k
        return None

    def note_bypass_miss(self) -> None:
        """Record a read that bypassed residency (an indexed cold read):
        counts as a miss in hit_rate without inserting or evicting."""
        self.stats.misses += 1

    def get(self, bucket_id: Hashable) -> object:
        return self._entries.get(bucket_id)

    def pin(self, bucket_id: Hashable) -> None:
        self._pinned.add(bucket_id)

    def unpin(self, bucket_id: Hashable) -> None:
        self._pinned.discard(bucket_id)

    def invalidate(self, bucket_ids: Iterable[Hashable]) -> None:
        for b in bucket_ids:
            if b in self._entries:
                self._entries.pop(b)
                self._notify(b)

    def resident(self) -> list[Hashable]:
        return list(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

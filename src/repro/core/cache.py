"""Bucket cache (paper §4: LRU, fixed capacity — 20 buckets in §5).

The cache is managed by the framework, independent of any lower-level
buffer pool, exactly as the paper flushes SQL Server's buffers and manages
bucket residency itself.  phi(i) in Eq. 1 is ``0 if cache.contains(i)``.

The scan-horizon prefetch pipeline (``core/prefetch.py``) made admission
and eviction *demand-aware*:

* ``insert_prefetched`` establishes residency ahead of demand without
  counting an access — the fill is tallied separately
  (``CacheStats.prefetch_fills``) so the hit rate stays an honest demand
  statistic, and the first demand touch of a prefetched entry is split
  out as ``prefetch_hits`` (hits the pipeline manufactured, not locality
  the workload exhibited);
* ``protect`` shields the committed horizon from eviction — evicting a
  bucket that is about to be serviced would turn the prefetch into pure
  waste (the victim walk never picks a protected or pinned entry);
* with a demand probe installed (``set_demand_probe``), the victim walk
  prefers buckets with *zero pending demand* — a resident bucket nobody
  is waiting on is a strictly better victim than one with queued work,
  whatever their LRU order says.

All of it is inert unless a prefetch pipeline wires it up: no protected
set, no demand probe, and no prefetch fills means ``access`` behaves
bit-for-bit as the reactive LRU it always was.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Hashable, Iterable, Optional

__all__ = ["CacheStats", "BucketCache", "CacheOverflowError"]


class CacheOverflowError(RuntimeError):
    """An insert needed a victim but every resident bucket is pinned.

    Historically the cache let residency exceed ``capacity`` silently in
    this case; over-pinning is a caller bug (pins outlive the batch that
    took them) and is now surfaced instead of absorbed.
    """


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    # -- prefetch split (all zero without a prefetch pipeline) ---------------
    prefetch_fills: int = 0  # residencies established ahead of demand
    prefetch_hits: int = 0  # first demand touch of a prefetched entry
    prefetch_unused: int = 0  # prefetched entries evicted untouched (waste)

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    @property
    def demand_hits(self) -> int:
        """Hits the workload's own locality produced (LRU would have had
        them too) — ``hits`` minus the ones the pipeline manufactured."""
        return self.hits - self.prefetch_hits


class BucketCache:
    """LRU cache over bucket ids (payloads optional).

    ``capacity`` counts buckets (uniform size by construction, §3.1), so
    LRU over ids is exact.  ``pin``/``unpin`` support batches in flight.
    """

    def __init__(self, capacity: int = 20) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: OrderedDict[Hashable, object] = OrderedDict()
        self._pinned: set[Hashable] = set()
        self._protected: set[Hashable] = set()  # committed prefetch horizon
        self._prefetched: set[Hashable] = set()  # filled, not demand-touched
        self._demand_of: Optional[Callable[[Hashable], int]] = None
        self.stats = CacheStats()
        self._listeners: list[Callable[[Hashable], None]] = []

    # -- change notification -------------------------------------------------
    def subscribe(self, fn: Callable[[Hashable], None]) -> Callable[[Hashable], None]:
        """Register ``fn(bucket_id)`` to fire whenever a bucket's *residency*
        changes (insert or eviction) — phi(i) in Eq. 1 flipped for that id."""
        self._listeners.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[Hashable], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def _notify(self, bucket_id: Hashable) -> None:
        for fn in self._listeners:
            fn(bucket_id)

    def contains(self, bucket_id: Hashable) -> bool:
        """Residency probe — does NOT count as an access or touch LRU."""
        return bucket_id in self._entries

    def access(self, bucket_id: Hashable, payload: object = None) -> list[Hashable]:
        """Record a demand access; insert on miss. Returns ids evicted (if
        any).  Raises :class:`CacheOverflowError` when the insert needs a
        victim and every resident bucket is pinned (over-pinning used to
        overflow capacity silently)."""
        evicted: list[Hashable] = []
        if bucket_id in self._entries:
            self.stats.hits += 1
            if bucket_id in self._prefetched:
                # First demand touch of a prefetched fill: the pipeline
                # manufactured this hit; split it out of the locality story.
                self._prefetched.discard(bucket_id)
                self.stats.prefetch_hits += 1
            self._entries.move_to_end(bucket_id)
            if payload is not None:
                self._entries[bucket_id] = payload
            return evicted
        self.stats.misses += 1
        self._entries[bucket_id] = payload
        self._entries.move_to_end(bucket_id)
        self._notify(bucket_id)
        while len(self._entries) > self.capacity:
            victim = self._pick_victim()
            if victim is None:
                # Everything else pinned: undo nothing (the demand read DID
                # happen) but refuse to overflow silently.
                self._evict(bucket_id)
                raise CacheOverflowError(
                    f"cannot insert bucket {bucket_id!r}: all "
                    f"{self.capacity} slots pinned"
                )
            self._evict(victim)
            evicted.append(victim)
        return evicted

    def _evict(self, bucket_id: Hashable) -> None:
        self._entries.pop(bucket_id)
        self.stats.evictions += 1
        if bucket_id in self._prefetched:  # prefetched but never demanded
            self._prefetched.discard(bucket_id)
            self.stats.prefetch_unused += 1
        self._notify(bucket_id)

    def _pick_victim(self, allow_demand: bool = True) -> Optional[Hashable]:
        """LRU victim, skipping pinned and protected entries.  With a
        demand probe installed, a first pass prefers zero-demand buckets
        (nobody is waiting on them); the plain LRU walk is the fallback,
        and is the *entire* policy when no probe is set (the reactive
        baseline's exact behavior).  ``allow_demand=False`` (prefetch
        admission) makes zero demand a hard requirement instead of a
        preference — a speculative fill must never displace work the
        scheduler still needs (cache pollution turns prefetch into a
        net loss on demand-saturated caches)."""
        fallback: Optional[Hashable] = None
        probe = self._demand_of
        for k in self._entries:  # OrderedDict: LRU first
            if k in self._pinned or k in self._protected:
                continue
            if probe is None:
                return k
            if not probe(k):
                return k  # zero pending demand: the preferred victim
            if fallback is None:
                fallback = k
        return fallback if allow_demand else None

    # -- prefetch-side admission ------------------------------------------------
    def insert_prefetched(
        self, bucket_id: Hashable, payload: object = None
    ) -> Optional[list[Hashable]]:
        """Establish residency ahead of demand (the prefetch pipeline's
        fill).  Not an access: hit-rate telemetry only ever counts demand
        reads.  Returns ids evicted to make room, or ``None`` when the
        fill was *refused* — no victim exists (all remaining slots pinned
        or horizon-protected), or, with a demand probe installed, every
        candidate victim still has pending demand (admission control: a
        speculative fill never pollutes the cache by displacing demanded
        work).  A refused prefetch degrades to a plain miss later; it
        never crashes the loop or silently overflows."""
        if bucket_id in self._entries:
            if payload is not None:
                self._entries[bucket_id] = payload
            return []
        evicted: list[Hashable] = []
        while len(self._entries) >= self.capacity:
            victim = self._pick_victim(allow_demand=False)
            if victim is None:
                for b in evicted:  # should be unreachable; stay safe
                    self._entries.setdefault(b, None)
                return None
            self._evict(victim)
            evicted.append(victim)
        self._entries[bucket_id] = payload
        self._entries.move_to_end(bucket_id)
        self._prefetched.add(bucket_id)
        self.stats.prefetch_fills += 1
        self._notify(bucket_id)
        return evicted

    def can_admit_prefetch(self) -> bool:
        """Would a prefetch fill land right now?  True with a free slot or
        an admissible victim (non-pinned, non-protected, and zero-demand
        when a probe is installed).  The pipeline checks before issuing a
        stage so the serial channel never burns time on a read the cache
        is bound to refuse."""
        return (
            len(self._entries) < self.capacity
            or self._pick_victim(allow_demand=False) is not None
        )

    def protect(self, bucket_ids: Iterable[Hashable]) -> None:
        """Replace the eviction-protected set (the committed scan horizon).
        Protection is *capped at capacity - 1* resident slots so a demand
        insert always has at least one victim candidate — the horizon may
        shield its buckets, never wedge the cache."""
        ids = list(dict.fromkeys(bucket_ids))  # de-dup, keep order
        if len(ids) >= self.capacity:
            ids = ids[: self.capacity - 1]
        self._protected = set(ids)

    def protected(self) -> set[Hashable]:
        return set(self._protected)

    def set_demand_probe(
        self, fn: Optional[Callable[[Hashable], int]]
    ) -> None:
        """Install ``fn(bucket_id) -> pending objects`` for demand-aware
        eviction (``None`` restores the plain LRU walk)."""
        self._demand_of = fn

    def note_bypass_miss(self) -> None:
        """Record a read that bypassed residency (an indexed cold read):
        counts as a miss in hit_rate without inserting or evicting."""
        self.stats.misses += 1

    def get(self, bucket_id: Hashable) -> object:
        return self._entries.get(bucket_id)

    def pin(self, bucket_id: Hashable) -> None:
        self._pinned.add(bucket_id)

    def unpin(self, bucket_id: Hashable) -> None:
        self._pinned.discard(bucket_id)

    def invalidate(self, bucket_ids: Iterable[Hashable]) -> None:
        """Drop the given buckets' residency.  Invalidating a *pinned*
        bucket is a hard error: a pin means a batch is reading that
        payload right now, and yanking it mid-flight used to be a quiet
        skip-shaped data race."""
        for b in bucket_ids:
            if b in self._pinned:
                raise ValueError(f"cannot invalidate pinned bucket {b!r}")
            if b in self._entries:
                self._entries.pop(b)
                self._prefetched.discard(b)
                self._notify(b)

    def resident(self) -> list[Hashable]:
        return list(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

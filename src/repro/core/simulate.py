"""Event-driven scheduler simulator (drives the paper's §5 experiments).

Replays a query trace (arrival times + per-object bucket ranges) against a
scheduling policy, the LRU bucket cache, and the empirical cost model, and
reports query throughput / response time / cache hit-rate — the quantities
in Figs. 7 & 8.

This is the same discrete-event harness the serving engine reuses for
capacity planning; on hardware the costs come from the roofline model
instead of (T_b, T_m) disk constants.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from .cache import BucketCache
from .control import ControlLoop, ShardControlPlane, TenantControlPlane
from .dispatch import DispatchLoop
from .hybrid import HybridPlanner
from .metrics import CostModel, per_tenant_latency
from .prefetch import PrefetchConfig, build_pipeline, prefetch_stats
from .scheduler import (
    BucketScheduler,
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
    RoundRobinScheduler,
)
from .shard import (
    ShardedDispatch,
    ShardMap,
    ShardRuntime,
    StealConfig,
    split_slots,
)
from .workload import Query, WorkloadManager

__all__ = [
    "SimResult",
    "simulate_batched",
    "simulate_sharded",
    "simulate_noshare",
    "run_policy",
]


@dataclasses.dataclass
class SimResult:
    policy: str
    makespan: float
    n_queries: int
    query_throughput: float  # completed queries / makespan
    object_throughput: float  # matched objects / makespan
    mean_response: float
    p95_response: float
    std_response: float
    cache_hit_rate: float
    busy_time: float
    n_batches: int
    indexed_batches: int = 0
    n_dispatches: int = 0  # scheduling rounds (== n_batches unless fused)
    device_dispatches: int = 0  # device calls (< rounds under shared plans)
    shared_batch_occupancy: float = 0.0  # mean query fill of shared calls
    # per tenant class: {tenant: {n, p50/p95/mean_response, throughput}}
    per_tenant: dict = dataclasses.field(default_factory=dict)
    # prefetch pipeline rollup (empty without one): staged/fills/refused/
    # demand_waits/stall_s + the CacheStats demand-vs-prefetch hit split
    prefetch: dict = dataclasses.field(default_factory=dict)
    # work-steal migrations (sharded harness only; 0 elsewhere)
    steals: int = 0

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _collect(
    policy: str,
    wm: WorkloadManager,
    cache: BucketCache,
    makespan: float,
    busy: float,
    n_batches: int,
    total_objects: int,
    indexed_batches: int = 0,
    n_dispatches: int | None = None,
    device_dispatches: int | None = None,
    shared_batch_occupancy: float = 0.0,
) -> SimResult:
    responses = wm.response_times()
    resp = np.array(sorted(responses.values()), dtype=np.float64)
    makespan = max(makespan, 1e-9)
    tenants = sorted({q.tenant for q in wm.queries.values()})
    per_tenant = (
        per_tenant_latency(responses, wm.tenant_of_query, makespan, tenants)
        if len(tenants) > 1
        else {}
    )
    return SimResult(
        policy=policy,
        makespan=makespan,
        n_queries=len(resp),
        query_throughput=len(resp) / makespan,
        object_throughput=total_objects / makespan,
        mean_response=float(resp.mean()) if len(resp) else 0.0,
        p95_response=float(np.percentile(resp, 95)) if len(resp) else 0.0,
        std_response=float(resp.std()) if len(resp) else 0.0,
        cache_hit_rate=cache.stats.hit_rate,
        busy_time=busy,
        n_batches=n_batches,
        indexed_batches=indexed_batches,
        n_dispatches=n_batches if n_dispatches is None else n_dispatches,
        device_dispatches=(
            (n_batches if n_dispatches is None else n_dispatches)
            if device_dispatches is None
            else device_dispatches
        ),
        shared_batch_occupancy=shared_batch_occupancy,
        per_tenant=per_tenant,
    )


class _ExecState:
    """Counters the cost-model executor accumulates across rounds (and, in
    the sharded harness, across shards)."""

    __slots__ = ("indexed_batches", "total_objects")

    def __init__(self) -> None:
        self.indexed_batches = 0
        self.total_objects = 0


def _make_executor(wm, cache, cost, hybrid, shared_plan, share_width, state, loop_box):
    """The simulator's cost-model executor, shared verbatim by the
    single-loop and sharded harnesses (one copy of the arithmetic is what
    makes the S=1 configuration bit-identical by construction).
    ``loop_box`` is a one-element list filled with the DispatchLoop after
    construction (the executor is built first)."""

    def execute(decisions, vector) -> float:
        round_cost = 0.0
        for decision in decisions:
            # Re-probe residency: within a fused round an earlier bucket's
            # insertion can evict a later one; cost must track the actual
            # read (for fuse_k == 1 this equals the decision snapshot).
            in_cache = cache.contains(decision.bucket_id)
            # sigma-pro-rated §6 read-back (== full T_spill for a wholly
            # spilled queue) — mirrors CrossMatchEngine._plan_and_fetch
            # and the scheduler's Eq. 1 so priced and charged costs agree.
            sigma = wm.spilled_fraction(decision.bucket_id)
            if hybrid is not None:
                plan = hybrid.plan(decision.queue_size, in_cache)
                step = plan.est_cost + cost.T_spill * sigma
                if plan.strategy == "indexed":
                    state.indexed_batches += 1
                    # Same accounting as CrossMatchEngine._plan_and_fetch:
                    # resident indexed reads are hits, cold ones are misses
                    # that establish no residency.
                    if in_cache:
                        cache.access(decision.bucket_id)
                    else:
                        cache.note_bypass_miss()
                else:
                    cache.access(decision.bucket_id)
            else:
                step = cost.batch_cost(decision.queue_size, in_cache, sigma)
                cache.access(decision.bucket_id)
            round_cost += step
            state.total_objects += decision.queue_size
        if shared_plan:
            # Shared-plan accounting: the round's distinct pending queries
            # share ceil(Q / width) masked calls (vs. the legacy one call
            # per round), and the chunk fill feeds the share_width law.
            width = max(
                1, getattr(vector, "share_width", 0) or share_width
            )
            qids = {
                u.query_id
                for d in decisions
                for u in (
                    wm.queue(d.bucket_id).units
                    + wm.queue(d.bucket_id).spilled_units
                )
            }
            n_chunks = max(1, -(-len(qids) // width))
            loop_box[0].note_device_dispatches(
                n_chunks,
                shared_occupancy=len(qids) / (n_chunks * width)
                if qids
                else 0.0,
            )
        return round_cost

    return execute


def simulate_batched(
    queries: Sequence[Query],
    bucket_of_range: Callable[[int, int], np.ndarray],
    scheduler: BucketScheduler,
    cost: CostModel,
    cache_capacity: int = 20,
    hybrid: Optional[HybridPlanner] = None,
    alpha_hook: Optional[Callable[[float], float]] = None,
    bucket_of_keys=None,
    fuse_k: int = 1,
    control: Optional[ControlLoop | TenantControlPlane] = None,
    on_round=None,
    prefetch: bool | PrefetchConfig = False,
    shared_plan: bool = False,
    share_width: int = 8,
    obs=None,
) -> SimResult:
    """Batched policies (LifeRaft any alpha, RR): one bucket batch at a time.

    The scheduling round itself (controller consult, alpha hot-swap, spill
    enforcement, top-k select, clock/completion) is the shared
    ``DispatchLoop`` — the same inner loop both engines run; this harness
    supplies only the cost-model executor.

    ``control`` plugs in the closed-loop ControlLoop (alpha/fuse_k/spill per
    round); it overrides ``alpha_hook`` and the static ``fuse_k``.  A
    ``TenantControlPlane`` runs one control vector per tenant class —
    queries are classed by their ``meta['tenant']`` tag, buckets by the
    tenant of their oldest pending unit — and ``SimResult.per_tenant``
    reports the per-class p50/p95/throughput rollup.
    ``alpha_hook(t) -> alpha`` remains for open-loop retuning on arrivals.
    ``fuse_k > 1`` services the top-k buckets per scheduling round (the
    fused multi-bucket execution path); residency/cost accounting stays
    per-bucket, but only one dispatch is counted.
    ``prefetch`` (off by default) wires the scan-horizon pipeline: bucket
    staging runs on a simulated serial I/O channel overlapping compute,
    and rounds pay only the residual stall for demanded in-flight buckets
    (``core/prefetch.py``; H is ControlLoop-sized when
    ``prefetch_horizon_max`` is set).
    ``shared_plan`` (off by default) models shared query plans: the
    round's pending queries evaluate in ceil(Q / share_width) masked
    device calls instead of one per bucket (``share_width`` is the static
    ceiling; a ControlLoop with ``share_width_max`` set resizes it per
    round).  Costs and decisions are unchanged — the simulator tracks
    only the device-dispatch/occupancy accounting.
    ``obs`` (off by default) attaches the ``repro.obs`` metrics/tracing
    tap to the loop — a pure side-channel consumer chained via
    ``add_round_tap``, so decisions and goldens are unchanged; pass an
    ``Observability`` instance to export its registry/trace afterwards.
    """
    queries = sorted(queries, key=lambda q: q.arrival_time)
    wm = WorkloadManager(
        bucket_of_range, bucket_of_keys, probe_bytes=cost.probe_bytes,
        min_unit_bytes=cost.min_unit_bytes,
    )
    cache = BucketCache(cache_capacity)
    i = 0
    state = _ExecState()
    loop_box: list = []
    execute = _make_executor(
        wm, cache, cost, hybrid, shared_plan, share_width, state, loop_box
    )

    loop = DispatchLoop(
        scheduler, wm, cache, execute, control=control, fuse_k=fuse_k,
        tenant_of=wm.tenant_of_bucket, on_round=on_round,
        prefetch=build_pipeline(prefetch, scheduler, cache, cost.T_b),
    )
    loop_box.append(loop)
    if obs:
        from ..obs import ensure as _obs_ensure  # lazy: off-path never imports

        _obs_ensure(obs).attach_loop(loop, track=0, clock="virtual")

    def admit(until: float) -> None:
        nonlocal i
        while i < len(queries) and queries[i].arrival_time <= until:
            q = queries[i]
            wm.submit(q)
            loop.observe_arrival(q.arrival_time)
            if (
                control is None
                and alpha_hook is not None
                and isinstance(scheduler, LifeRaftScheduler)
            ):
                scheduler.alpha = alpha_hook(q.arrival_time)
            i += 1

    while i < len(queries) or wm.n_pending_queries:
        if not wm.nonempty_queues():
            # Idle: jump to the next arrival.
            loop.clock = max(loop.clock, queries[i].arrival_time)
            admit(loop.clock)
            continue
        admit(loop.clock)
        outcome = loop.round()
        assert outcome is not None

    name = getattr(scheduler, "name", type(scheduler).__name__)
    if isinstance(scheduler, LifeRaftScheduler):
        name = f"{scheduler.name}(a={scheduler.alpha:g})"
    if isinstance(control, TenantControlPlane):
        name = f"{name}+mt"
    elif control is not None:
        name = f"{name}+ctl"
    if loop.prefetch is not None:
        name = f"{name}+pf"
    if shared_plan:
        name = f"{name}+sp"
    result = _collect(
        name, wm, cache, loop.clock, loop.busy, loop.batches,
        state.total_objects, state.indexed_batches, loop.dispatches,
        loop.device_dispatches, loop.shared_batch_occupancy,
    )
    if loop.prefetch is not None:
        result.prefetch = prefetch_stats(loop.prefetch, cache)
    return result


def simulate_sharded(
    queries: Sequence[Query],
    bucket_of_range: Callable[[int, int], np.ndarray],
    cost: CostModel,
    *,
    scheduler_factory: Callable[[], BucketScheduler],
    n_shards: int = 1,
    shard_map: Optional[ShardMap] = None,
    bucket_bytes: Optional[dict[int, float]] = None,
    cache_capacity: int = 20,
    bucket_of_keys=None,
    fuse_k: int = 1,
    control_factory: Optional[Callable[[], ControlLoop]] = None,
    steal: Optional[StealConfig] = None,
    plane: Optional[ShardControlPlane] = None,
    prefetch: bool | PrefetchConfig = False,
    hybrid: Optional[HybridPlanner] = None,
    shared_plan: bool = False,
    share_width: int = 8,
    on_round: Optional[Callable[[int, object], None]] = None,
    on_steal=None,
    obs=None,
) -> SimResult:
    """Multi-shard harness: S shard-local DispatchLoops on virtual clocks
    behind one ``ShardedDispatch`` coordinator (``core/shard.py``).

    Buckets partition by SFC range (``shard_map``, or byte-balanced from
    ``bucket_bytes``, or an equal split when neither is given); each query
    is decomposed once and its slices routed to the owning shards, with
    completion a join over per-shard completions.  ``cache_capacity`` is
    the **aggregate** across shards — slots are split evenly with the
    remainder going to the lowest shard ids (``split_slots``), so an
    S-vs-1 comparison holds total cache slots equal.
    ``scheduler_factory`` / ``control_factory`` build one instance per
    shard (schedulers and control loops hold per-workload state and
    cannot be shared).  ``steal`` enables work stealing; ``plane`` wires
    the cross-shard ``ShardControlPlane`` byte arbiter.  ``on_round``
    receives ``(shard_id, DispatchOutcome)`` — the golden recorder's tap.

    With ``n_shards=1`` (stealing and plane off) the round sequence, the
    executor arithmetic, and therefore the decision trace are identical
    to :func:`simulate_batched` — the tentpole's proof of safety.
    """
    queries = sorted(queries, key=lambda q: q.arrival_time)
    if shard_map is None:
        if bucket_bytes is not None:
            shard_map = ShardMap.from_bucket_bytes(bucket_bytes, n_shards)
        else:
            # No byte profile: equal-count split over the bucket span the
            # trace actually touches.
            router_probe = WorkloadManager(bucket_of_range, bucket_of_keys)
            touched = sorted(
                {
                    b
                    for q in queries
                    for b in router_probe.decompose(q)
                }
            )
            shard_map = ShardMap.from_bucket_bytes(
                {b: 1.0 for b in touched} or {0: 1.0}, n_shards
            )
    router = WorkloadManager(
        bucket_of_range, bucket_of_keys, probe_bytes=cost.probe_bytes,
        min_unit_bytes=cost.min_unit_bytes,
    )
    coord = ShardedDispatch(
        shard_map, router.decompose, steal=steal, plane=plane,
        on_steal=on_steal, on_round=on_round,
    )
    state = _ExecState()
    caps = split_slots(cache_capacity, n_shards)
    runtimes: list[ShardRuntime] = []
    for sid in range(n_shards):
        wm = WorkloadManager(
            bucket_of_range, bucket_of_keys, probe_bytes=cost.probe_bytes,
            min_unit_bytes=cost.min_unit_bytes,
        )
        cache = BucketCache(caps[sid])
        sched = scheduler_factory()
        loop_box: list = []
        execute = _make_executor(
            wm, cache, cost, hybrid, shared_plan, share_width, state, loop_box
        )
        loop = DispatchLoop(
            sched, wm, cache, execute,
            control=control_factory() if control_factory is not None else None,
            fuse_k=fuse_k,
            tenant_of=wm.tenant_of_bucket,
            complete=coord.make_complete(sid),
            prefetch=build_pipeline(prefetch, sched, cache, cost.T_b),
        )
        loop_box.append(loop)
        if loop.prefetch is not None and bucket_bytes is not None:
            loop.prefetch.nbytes_of = lambda b, _bb=bucket_bytes: _bb.get(b, 0.0)
        rt = ShardRuntime(sid, wm, cache, sched, loop)
        runtimes.append(rt)
        coord.add_shard(rt)

    if obs:
        from ..obs import ensure as _obs_ensure  # lazy: off-path never imports

        _o = _obs_ensure(obs)
        for rt in runtimes:
            _o.attach_loop(rt.loop, track=rt.shard_id, clock="virtual")
        coord.on_steal = _o.chain_steal_tap(coord.on_steal)

    for q in queries:
        coord.route(q)
    coord.run_virtual()
    # Conservation: the join must have resolved every routed query.
    assert all(not owners for owners in coord.owners.values()), (
        "unresolved cross-shard joins after drain"
    )

    sched0 = runtimes[0].scheduler
    name = getattr(sched0, "name", type(sched0).__name__)
    if isinstance(sched0, LifeRaftScheduler):
        name = f"{sched0.name}(a={sched0.alpha:g})"
    if control_factory is not None:
        name = f"{name}+ctl"
    if runtimes[0].loop.prefetch is not None:
        name = f"{name}+pf"
    name = f"{name}+S{n_shards}"
    if steal is not None:
        name = f"{name}st"

    responses = coord.response_times()
    resp = np.array(sorted(responses.values()), dtype=np.float64)
    makespan = max(coord.makespan(), 1e-9)
    hits = sum(rt.cache.stats.hits for rt in runtimes)
    accesses = sum(rt.cache.stats.accesses for rt in runtimes)
    tenants = sorted({q.tenant for q in coord.queries.values()})
    per_tenant = (
        per_tenant_latency(
            responses,
            lambda qid: coord.queries[qid].tenant,
            makespan,
            tenants,
        )
        if len(tenants) > 1
        else {}
    )
    result = SimResult(
        policy=name,
        makespan=makespan,
        n_queries=len(resp),
        query_throughput=len(resp) / makespan,
        object_throughput=state.total_objects / makespan,
        mean_response=float(resp.mean()) if len(resp) else 0.0,
        p95_response=float(np.percentile(resp, 95)) if len(resp) else 0.0,
        std_response=float(resp.std()) if len(resp) else 0.0,
        cache_hit_rate=hits / accesses if accesses else 0.0,
        busy_time=sum(rt.loop.busy for rt in runtimes),
        n_batches=sum(rt.loop.batches for rt in runtimes),
        indexed_batches=state.indexed_batches,
        n_dispatches=sum(rt.loop.dispatches for rt in runtimes),
        device_dispatches=sum(rt.loop.device_dispatches for rt in runtimes),
        per_tenant=per_tenant,
    )
    if any(rt.loop.prefetch is not None for rt in runtimes):
        rollup: dict = {}
        for rt in runtimes:
            if rt.loop.prefetch is None:
                continue
            for k, v in prefetch_stats(rt.loop.prefetch, rt.cache).items():
                rollup[k] = rollup.get(k, 0) + v
        result.prefetch = rollup
    result.steals = len(coord.steals)
    return result


def simulate_noshare(
    queries: Sequence[Query],
    bucket_of_range: Callable[[int, int], np.ndarray],
    cost: CostModel,
    cache_capacity: int = 20,
    bucket_of_keys=None,
) -> SimResult:
    """NoShare baseline: each query evaluated independently, arrival order.

    No batching across queries — every query pays its own bucket reads
    (through the shared cache, which models the DB buffer pool)."""
    queries = sorted(queries, key=lambda q: q.arrival_time)
    wm = WorkloadManager(bucket_of_range, bucket_of_keys)
    cache = BucketCache(cache_capacity)
    clock = 0.0
    busy = 0.0
    n_batches = 0
    total_objects = 0
    for q in queries:
        units = wm.submit(q)
        clock = max(clock, q.arrival_time)
        for u in sorted(units, key=lambda u: u.bucket_id):
            step = cost.batch_cost(u.size, cache.contains(u.bucket_id))
            cache.access(u.bucket_id)
            clock += step
            busy += step
            total_objects += u.size
            n_batches += 1
        # All this query's buckets are done; nothing shared with others.
        for u in units:
            wm.complete_bucket(u.bucket_id, clock)
    return _collect("noshare", wm, cache, clock, busy, n_batches, total_objects)


def run_policy(
    policy: str,
    queries: Sequence[Query],
    bucket_of_range: Callable[[int, int], np.ndarray],
    cost: CostModel,
    alpha: float = 0.0,
    cache_capacity: int = 20,
    hybrid: Optional[HybridPlanner] = None,
    normalized: bool = False,
    bucket_of_keys=None,
    fuse_k: int = 1,
    control: Optional[ControlLoop] = None,
    on_round=None,
    prefetch: bool | PrefetchConfig = False,
    shared_plan: bool = False,
    share_width: int = 8,
    obs=None,
) -> SimResult:
    """Convenience dispatcher used by benchmarks:
    'noshare'|'rr'|'liferaft'|'liferaft-naive'."""
    if policy == "noshare":
        return simulate_noshare(
            queries, bucket_of_range, cost, cache_capacity,
            bucket_of_keys=bucket_of_keys,
        )
    if policy == "rr":
        sched: BucketScheduler = RoundRobinScheduler(cost)
    elif policy == "liferaft":
        sched = LifeRaftScheduler(cost, alpha=alpha, normalized=normalized)
    elif policy == "liferaft-naive":
        sched = NaiveLifeRaftScheduler(cost, alpha=alpha, normalized=normalized)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    return simulate_batched(
        queries, bucket_of_range, sched, cost, cache_capacity, hybrid,
        bucket_of_keys=bucket_of_keys, fuse_k=fuse_k, control=control,
        on_round=on_round, prefetch=prefetch, shared_plan=shared_plan,
        share_width=share_width, obs=obs,
    )

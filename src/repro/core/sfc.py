"""Space-filling curves used to linearize spatial data into buckets.

The paper partitions the celestial sphere with the Hierarchical Triangular
Mesh (HTM): a quad-tree decomposition of the 8 octahedral faces into
spherical triangles.  HTM IDs form a space-filling curve — objects close on
the sky are close in ID — which lets equal-count ID ranges double as
spatially-coherent buckets (paper §3.1, Fig. 1).

We implement:
  * a real (vectorized, numpy) HTM trixel index, ``htm_id`` — the paper's
    curve, 32-bit at level 14 exactly as in SkyQuery;
  * Morton / Z-order curves in 2-D and 3-D, used by the generic partitioner
    (``repro.core.bucket``) for non-spherical data (KV pages, token blocks).

Everything here is pure numpy (host-side pre-processing, never traced).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "htm_id",
    "htm_level_of",
    "unit_vectors",
    "radec_to_unit",
    "morton2d",
    "morton3d",
    "morton2d_decode",
]

# ---------------------------------------------------------------------------
# HTM (Hierarchical Triangular Mesh)
# ---------------------------------------------------------------------------

# Octahedron vertices (the standard HTM basis).
_V = np.array(
    [
        [0.0, 0.0, 1.0],   # v0: north pole
        [1.0, 0.0, 0.0],   # v1
        [0.0, 1.0, 0.0],   # v2
        [-1.0, 0.0, 0.0],  # v3
        [0.0, -1.0, 0.0],  # v4
        [0.0, 0.0, -1.0],  # v5: south pole
    ]
)

# The 8 root trixels (S0-S3, N0-N3) in canonical HTM order; ids 8..15.
# Each row: indices into _V for the triangle corners (counter-clockwise
# seen from outside the sphere).
_ROOTS = np.array(
    [
        [1, 5, 2],  # S0 -> id 8
        [2, 5, 3],  # S1 -> id 9
        [3, 5, 4],  # S2 -> id 10
        [4, 5, 1],  # S3 -> id 11
        [1, 0, 4],  # N0 -> id 12
        [4, 0, 3],  # N1 -> id 13
        [3, 0, 2],  # N2 -> id 14
        [2, 0, 1],  # N3 -> id 15
    ]
)


def _normalize(v: np.ndarray) -> np.ndarray:
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def unit_vectors(n: int, seed: int = 0) -> np.ndarray:
    """``n`` uniformly distributed unit vectors on the sphere, shape (n, 3)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n, 3))
    return _normalize(v)


def radec_to_unit(ra_deg: np.ndarray, dec_deg: np.ndarray) -> np.ndarray:
    """Astronomy (RA, Dec) in degrees -> unit vectors, shape (..., 3)."""
    ra = np.deg2rad(np.asarray(ra_deg, dtype=np.float64))
    dec = np.deg2rad(np.asarray(dec_deg, dtype=np.float64))
    return np.stack(
        [np.cos(dec) * np.cos(ra), np.cos(dec) * np.sin(ra), np.sin(dec)],
        axis=-1,
    )


def _inside(p: np.ndarray, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """True where point ``p`` is on the inner side of great-circle edge a->b."""
    # sign of det([a, b, p]) == dot(cross(a, b), p)
    return np.einsum("...k,...k->...", np.cross(a, b), p) >= -1e-12


def htm_id(points: np.ndarray, level: int = 14) -> np.ndarray:
    """Vectorized HTM trixel IDs for unit vectors ``points`` (n, 3).

    Returns uint64 ids; at ``level`` L the id occupies 4 + 2L bits
    (level 14 -> 32 bits, matching the paper / SkyQuery).
    """
    p = _normalize(np.asarray(points, dtype=np.float64))
    if p.ndim == 1:
        p = p[None]
    n = p.shape[0]

    # Root trixel: test all 8 (cheap) and take the first containing one.
    ids = np.zeros(n, dtype=np.uint64)
    corners = np.zeros((n, 3, 3))
    assigned = np.zeros(n, dtype=bool)
    for r in range(8):
        a, b, c = _V[_ROOTS[r, 0]], _V[_ROOTS[r, 1]], _V[_ROOTS[r, 2]]
        inside = (
            _inside(p, a[None], b[None])
            & _inside(p, b[None], c[None])
            & _inside(p, c[None], a[None])
            & ~assigned
        )
        ids[inside] = 8 + r
        corners[inside] = np.stack([a, b, c])
        assigned |= inside
    # Numerical stragglers on edges: assign to root 8 (harmless for bucketing).
    if not assigned.all():
        rem = ~assigned
        a, b, c = _V[_ROOTS[0, 0]], _V[_ROOTS[0, 1]], _V[_ROOTS[0, 2]]
        ids[rem] = 8
        corners[rem] = np.stack([a, b, c])

    for _ in range(level):
        v0, v1, v2 = corners[:, 0], corners[:, 1], corners[:, 2]
        w0 = _normalize(v1 + v2)
        w1 = _normalize(v0 + v2)
        w2 = _normalize(v0 + v1)
        # child 0: (v0, w2, w1); 1: (v1, w0, w2); 2: (v2, w1, w0); 3: (w0, w1, w2)
        in0 = _inside(p, v0, w2) & _inside(p, w2, w1) & _inside(p, w1, v0)
        in1 = _inside(p, v1, w0) & _inside(p, w0, w2) & _inside(p, w2, v1)
        in2 = _inside(p, v2, w1) & _inside(p, w1, w0) & _inside(p, w0, v2)
        child = np.where(in0, 0, np.where(in1, 1, np.where(in2, 2, 3)))
        ids = ids * np.uint64(4) + child.astype(np.uint64)
        new_corners = np.empty_like(corners)
        m0, m1, m2 = child == 0, child == 1, child == 2
        m3 = child == 3
        new_corners[m0] = np.stack([v0[m0], w2[m0], w1[m0]], axis=1)
        new_corners[m1] = np.stack([v1[m1], w0[m1], w2[m1]], axis=1)
        new_corners[m2] = np.stack([v2[m2], w1[m2], w0[m2]], axis=1)
        new_corners[m3] = np.stack([w0[m3], w1[m3], w2[m3]], axis=1)
        corners = new_corners
    return ids


def htm_level_of(hid: int) -> int:
    """Level encoded in an HTM id (inverse of the 4+2L bit layout)."""
    return (int(hid).bit_length() - 4) // 2


# ---------------------------------------------------------------------------
# Morton / Z-order
# ---------------------------------------------------------------------------

def _part1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(0xFFFFFFFF)
    x = (x | (x << np.uint64(16))) & np.uint64(0x0000FFFF0000FFFF)
    x = (x | (x << np.uint64(8))) & np.uint64(0x00FF00FF00FF00FF)
    x = (x | (x << np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    x = (x | (x << np.uint64(2))) & np.uint64(0x3333333333333333)
    x = (x | (x << np.uint64(1))) & np.uint64(0x5555555555555555)
    return x


def _unpart1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(0x5555555555555555)
    x = (x | (x >> np.uint64(1))) & np.uint64(0x3333333333333333)
    x = (x | (x >> np.uint64(2))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    x = (x | (x >> np.uint64(4))) & np.uint64(0x00FF00FF00FF00FF)
    x = (x | (x >> np.uint64(8))) & np.uint64(0x0000FFFF0000FFFF)
    x = (x | (x >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return x


def _part1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(0x1FFFFF)
    x = (x | (x << np.uint64(32))) & np.uint64(0x1F00000000FFFF)
    x = (x | (x << np.uint64(16))) & np.uint64(0x1F0000FF0000FF)
    x = (x | (x << np.uint64(8))) & np.uint64(0x100F00F00F00F00F)
    x = (x | (x << np.uint64(4))) & np.uint64(0x10C30C30C30C30C3)
    x = (x | (x << np.uint64(2))) & np.uint64(0x1249249249249249)
    return x


def morton2d(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Interleave two uint32 coordinate arrays into Z-order keys (uint64)."""
    return _part1by1(np.asarray(x)) | (_part1by1(np.asarray(y)) << np.uint64(1))


def morton2d_decode(code: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    code = np.asarray(code, dtype=np.uint64)
    return _unpart1by1(code), _unpart1by1(code >> np.uint64(1))


def morton3d(x: np.ndarray, y: np.ndarray, z: np.ndarray) -> np.ndarray:
    """Interleave three 21-bit coordinates into Z-order keys (uint64)."""
    return (
        _part1by2(np.asarray(x))
        | (_part1by2(np.asarray(y)) << np.uint64(1))
        | (_part1by2(np.asarray(z)) << np.uint64(2))
    )

"""Bucket partitioning: equal-sized, spatially-coherent units of work.

Paper §3.1: relational tables are partitioned into equal-sized (same number
of objects) buckets along the HTM space-filling curve.  Each bucket covers a
contiguous key range, so (a) bucket I/O cost is uniform, (b) spatial
proximity is preserved and joins localize inside a bucket, and (c) a query's
key-range bounding box maps to a small set of overlapping buckets.

``Partitioner`` is data-structure only (host-side numpy); the actual object
payloads live in a ``BucketStore`` that the engines read through the
``BucketCache``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["BucketSpec", "Partitioner", "BucketStore"]


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One bucket: a contiguous SFC-key range holding ``count`` objects."""

    bucket_id: int
    key_lo: int  # inclusive
    key_hi: int  # exclusive
    count: int
    nbytes: int  # simulated storage footprint (uniform by construction)


class Partitioner:
    """Equal-count partition of a sorted key space into buckets.

    Parameters
    ----------
    keys:
        SFC keys of every object in the table (need not be sorted).
    objects_per_bucket:
        Paper uses 10,000 objects => ~40 MB buckets on SDSS.
    bytes_per_object:
        Only used to report the simulated bucket size.
    """

    def __init__(
        self,
        keys: np.ndarray,
        objects_per_bucket: int = 10_000,
        bytes_per_object: int = 4_096,
    ) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        order = np.argsort(keys, kind="stable")
        self.sorted_keys = keys[order]
        self.order = order  # original-index permutation, sorted by key
        self.objects_per_bucket = int(objects_per_bucket)
        self.bytes_per_object = int(bytes_per_object)
        n = len(keys)
        self.n_buckets = max(1, -(-n // self.objects_per_bucket))
        # Boundaries are the keys at each bucket's first object.
        starts = np.arange(self.n_buckets) * self.objects_per_bucket
        self._start_idx = starts
        self._boundary_keys = self.sorted_keys[starts]
        self._layout_pos: dict[int, float] = {}  # layout_position cache
        self.specs: list[BucketSpec] = []
        for b in range(self.n_buckets):
            lo = int(self._boundary_keys[b])
            hi = (
                int(self._boundary_keys[b + 1])
                if b + 1 < self.n_buckets
                else int(self.sorted_keys[-1]) + 1
            )
            i0 = starts[b]
            i1 = min(n, i0 + self.objects_per_bucket)
            self.specs.append(
                BucketSpec(
                    bucket_id=b,
                    key_lo=lo,
                    key_hi=hi,
                    count=int(i1 - i0),
                    nbytes=int(i1 - i0) * self.bytes_per_object,
                )
            )

    # -- lookup ------------------------------------------------------------
    def bucket_of_keys(self, keys: np.ndarray) -> np.ndarray:
        """Bucket id for each key (vectorized binary search)."""
        keys = np.asarray(keys, dtype=np.uint64)
        idx = np.searchsorted(self._boundary_keys, keys, side="right") - 1
        return np.clip(idx, 0, self.n_buckets - 1).astype(np.int64)

    def buckets_for_range(self, key_lo: int, key_hi: int) -> np.ndarray:
        """All bucket ids whose key range overlaps [key_lo, key_hi]."""
        b0 = int(self.bucket_of_keys(np.array([key_lo]))[0])
        b1 = int(self.bucket_of_keys(np.array([key_hi]))[0])
        return np.arange(b0, b1 + 1, dtype=np.int64)

    def object_slice(self, bucket_id: int) -> np.ndarray:
        """Original-table indices of the objects stored in ``bucket_id``."""
        i0 = self._start_idx[bucket_id]
        i1 = min(len(self.sorted_keys), i0 + self.objects_per_bucket)
        return self.order[i0:i1]

    def layout_position(self, bucket_id: int) -> float:
        """Physical file position of the bucket: the mean *original-table*
        row address of its objects (its SFC run gathered back to where the
        rows actually sit).  The table was written in ingest order, not
        SFC order, so bucket id (SFC run) and file position are different
        axes — an elevator sweep that seeks by id zig-zags across the
        file.  This is the ``layout_of`` the prefetch planner's sweep
        should order by (ScanPlanConfig.layout_of)."""
        pos = self._layout_pos.get(bucket_id)
        if pos is None:
            idx = self.object_slice(bucket_id)
            pos = float(idx.mean()) if len(idx) else float(bucket_id)
            self._layout_pos[bucket_id] = pos
        return pos


class BucketStore:
    """Holds per-bucket object payloads (host numpy; the 'disk').

    ``payload`` is any dict of equal-length arrays (e.g. unit vectors +
    attributes).  Reads go through ``repro.core.cache.BucketCache``.
    """

    def __init__(self, partitioner: Partitioner, payload: dict[str, np.ndarray]):
        self.partitioner = partitioner
        self._payload = payload
        lengths = {k: len(v) for k, v in payload.items()}
        assert len(set(lengths.values())) <= 1, f"ragged payload: {lengths}"

    def read(self, bucket_id: int) -> dict[str, np.ndarray]:
        idx = self.partitioner.object_slice(bucket_id)
        return {k: v[idx] for k, v in self._payload.items()}

    @property
    def n_buckets(self) -> int:
        return self.partitioner.n_buckets

    def spec(self, bucket_id: int) -> BucketSpec:
        return self.partitioner.specs[bucket_id]


def equal_count_edges(values: Sequence[float], n_buckets: int) -> np.ndarray:
    """Generic helper: quantile edges giving ~equal-count buckets."""
    qs = np.linspace(0.0, 1.0, n_buckets + 1)
    return np.quantile(np.asarray(values), qs)

"""Production mesh definitions (functions only — importing this module
never touches jax device state).

Single pod : (16, 16)  = ("data", "model")      -> 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) = ("pod", "data", "model") -> 512 chips

The 'pod' axis carries only data parallelism (plus ZeRO/compressed-grad
all-reduce) because inter-pod links are the slow tier at 1000+ nodes.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_shape"]


def make_mesh_shape(multi_pod: bool = False):
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = make_mesh_shape(multi_pod)
    return jax.make_mesh(shape, axes)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks device count on
first init) — these two lines are first on purpose."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import SHAPES, get_config, cells  # noqa: E402
from ..models import registry as R  # noqa: E402
from ..sharding.logical import (  # noqa: E402
    DECODE_RULES,
    DEFAULT_RULES,
    ShardingRules,
    activate,
)
from ..training.optimizer import make_optimizer  # noqa: E402
from ..training.train_step import (  # noqa: E402
    make_prefill_step,
    make_serve_step,
    make_train_step,
    tree_shardings,
)
from .mesh import make_production_mesh  # noqa: E402
from .roofline import HW, parse_collectives, roofline_terms  # noqa: E402

__all__ = ["lower_cell", "run_cell"]


def _replicated(mesh):
    return NamedSharding(mesh, P())


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    zero1: bool = False,
    overrides: dict | None = None,
    rule_overrides: dict | None = None,
):
    """Lower one cell; returns (lowered, meta). No device allocation."""
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rule_table = dict(DECODE_RULES if shape.kind == "decode" else DEFAULT_RULES)
    if rule_overrides:
        rule_table.update(rule_overrides)
    rules = ShardingRules(mesh, rule_table)
    chips = mesh.devices.size

    params_abs = R.init_params(cfg, mode="abstract")
    paxes = R.param_axes(cfg)
    params_sh = tree_shardings(rules, paxes, params_abs)
    batch_abs = R.input_specs(cfg, shape)
    baxes = R.batch_axes(cfg, shape)
    batch_sh = tree_shardings(rules, baxes, batch_abs)
    rep = _replicated(mesh)

    with activate(rules):
        if shape.kind == "train":
            opt = make_optimizer(cfg.optimizer)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            oaxes = opt.state_axes(paxes)
            opt_sh = tree_shardings(rules, oaxes, opt_abs, zero1=zero1)
            step = make_train_step(cfg, opt)
            metrics_sh = jax.tree_util.tree_map(
                lambda _: rep, {"loss": 0, "grad_norm": 0, "lr": 0}
            )
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            out_sh = NamedSharding(
                mesh, rules.spec_for(("batch",), (shape.global_batch,))
            )
            jitted = jax.jit(
                step, in_shardings=(params_sh, batch_sh), out_shardings=out_sh
            )
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            cache_abs = R.make_cache(
                cfg, shape.global_batch, shape.seq_len, mode="abstract",
                enc_len=min(shape.seq_len, 32768),
            )
            caxes = R.cache_axes(
                cfg, shape.global_batch, shape.seq_len,
                enc_len=min(shape.seq_len, 32768),
            )
            cache_sh = tree_shardings(rules, caxes, cache_abs)
            token_sh = batch_sh["token"]
            step = make_serve_step(cfg, shape.seq_len)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, token_sh, cache_sh),
                out_shardings=(token_sh, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                params_abs, batch_abs["token"], cache_abs
            )
    meta = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "zero1": zero1,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "rule_overrides": {k: str(v) for k, v in (rule_overrides or {}).items()},
    }
    return lowered, meta, cfg, shape


def _model_flops(cfg, shape) -> float:
    n_active = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


# ------------------------------------------------------------------ calibration
# XLA's HLO cost analysis counts a while-loop (lax.scan) body ONCE, so the
# reported flops/bytes of a scanned-layer model are depth-independent.  Cost
# is affine in depth (embed/head + L x body), so we compile two shallow
# variants (depths L1 < L2, all widths full) and extrapolate linearly to the
# real depth.  Exact for affine cost; the full-depth compile still provides
# the compile proof, memory analysis, and the collective *schedule*.
def _depth_field_and_pair(cfg):
    if cfg.family == "hybrid":
        return {"n_layers": (cfg.attn_period, 2 * cfg.attn_period)}
    if cfg.family == "encdec":
        return {"n_layers": (2, 4), "n_enc_layers": (2, 4)}
    return {"n_layers": (2, 4)}


def _measure(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return (
        compiled,
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll,
    )


def _calibrated_costs(arch, shape_name, multi_pod, zero1, overrides, cfg,
                      rule_overrides=None):
    """(flops, bytes, wire_bytes) extrapolated to full depth."""
    pairs = _depth_field_and_pair(cfg)
    L_full = cfg.n_layers
    ovr = dict(overrides or {})
    ovr["microbatch"] = None  # accumulation scan would also hide flops
    ovr["scan_layers"] = False  # unrolled layers: cost analysis sees each one
    o1 = dict(ovr, **{k: v[0] for k, v in pairs.items()})
    o2 = dict(ovr, **{k: v[1] for k, v in pairs.items()})
    l1, *_ = lower_cell(arch, shape_name, multi_pod, zero1, o1, rule_overrides)
    _, f1, b1, c1 = _measure(l1)
    l2, *_ = lower_cell(arch, shape_name, multi_pod, zero1, o2, rule_overrides)
    _, f2, b2, c2 = _measure(l2)
    L1, L2 = pairs["n_layers"]
    scale = (L_full - L1) / (L2 - L1)
    flops = f1 + (f2 - f1) * scale
    byt = b1 + (b2 - b1) * scale
    wire = c1.wire_bytes_per_chip + (c2.wire_bytes_per_chip - c1.wire_bytes_per_chip) * scale
    return flops, byt, wire, {"L1": L1, "L2": L2, "f1": f1, "f2": f2}


def run_cell(arch, shape_name, multi_pod=False, zero1=False, overrides=None,
             out_dir="experiments/dryrun", tag="", calibrate=True,
             rule_overrides=None):
    t0 = time.perf_counter()
    lowered, meta, cfg, shape = lower_cell(
        arch, shape_name, multi_pod, zero1, overrides, rule_overrides
    )
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    if calibrate:
        flops, byt, wire, calib = _calibrated_costs(
            arch, shape_name, multi_pod, zero1, overrides, cfg, rule_overrides
        )
        coll.wire_bytes_per_chip = wire
    else:
        flops, byt, calib = raw_flops, raw_bytes, {}
    terms = roofline_terms(
        flops, byt, coll,
        model_flops_global=_model_flops(cfg, shape), chips=meta["chips"],
    )
    result = {
        **meta,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_chip": flops,
        "bytes_per_chip": byt,
        "raw_flops_uncalibrated": raw_flops,
        "raw_bytes_uncalibrated": raw_bytes,
        "calibration": calib,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        },
        **terms,
    }
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{result['mesh']}{('__' + tag) if tag else ''}.json"
    (out / name).write_text(json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser(description="LifeRaft-JAX multi-pod dry-run")
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every live cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (int/float/bool literal)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override name=axis1,axis2 (or 'none')")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            import ast

            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rule_overrides[k] = None if v == "none" else tuple(v.split(","))

    todo = cells() if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in todo:
        try:
            r = run_cell(arch, shape, args.multi_pod, args.zero1,
                         overrides or None, args.out, args.tag,
                         rule_overrides=rule_overrides or None)
            print(
                f"OK  {arch:26s} {shape:12s} {r['mesh']:8s} "
                f"compile={r['compile_s']:7.1f}s flops/chip={r['flops_per_chip']:.3e} "
                f"tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f} "
                f"tx={r['t_collective_s']:.4f} dom={r['dominant']}"
            )
            print("  memory_analysis:", json.dumps(r["memory_analysis"]))
            print("  cost_analysis: flops={:.3e} bytes={:.3e}".format(
                r["flops_per_chip"], r["bytes_per_chip"]))
        except Exception:
            failures += 1
            print(f"FAIL {arch} {shape} multi_pod={args.multi_pod}")
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

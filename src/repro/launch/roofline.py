"""Roofline-term derivation from a compiled dry-run artifact.

Per the brief (TPU v5e targets):
    compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s)      [per-chip HLO]
    memory term     = HLO_bytes / (chips x 819e9  B/s)
    collective term = collective bytes per chip / 50e9 B/s/link

``cost_analysis()`` on the partitioned module already reports *per-chip*
flops/bytes, so no further division by chip count is applied to those.
Collective bytes are parsed from the optimized HLO: for each all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take the
result buffer size and the replica-group size g, then convert to ring-
algorithm bytes-on-the-wire per chip:

    all-reduce      2 (g-1)/g * size
    all-gather        (g-1)/g * size          (size = gathered result)
    reduce-scatter    (g-1)   * size          (size = scattered result)
    all-to-all        (g-1)/g * size
    collective-permute          size
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "Hardware", "parse_collectives", "roofline_terms", "CollectiveStats"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s / chip
    ici_bw: float = 50e9  # B/s / link
    hbm_bytes: float = 16e9


HW = Hardware()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# e.g. "bf16[16,256,1024]{2,1,0}" or "f32[]"; tuples handled separately
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict
    wire_bytes_per_chip: float

    def total_result_bytes(self) -> float:
        return sum(self.result_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    result_bytes: dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":  # started op already counted at -start
            continue
        type_str, op = m.group(1), m.group(2)
        size = _type_bytes(type_str)
        # group size
        g = 1
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            g = int(gi.group(2))
        else:
            gm = _GROUPS_RE.search(line)
            if gm:
                first = gm.group(1).split("}")[0].lstrip("{")
                g = max(len([x for x in first.split(",") if x.strip() != ""]), 1)
        counts[op] = counts.get(op, 0) + 1
        result_bytes[op] = result_bytes.get(op, 0.0) + size
        if op == "collective-permute":  # point-to-point: no group attribute
            wire += size
            continue
        if g <= 1:
            continue
        if op == "all-reduce":
            wire += 2.0 * (g - 1) / g * size
        elif op == "all-gather":
            wire += (g - 1) / g * size
        elif op == "reduce-scatter":
            wire += (g - 1) * size
        elif op == "all-to-all":
            wire += (g - 1) / g * size
        elif op == "collective-permute":
            wire += size
    return CollectiveStats(counts, result_bytes, wire)


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll: CollectiveStats,
    hw: Hardware = HW,
    model_flops_global: Optional[float] = None,
    chips: int = 256,
) -> dict:
    t_compute = flops_per_chip / hw.peak_flops
    t_memory = bytes_per_chip / hw.hbm_bw
    t_coll = coll.wire_bytes_per_chip / hw.ici_bw
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    out = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
        "collective_counts": coll.counts,
        "collective_result_bytes": coll.result_bytes,
        "wire_bytes_per_chip": coll.wire_bytes_per_chip,
    }
    if model_flops_global:
        hlo_global = flops_per_chip * chips
        out["model_flops_global"] = model_flops_global
        out["useful_flop_ratio"] = model_flops_global / max(hlo_global, 1.0)
        out["mfu_upper_bound"] = model_flops_global / max(
            chips * hw.peak_flops * bound, 1e-30
        )
    return out

"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced smoke configs end-to-end (real
optimizer, data pipeline, checkpoints).  On a TPU slice the same driver
runs the full config: the jitted step picks up the production mesh +
logical-rule shardings, and checkpoint/restart + elastic re-shard come
from ``repro.checkpoint``.
"""
from __future__ import annotations

import argparse

from ..configs import get_config, list_archs, smoke_config
from ..training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser(description="LifeRaft-JAX trainer")
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full-config", action="store_true",
                    help="use the production config (requires accelerators)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    tcfg = TrainerConfig(
        steps=args.steps,
        checkpoint_dir=args.ckpt_dir,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        lr=args.lr,
    )
    trainer = Trainer(cfg, tcfg)
    history = trainer.run()
    if history:
        print(f"[train] final loss {history[-1]['loss']:.4f} "
              f"after {history[-1]['step']} steps")


if __name__ == "__main__":
    main()

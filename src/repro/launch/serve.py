"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the LifeRaft multi-tenant engine against a Poisson/Zipf request trace
with real decode steps of a (reduced) model; ``--policy`` flips between
the paper's schedulers.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs, smoke_config
from ..models import registry as R
from ..serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig
from ..training.train_step import make_serve_step


def main():
    ap = argparse.ArgumentParser(description="LifeRaft-JAX serving engine")
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--policy", default="liferaft",
                    choices=["liferaft", "rr", "noshare"])
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--no-decode", action="store_true",
                    help="scheduling simulation only (no device compute)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    decode_fn = None
    if not args.no_decode:
        params = R.init_params(cfg, jax.random.PRNGKey(0))
        max_seq, B = 64, 8
        step = jax.jit(make_serve_step(cfg, max_seq))

        def decode_fn(adapter_id, batch, quantum):
            cache = R.make_cache(cfg, B, max_seq, enc_len=16)
            tok = jnp.zeros((B, 1), jnp.int32)
            for _ in range(quantum):
                tok, cache = step(params, tok, cache)

    rng = np.random.default_rng(0)
    w = 1.0 / np.arange(1, args.tenants + 1) ** 1.5
    w /= w.sum()
    t, reqs = 0.0, []
    for i in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        reqs.append(Request(i, int(rng.choice(args.tenants, p=w)), t,
                            int(rng.integers(8, 64)), 16))
    engine = LifeRaftEngine(
        [AdapterSpec(a, 4 << 30) for a in range(args.tenants)],
        ServeConfig(policy=args.policy, alpha=args.alpha,
                    adapter_slots=max(args.tenants // 3, 1)),
        decode_batch_fn=decode_fn,
    )
    s = engine.run(reqs)
    for k, v in s.items():
        print(f"  {k:20s} {v}")


if __name__ == "__main__":
    main()

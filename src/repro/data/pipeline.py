"""Deterministic, shard-aware, resumable synthetic token pipeline.

Every (step, dp_rank) pair maps to an independent counter-based seed, so:
  * restarts resume exactly (state == step index, nothing else);
  * each data-parallel rank draws a disjoint stream (no host coordination);
  * elastic rescaling re-partitions the same global stream deterministically
    (global sample index = step * global_batch + position).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so the loss has signal to minimize
    n_states: int = 64


class TokenPipeline:
    def __init__(self, cfg: DataConfig, dp_rank: int = 0, dp_size: int = 1,
                 start_step: int = 0):
        assert cfg.global_batch % dp_size == 0
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.step = start_step
        self.local_batch = cfg.global_batch // dp_size
        # fixed per-state emission tables (same on every rank; derived from seed)
        rng = np.random.default_rng(cfg.seed)
        self._emit = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_states, 8), dtype=np.int64
        )
        self._trans = rng.integers(
            0, cfg.n_states, size=(cfg.n_states, 4), dtype=np.int64
        )

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict, dp_rank=0, dp_size=1):
        assert state["seed"] == cfg.seed, "restoring against a different stream"
        return cls(cfg, dp_rank, dp_size, start_step=state["step"])

    def _sample(self, global_idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, global_idx))
        s = int(rng.integers(0, self.cfg.n_states))
        out = np.empty(self.cfg.seq_len + 1, dtype=np.int64)
        for t in range(self.cfg.seq_len + 1):
            out[t] = self._emit[s, rng.integers(0, 8)]
            s = int(self._trans[s, rng.integers(0, 4)])
        return out

    def next_batch(self) -> dict:
        """Returns {'tokens','labels'} of shape (local_batch, seq_len)."""
        base = self.step * self.cfg.global_batch + self.dp_rank * self.local_batch
        seqs = np.stack([self._sample(base + i) for i in range(self.local_batch)])
        self.step += 1
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses

from .base import SHAPES, ModelConfig, ShapeConfig
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .mistral_large_123b import CONFIG as mistral_large_123b
from .qwen1_5_110b import CONFIG as qwen1_5_110b
from .codeqwen1_5_7b import CONFIG as codeqwen1_5_7b
from .nemotron_4_340b import CONFIG as nemotron_4_340b
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .mixtral_8x22b import CONFIG as mixtral_8x22b
from .moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from .paligemma_3b import CONFIG as paligemma_3b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b

__all__ = ["ARCHS", "get_config", "smoke_config", "list_archs", "cells"]

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        falcon_mamba_7b,
        mistral_large_123b,
        qwen1_5_110b,
        codeqwen1_5_7b,
        nemotron_4_340b,
        seamless_m4t_large_v2,
        mixtral_8x22b,
        moonshot_v1_16b_a3b,
        paligemma_3b,
        jamba_v0_1_52b,
    ]
}


def list_archs() -> list[str]:
    return sorted(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (tiny dims, few layers)."""
    cfg = get_config(name)
    small: dict = dict(
        d_model=64,
        d_ff=128,
        vocab_size=512,
        dtype="float32",
        remat=False,
        microbatch=None,
    )
    if cfg.n_heads:
        small.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
                     head_dim=16)
    if cfg.family == "hybrid":
        small.update(n_layers=8, attn_period=4, attn_offset=2)
    elif cfg.family == "encdec":
        small.update(n_layers=2, n_enc_layers=2)
    else:
        small.update(n_layers=2)
    if cfg.n_experts:
        small.update(n_experts=4, top_k=2, moe_d_ff=128)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=8, ssm_conv=4, ssm_expand=2, dt_rank=8)
    if cfg.sliding_window:
        small.update(sliding_window=16)
    if cfg.family == "vlm":
        small.update(n_prefix=8)
    return dataclasses.replace(cfg, **small)


def cells() -> list[tuple[str, str]]:
    """All live (arch, shape) dry-run cells: 40 minus skipped long_500k.

    long_500k needs sub-quadratic attention (SSM / hybrid / SWA); pure
    full-attention archs skip it (DESIGN.md §4)."""
    out = []
    for arch, cfg in ARCHS.items():
        for sname, sh in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                continue
            out.append((arch, sname))
    return out

"""seamless-m4t-large-v2 [audio]: enc-dec, 24L enc + 24L dec, d_model=1024
16H (kv=16) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

The audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S_enc, d_model); vocab pads 256206 -> 256256 for TP."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    frontend="audio",
    optimizer="adamw",
)

"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H GQA(kv=8) d_ff=14336,
Mamba:attn 7:1 interleave (attn at offset 4 of each 8-layer period),
MoE 16 experts top-2 on every other layer.  [arXiv:2403.19887; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    optimizer="adamw8bit",
)

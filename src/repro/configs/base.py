"""Config system: architecture + input-shape descriptors, CLI registry."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # -- variants ------------------------------------------------------------
    activation: str = "swiglu"  # swiglu | relu2 | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1  # MoE replaces the MLP on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "onehot"  # onehot (paper-era baseline) | sort (optimized)
    # -- SSM (Mamba-1) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    # -- hybrid (Jamba): one attention layer per `attn_period` layers -----------
    attn_period: int = 0
    attn_offset: int = 0
    # -- encoder-decoder ----------------------------------------------------------
    n_enc_layers: int = 0
    # -- modality frontend stubs -----------------------------------------------
    frontend: Optional[str] = None  # vision | audio
    n_prefix: int = 256  # vision patches / audio frames prepended or encoded
    # -- numerics / compilation ---------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    scan_layers: bool = True
    remat: bool = True
    loss_chunk: Optional[int] = None  # token-chunked CE (memory optimization)
    # -- training ---------------------------------------------------------------
    optimizer: str = "adamw"  # adamw | adamw8bit | adafactor
    microbatch: Optional[int] = None  # grad-accum microbatch (global); None = no accum

    # ------------------------------------------------------------------ derived
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def uses_attention(self, layer_idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return layer_idx % self.attn_period == self.attn_offset
        return True

    def uses_moe(self, layer_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer_idx % self.moe_every == self.moe_offset

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (no encoder-only)

    # Parameter count for MODEL_FLOPS = 6*N*D (N_active for MoE).
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        n = 0
        vocab = self.vocab_size
        n += vocab * d  # embed
        if not self.tie_embeddings:
            n += vocab * d  # unembed
        enc_layers = self.n_enc_layers
        for i in range(L):
            if self.uses_attention(i):
                qkv = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
                n += qkv + self.n_heads * self.head_dim * d
            elif self.family in ("ssm", "hybrid"):
                di, ns, dr = self.d_inner, self.ssm_state, self.dt_rank_actual
                n += d * 2 * di + self.ssm_conv * di + di * (dr + 2 * ns)
                n += dr * di + di * ns + di + di * d  # dt_proj, A, D, out
            if self.uses_moe(i):
                e = self.n_experts if not active_only else self.top_k
                ff = self.moe_d_ff or self.d_ff
                mult = 3 if self.activation == "swiglu" else 2
                n += e * mult * d * ff + d * self.n_experts  # experts + router
            else:
                mult = 3 if self.activation == "swiglu" else 2
                n += mult * d * self.d_ff
            n += 2 * d  # norms
        for _ in range(enc_layers):  # encoder stack (full attention + mlp)
            qkv = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
            n += qkv + self.n_heads * self.head_dim * d
            mult = 3 if self.activation == "swiglu" else 2
            n += mult * d * self.d_ff + 2 * d
            if self.family == "encdec":  # decoder cross-attention params
                n += qkv + self.n_heads * self.head_dim * d
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

"""moonshot-v1-16b-a3b (Moonlight) [moe]: 48L d_model=2048 16H (kv=16)
expert d_ff=1408, MoE 64 experts top-6, vocab 163840.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=163840,
    activation="swiglu",
    n_experts=64,
    top_k=6,
    optimizer="adamw",
)

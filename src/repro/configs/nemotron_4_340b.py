"""nemotron-4-340b [dense]: 96L d_model=18432 96H GQA(kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP (non-gated).  [arXiv:2402.16819; unverified]

Head dim 18432/96 = 192.  8-bit optimizer state is required to fit v5e HBM
(see DESIGN.md §5)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",
    optimizer="adamw8bit",
    microbatch=32,
)

from .base import SHAPES, ModelConfig, ShapeConfig
from .registry import ARCHS, cells, get_config, list_archs, smoke_config

__all__ = [
    "SHAPES", "ModelConfig", "ShapeConfig",
    "ARCHS", "cells", "get_config", "list_archs", "smoke_config",
]

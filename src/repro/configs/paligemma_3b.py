"""paligemma-3b [vlm]: SigLIP (stub) + 18L gemma d_model=2048 8H MQA(kv=1)
d_ff=16384 vocab=257216, GeGLU, prefix-LM over image tokens.
[arXiv:2407.07726; hf]

The vision frontend is a STUB: input_specs() provides 256 precomputed
patch embeddings per image; head_dim 256 (gemma)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="geglu",
    frontend="vision",
    n_prefix=256,
    tie_embeddings=True,
    optimizer="adamw",
)

from .checkpointer import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from .elastic import elastic_restore

__all__ = ["AsyncCheckpointer", "latest_step", "restore_checkpoint",
           "save_checkpoint", "elastic_restore"]

"""Sharded checkpointing: atomic, async, elastic (mesh-agnostic restore).

Layout: <dir>/step_<N>/
    manifest.json      — step, leaf paths, shapes, dtypes, data shards
    arrays_<k>.npz     — leaf arrays, chunked ~512 MB per file

Writes go to ``step_<N>.tmp`` then ``os.replace`` (atomic on POSIX), so a
preempted save never corrupts the latest checkpoint.  ``AsyncCheckpointer``
moves the host copy + write off the training thread and blocks the *next*
save until the previous one lands (bounded staleness of one).

On a real multi-host cluster each host writes the shards it owns; here the
single process owns everything, and elastic restore re-shards by simply
``device_put``-ing to the new mesh's NamedShardings (``elastic.py``).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "AsyncCheckpointer"]

_CHUNK_BYTES = 512 << 20


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any) -> pathlib.Path:
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, _ = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    manifest = {"step": step, "leaves": [], "files": []}
    fidx, cur, cur_bytes = 0, {}, 0
    for p, a in zip(paths, host):
        key = f"a{len(manifest['leaves'])}"
        manifest["leaves"].append(
            {"path": p, "file": fidx, "key": key, "shape": list(a.shape),
             "dtype": str(a.dtype)}
        )
        cur[key] = a
        cur_bytes += a.nbytes
        if cur_bytes >= _CHUNK_BYTES:
            np.savez(tmp / f"arrays_{fidx}.npz", **cur)
            manifest["files"].append(f"arrays_{fidx}.npz")
            fidx, cur, cur_bytes = fidx + 1, {}, 0
    if cur:
        np.savez(tmp / f"arrays_{fidx}.npz", **cur)
        manifest["files"].append(f"arrays_{fidx}.npz")
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | os.PathLike,
    step: Optional[int],
    like: Any,
    shardings: Any = None,
):
    """Restore into the structure of ``like``; optionally place with
    ``shardings`` (a matching pytree of NamedSharding — elastic restore)."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = {}
    for i, fname in enumerate(manifest["files"]):
        with np.load(d / fname) as z:
            for k in z.files:
                data[(i, k)] = z[k]
    by_path = {
        leaf["path"]: data[(leaf["file"], leaf["key"])]
        for leaf in manifest["leaves"]
    }
    paths, leaves, treedef = _flatten(like)
    out = []
    for p, ref in zip(paths, leaves):
        a = by_path[p]
        assert tuple(a.shape) == tuple(ref.shape), (p, a.shape, ref.shape)
        out.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, manifest["step"]


class AsyncCheckpointer:
    """Background-thread writer with bounded staleness of one save."""

    def __init__(self, directory: str | os.PathLike):
        self.directory = pathlib.Path(directory)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host = jax.tree_util.tree_map(np.asarray, tree)  # snapshot on caller

        def _run():
            try:
                save_checkpoint(self.directory, step, host)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

"""Elastic restore: load a checkpoint into a *different* mesh.

Checkpoints store logical arrays (mesh-free), so rescaling = recomputing
the sharding-spec pytree for the new mesh and device_put-ing.  Combined
with the divisibility-aware rules this supports growing 256 -> 512 chips
(add the pod axis) or shrinking to whatever survives a failure — the
LifeRaft answer to node loss: checkpoint/restart onto the remaining mesh.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from ..sharding.logical import ShardingRules
from ..training.train_step import tree_shardings
from .checkpointer import restore_checkpoint

__all__ = ["elastic_restore"]


def elastic_restore(
    directory,
    step: Optional[int],
    like: Any,
    axes_tree: Any,
    rules: ShardingRules,
    zero1: bool = False,
):
    """Restore ``like``-shaped tree, resharded for ``rules.mesh``."""
    shardings = tree_shardings(rules, axes_tree, like, zero1=zero1)
    return restore_checkpoint(directory, step, like, shardings)

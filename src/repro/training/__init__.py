from .optimizer import Optimizer, cosine_schedule, global_norm, make_optimizer
from .train_step import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    tree_shardings,
)

__all__ = [
    "Optimizer", "cosine_schedule", "global_norm", "make_optimizer",
    "make_prefill_step", "make_serve_step", "make_train_step", "tree_shardings",
]

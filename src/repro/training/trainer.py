"""Training loop: data pipeline -> jitted step -> async checkpoints, with
restart recovery and straggler tracking.  Arch-agnostic via the registry."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs.base import ModelConfig
from ..data import DataConfig, TokenPipeline
from ..dist.ft import StragglerPolicy
from ..models import registry as R
from .optimizer import make_optimizer
from .train_step import make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    lr: float = 3e-4
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.tcfg = tcfg
        self.log = log_fn
        self.opt = make_optimizer(cfg.optimizer, lr=tcfg.lr)
        self.step_fn = jax.jit(make_train_step(cfg, self.opt), donate_argnums=(0, 1))
        self.data = TokenPipeline(
            DataConfig(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                       seed=tcfg.seed)
        )
        self.params = R.init_params(cfg, jax.random.PRNGKey(tcfg.seed))
        self.opt_state = self.opt.init(self.params)
        self.start_step = 0
        self.ckpt = AsyncCheckpointer(tcfg.checkpoint_dir) if tcfg.checkpoint_dir else None
        self.straggler = StragglerPolicy()
        self.history: list[dict] = []
        self._maybe_restore()

    def _maybe_restore(self) -> None:
        if not self.ckpt:
            return
        last = latest_step(self.tcfg.checkpoint_dir)
        if last is None:
            return
        state = {"params": self.params, "opt": self.opt_state}
        restored, step = restore_checkpoint(self.tcfg.checkpoint_dir, last, state)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.start_step = step
        self.data = TokenPipeline.restore(
            self.data.cfg, {"step": step, "seed": self.tcfg.seed}
        )
        self.log(f"[trainer] restored checkpoint at step {step}")

    def run(self) -> list[dict]:
        for step in range(self.start_step, self.tcfg.steps):
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.straggler.observe(dt)
            rec = {"step": step + 1, "loss": loss, "sec": dt, "straggler": slow}
            self.history.append(rec)
            if (step + 1) % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {step+1} loss={loss:.4f} ({dt*1e3:.0f} ms)")
            if self.ckpt and (step + 1) % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, {"params": self.params, "opt": self.opt_state})
        if self.ckpt:
            self.ckpt.wait()
        return self.history

"""Optimizers built from scratch (no optax): AdamW, 8-bit AdamW, Adafactor.

8-bit AdamW stores both moments block-quantized (int8 + per-block f32
scale, block=256), cutting optimizer state from 8 to ~2.03 bytes/param —
the difference between nemotron-4-340b fitting a 16 GB v5e chip or not
(DESIGN.md §5).  Quantization error is bounded per-block and re-absorbed
every step because moments are re-quantized from the f32 update.

API:  opt = make_optimizer(cfg_like)
      state  = opt.init(params)                    (works under eval_shape)
      params, state = opt.update(grads, state, params)
      axes   = opt.state_axes(param_axes)          (for sharding specs)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "make_optimizer", "cosine_schedule", "global_norm"]

_QBLOCK = 256


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


# ---------------------------------------------------------------- quantization
def _quantize(x: jax.Array):
    """f32 -> (int8 blocks, f32 scales). Shape (n_blocks, _QBLOCK)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


# ---------------------------------------------------------------- optimizer API
@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]
    state_axes: Callable[[Any], Any]


def make_optimizer(
    name: str = "adamw",
    lr: float | Callable = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    if name == "adamw":
        return _adamw(lr_fn, b1, b2, eps, weight_decay, clip_norm, bits8=False)
    if name == "adamw8bit":
        return _adamw(lr_fn, b1, b2, eps, weight_decay, clip_norm, bits8=True)
    if name == "adafactor":
        return _adafactor(lr_fn, weight_decay, clip_norm)
    raise ValueError(f"unknown optimizer {name!r}")


# ---------------------------------------------------------------- AdamW (+8bit)
def _adamw(lr_fn, b1, b2, eps, wd, clip_norm, bits8: bool) -> Optimizer:
    def init(params):
        def per_leaf(p):
            if bits8:
                nb = -(-_size(p.shape) // _QBLOCK)
                return {
                    "m_q": jnp.zeros((nb, _QBLOCK), jnp.int8),
                    "m_s": jnp.zeros((nb, 1), jnp.float32),
                    "v_q": jnp.zeros((nb, _QBLOCK), jnp.int8),
                    "v_s": jnp.zeros((nb, 1), jnp.float32),
                }
            return {
                "m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32),
            }

        return {
            "mu": jax.tree_util.tree_map(per_leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def per_leaf(p, g, s):
            g = g.astype(jnp.float32)
            if bits8:
                m = _dequantize(s["m_q"], s["m_s"], p.shape)
                v = _dequantize(s["v_q"], s["v_s"], p.shape)
            else:
                m, v = s["m"], s["v"]
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            upd = upd + wd * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            if bits8:
                mq, ms = _quantize(m)
                vq, vs = _quantize(v)
                return new_p, {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
            return new_p, {"m": m, "v": v}

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["mu"])
        out = [per_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        return new_params, {"mu": new_mu, "step": step}, {"grad_norm": gnorm, "lr": lr}

    def state_axes(param_axes):
        def per_leaf(ax):
            if bits8:
                return {
                    "m_q": ("opt", None),
                    "m_s": ("opt", None),
                    "v_q": ("opt", None),
                    "v_s": ("opt", None),
                }
            return {"m": ax, "v": ax}

        return {
            "mu": jax.tree_util.tree_map(
                per_leaf, param_axes, is_leaf=lambda x: isinstance(x, tuple)
            ),
            "step": (),
        }

    return Optimizer("adamw8bit" if bits8 else "adamw", init, update, state_axes)


# ---------------------------------------------------------------- Adafactor
def _adafactor(lr_fn, wd, clip_norm) -> Optimizer:
    eps = 1e-30

    def init(params):
        def per_leaf(p):
            if len(p.shape) >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "mu": jax.tree_util.tree_map(per_leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

        def per_leaf(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if len(p.shape) >= 2:
                vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                mean_r = jnp.mean(vr, axis=-1, keepdims=True)
                pre = (vr / jnp.maximum(mean_r, eps))[..., None] * vc[..., None, :]
                upd = g / jnp.sqrt(jnp.maximum(pre, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                upd = g / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            upd = upd + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_s

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["mu"])
        out = [per_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        return (
            tdef.unflatten([o[0] for o in out]),
            {"mu": tdef.unflatten([o[1] for o in out]), "step": step},
            {"grad_norm": gnorm, "lr": lr},
        )

    def state_axes(param_axes):
        def per_leaf(ax):
            if len(ax) >= 2:
                return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
            return {"v": tuple(ax)}

        return {
            "mu": jax.tree_util.tree_map(
                per_leaf, param_axes, is_leaf=lambda x: isinstance(x, tuple)
            ),
            "step": (),
        }

    return Optimizer("adafactor", init, update, state_axes)


def _size(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n

"""Train step assembly: loss -> grads (with microbatch accumulation) ->
optimizer update, plus the sharding-spec derivation used by the launcher
and the multi-pod dry-run."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import registry as R
from ..sharding.logical import ShardingRules
from .optimizer import Optimizer

__all__ = ["make_train_step", "make_serve_step", "make_prefill_step", "tree_shardings"]


def _is_axes(x) -> bool:
    return isinstance(x, tuple)


def tree_shardings(rules: ShardingRules, axes_tree, abstract_tree, zero1: bool = False):
    """NamedSharding pytree from (logical axes, abstract shapes) twins.

    ``zero1``: additionally shard dim-0 of any leaf whose dim-0 is
    unsharded over the 'data' axis when divisible (optimizer states)."""

    def one(ax, ab):
        shape = ab.shape
        spec = rules.spec_for(ax, shape)
        if zero1 and shape:
            parts = list(spec) + [None] * (len(shape) - len(spec))
            if parts[0] is None and "data" in rules.mesh.shape:
                if shape[0] % rules.mesh.shape["data"] == 0:
                    used = {a for p in parts if p for a in ((p,) if isinstance(p, str) else p)}
                    if "data" not in used:
                        parts[0] = "data"
                        spec = jax.sharding.PartitionSpec(*parts)
        return jax.sharding.NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map(one, axes_tree, abstract_tree, is_leaf=_is_axes)


def make_train_step(cfg: ModelConfig, opt: Optimizer):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = R.loss_fn(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        micro = cfg.microbatch
        lead = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if micro and micro < lead:
            n_acc = lead // micro
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(n_acc, micro, *x.shape[1:]), batch
            )
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc_step(carry, b):
                lsum, gsum = carry
                l, g = grads_of(params, b)
                gsum = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g
                )
                return (lsum + l, gsum), None

            (lsum, gsum), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zero_g), mb
            )
            loss = lsum / n_acc
            grads = jax.tree_util.tree_map(lambda g: g / n_acc, gsum)
        else:
            loss, grads = grads_of(params, batch)
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, max_seq: int, greedy: bool = True):
    """decode: (params, token (B,1), cache) -> (next_token (B,1), cache)."""
    step_fn = R.decode_fn(cfg, max_seq)

    def serve_step(params, token, cache):
        logits, cache = step_fn(params, token, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    fwd = R.forward_fn(cfg)

    def prefill_step(params, batch):
        logits = fwd(params, batch)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    return prefill_step

"""Decoder-only LM backbone: dense / MoE / VLM-prefix / SSM families.

One scanned, remat'd layer stack (compile cost O(1) in depth).  Forward
(train/prefill), prefill-with-cache, and single-token decode paths share
the same parameter structure.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .attention import attn_decode, attn_full, cache_layout, init_attention
from .common import ParamFactory, pad_vocab, rms_norm
from .mlp import init_mlp, mlp_apply
from .moe import init_moe, moe_apply_with_aux
from .ssm import init_mamba, mamba_decode, mamba_full, mamba_state_shapes

__all__ = [
    "init_lm",
    "lm_forward",
    "lm_loss",
    "make_decode_cache",
    "lm_decode_step",
]


# ------------------------------------------------------------------ init
def _init_layer_stack(cfg, f: ParamFactory) -> dict:
    L = cfg.n_layers
    d = cfg.d_model
    p = {"ln1": f.const(1.0, (L, d), ("layers", "embed"))}
    if cfg.family == "ssm":
        p["mixer"] = init_mamba(cfg, f, layers=L)
        return p
    p["attn"] = init_attention(cfg, f, layers=L)
    p["ln2"] = f.const(1.0, (L, d), ("layers", "embed"))
    if cfg.n_experts and cfg.moe_every == 1:
        p["moe"] = init_moe(cfg, f, layers=L)
    else:
        p["mlp"] = init_mlp(cfg, f, cfg.d_ff, layers=L)
    return p


def init_lm(cfg, f: ParamFactory) -> dict:
    V = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    params = {
        "embed": f.param((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": f.const(1.0, (d,), ("embed",)),
        "layers": _init_layer_stack(cfg, f),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = f.param((V, d), ("vocab", "embed"), scale=0.02)
    if cfg.family == "vlm":
        # Stub frontend adapter: precomputed patch embeddings -> d_model.
        params["vision_proj"] = f.param((d, d), ("embed", None))
    return params


# ------------------------------------------------------------------ blocks
def _block_full(cfg, lp: dict, x: jax.Array, positions: jax.Array, prefix_len: int):
    """One layer, full-sequence. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        return x + mamba_full(cfg, lp["mixer"], h), aux
    a = attn_full(
        cfg,
        lp["attn"],
        h,
        positions,
        causal=True,
        window=cfg.sliding_window,
        prefix_len=prefix_len,
    )
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        m, aux = moe_apply_with_aux(cfg, lp["moe"], h)
    else:
        m = mlp_apply(cfg, lp["mlp"], h)
    return x + m, aux


# ------------------------------------------------------------------ forward
def _embed_inputs(cfg, params, tokens, prefix_embeds):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.family == "vlm":
        assert prefix_embeds is not None
        pe = jnp.einsum("bpd,de->bpe", prefix_embeds.astype(cfg.activation_dtype),
                        params["vision_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    return shard_hint(x, ("batch", "seq", "embed"))


def lm_forward(
    cfg,
    params: dict,
    tokens: jax.Array,  # (B, S_text)
    prefix_embeds: Optional[jax.Array] = None,  # (B, P, d) for VLM
    return_hidden: bool = False,
) -> jax.Array:
    """Logits over the padded vocab: (B, S_total, V)."""
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    B, S, d = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    prefix_len = prefix_embeds.shape[1] if prefix_embeds is not None else 0

    def body(carry, lp):
        x, aux = carry
        x, a = _block_full(cfg, lp, x, positions, prefix_len)
        return (x, aux + a), None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:  # unrolled: used by dry-run cost calibration (exact per-layer flops)
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda t: t[i], params["layers"])
            carry, _ = fn(carry, lp)
        x, aux = carry
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    logits = shard_hint(logits, ("batch", "seq", "vocab"))
    return logits, aux


def cross_entropy(cfg, hidden: jax.Array, table: jax.Array, labels: jax.Array):
    """CE over the padded vocab; optionally token-chunked (cfg.loss_chunk).

    The chunked path never materializes the full (B, S, V) f32 logits —
    each unrolled chunk computes (B, c, V), reduces to per-token nll, and
    is dead after use.  This is the §Perf 'memory-term' optimization for
    vocab-heavy archs; the full path is the paper-faithful baseline."""
    B, S, d = hidden.shape
    V = table.shape[0]
    vmask = jnp.arange(V) < cfg.vocab_size

    def chunk_nll(xc, lc):
        logits = jnp.einsum("bsd,vd->bsv", xc, table)
        logits = shard_hint(logits, ("batch", "seq", "vocab"))
        logits = jnp.where(
            vmask[None, None, :], logits.astype(jnp.float32), -1e30
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - ll)

    c = cfg.loss_chunk
    if not c or c >= S:
        return chunk_nll(hidden, labels) / (B * S)
    total = jnp.zeros((), jnp.float32)
    # Unrolled (not scanned) so HLO cost analysis sees every chunk.
    for i in range(0, S, c):
        total = total + chunk_nll(hidden[:, i : i + c], labels[:, i : i + c])
    return total / (B * S)


def lm_loss(
    cfg,
    params: dict,
    tokens: jax.Array,
    labels: jax.Array,
    prefix_embeds: Optional[jax.Array] = None,
    aux_weight: float = 0.01,
) -> jax.Array:
    hidden, aux = lm_forward(cfg, params, tokens, prefix_embeds, return_hidden=True)
    if cfg.family == "vlm":  # loss only on the text positions
        P = prefix_embeds.shape[1]
        hidden = hidden[:, P:, :]
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    nll = cross_entropy(cfg, hidden, table, labels)
    return nll + aux_weight * aux


# ------------------------------------------------------------------ decode
def _scan_or_unroll(cfg, body, carry, xs):
    """lax.scan over stacked layers, or an unrolled python loop that stacks
    the per-layer outputs (dry-run cost calibration path)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda t: t[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *ys)
    return carry, stacked


def make_decode_cache(cfg, f: ParamFactory, batch: int, max_seq: int) -> dict:
    """Pre-allocated decode cache pytree (zeros / abstract / axes by mode)."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        (cs, hs) = mamba_state_shapes(cfg, batch)
        return {
            "conv": f.param((L, *cs), ("layers", "batch", "conv", "inner"), zero=True),
            "h": f.param(
                (L, *hs), ("layers", "batch", "inner", "state"),
                zero=True, dtype=jnp.float32,
            ),
            "pos": f.param((), (), zero=True, dtype=jnp.int32),
        }
    layout = cache_layout(cfg, max_seq)
    kv = (L, batch, layout.seq, cfg.n_kv_heads, cfg.head_dim)
    lax_ = ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim")
    return {
        "k": f.param(kv, lax_, zero=True),
        "v": f.param(kv, lax_, zero=True),
        "pos": f.param((), (), zero=True, dtype=jnp.int32),
    }


def lm_decode_step(cfg, params: dict, token: jax.Array, cache: dict, max_seq: int):
    """One decode step. token: (B, 1) int32. Returns (logits (B,1,V), cache)."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.activation_dtype)
    x = shard_hint(x, ("batch", "seq", "embed"))
    pos = cache["pos"]

    if cfg.family == "ssm":

        def body(x, xs):
            lp, conv, h = xs
            hn = rms_norm(x, lp["ln1"], cfg.norm_eps)
            out, conv, h = mamba_decode(cfg, lp["mixer"], hn, conv, h)
            return x + out, (conv, h)

        x, (conv, h) = _scan_or_unroll(
            cfg, body, x, (params["layers"], cache["conv"], cache["h"])
        )
        new_cache = {"conv": conv, "h": h, "pos": pos + 1}
    else:
        layout = cache_layout(cfg, max_seq)

        def body(x, xs):
            lp, kc, vc = xs
            hn = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, kc, vc = attn_decode(cfg, lp["attn"], hn, kc, vc, pos, layout)
            x = x + a
            hn = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                m, _ = moe_apply_with_aux(cfg, lp["moe"], hn)
            else:
                m = mlp_apply(cfg, lp["mlp"], hn)
            return x + m, (kc, vc)

        x, (k, v) = _scan_or_unroll(
            cfg, body, x, (params["layers"], cache["k"], cache["v"])
        )
        new_cache = {"k": k, "v": v, "pos": pos + 1}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    logits = shard_hint(logits, ("batch", "seq", "vocab"))
    return logits, new_cache

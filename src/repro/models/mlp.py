"""Feed-forward blocks: SwiGLU / GeGLU (gated) and squared-ReLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .common import ParamFactory

__all__ = ["init_mlp", "mlp_apply", "is_gated", "act_fn"]


def is_gated(activation: str) -> bool:
    return activation in ("swiglu", "geglu")


def act_fn(activation: str):
    if activation in ("swiglu",):
        return jax.nn.silu
    if activation in ("geglu", "gelu"):
        return jax.nn.gelu
    if activation == "relu2":  # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {activation!r}")


def init_mlp(cfg, f: ParamFactory, d_ff: int, layers: int | None = None) -> dict:
    d = cfg.d_model
    L = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    p = {}
    if is_gated(cfg.activation):
        p["wg"] = f.param(L + (d, d_ff), lax_ + ("embed", "ff"))
        p["wu"] = f.param(L + (d, d_ff), lax_ + ("embed", "ff"))
    else:
        p["wu"] = f.param(L + (d, d_ff), lax_ + ("embed", "ff"))
    p["wd"] = f.param(L + (d_ff, d), lax_ + ("ff_in", "embed"))
    return p


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.activation)
    up = jnp.einsum("bsd,df->bsf", x, p["wu"])
    up = shard_hint(up, ("batch", "seq", "ff"))
    if is_gated(cfg.activation):
        gate = jnp.einsum("bsd,df->bsf", x, p["wg"])
        gate = shard_hint(gate, ("batch", "seq", "ff"))
        h = act(gate) * up
    else:
        h = act(up)
    out = jnp.einsum("bsf,fd->bsd", h, p["wd"])
    return shard_hint(out, ("batch", "seq", "embed"))

"""Encoder-decoder backbone (seamless-m4t style, audio frontend stubbed).

The modality frontend is a STUB per the brief: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model); a learned adapter
projects them into the encoder.  Decoder = self-attention (causal, cached)
+ cross-attention (static K/V, precomputed at prefill) + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .attention import (
    attn_decode,
    attn_full,
    cache_layout,
    cross_attn_decode,
    cross_attn_full,
    init_attention,
    init_cross_attention,
    precompute_cross_kv,
)
from .common import ParamFactory, pad_vocab, rms_norm
from .mlp import init_mlp, mlp_apply
from .transformer import _scan_or_unroll, cross_entropy

__all__ = [
    "init_encdec",
    "encdec_encode",
    "encdec_forward",
    "encdec_loss",
    "make_encdec_cache",
    "encdec_decode_step",
]


def init_encdec(cfg, f: ParamFactory) -> dict:
    V = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    enc = {
        "ln1": f.const(1.0, (Le, d), ("layers", "embed")),
        "attn": init_attention(cfg, f, layers=Le),
        "ln2": f.const(1.0, (Le, d), ("layers", "embed")),
        "mlp": init_mlp(cfg, f, cfg.d_ff, layers=Le),
    }
    dec = {
        "ln1": f.const(1.0, (Ld, d), ("layers", "embed")),
        "self_attn": init_attention(cfg, f, layers=Ld),
        "ln2": f.const(1.0, (Ld, d), ("layers", "embed")),
        "cross_attn": init_cross_attention(cfg, f, layers=Ld),
        "ln3": f.const(1.0, (Ld, d), ("layers", "embed")),
        "mlp": init_mlp(cfg, f, cfg.d_ff, layers=Ld),
    }
    return {
        "frontend_proj": f.param((d, d), ("embed", None)),
        "embed": f.param((V, d), ("vocab", "embed"), scale=0.02),
        "enc": enc,
        "enc_norm": f.const(1.0, (d,), ("embed",)),
        "dec": dec,
        "final_norm": f.const(1.0, (d,), ("embed",)),
        "unembed": f.param((V, d), ("vocab", "embed"), scale=0.02),
    }


def encdec_encode(cfg, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder memory (B, S_enc, d)."""
    x = jnp.einsum("bsd,de->bse", frames.astype(cfg.activation_dtype),
                   params["frontend_proj"])
    x = shard_hint(x, ("batch", "seq", "embed"))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn_full(cfg, lp["attn"], h, positions, causal=False)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(cfg, lp["mlp"], h), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = _scan_or_unroll(cfg, fn, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def encdec_forward(cfg, params: dict, frames: jax.Array, dec_tokens: jax.Array,
                   return_hidden: bool = False):
    """Teacher-forced logits (B, S_dec, V)."""
    memory = encdec_encode(cfg, params, frames)
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.activation_dtype)
    x = shard_hint(x, ("batch", "seq", "embed"))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn_full(cfg, lp["self_attn"], h, positions, causal=True)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + cross_attn_full(cfg, lp["cross_attn"], h, memory)
        h = rms_norm(x, lp["ln3"], cfg.norm_eps)
        return x + mlp_apply(cfg, lp["mlp"], h), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = _scan_or_unroll(cfg, fn, x, params["dec"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    logits = jnp.einsum("bsd,vd->bsv", x, params["unembed"])
    return shard_hint(logits, ("batch", "seq", "vocab"))


def encdec_loss(cfg, params, frames, dec_tokens, labels):
    hidden = encdec_forward(cfg, params, frames, dec_tokens, return_hidden=True)
    return cross_entropy(cfg, hidden, params["unembed"], labels)


def make_encdec_cache(cfg, f: ParamFactory, batch: int, max_seq: int, enc_len: int):
    L = cfg.n_layers
    layout = cache_layout(cfg, max_seq)
    kv = (L, batch, layout.seq, cfg.n_kv_heads, cfg.head_dim)
    ckv = (L, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    lax_ = ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim")
    return {
        "k": f.param(kv, lax_, zero=True),
        "v": f.param(kv, lax_, zero=True),
        "cross_k": f.param(ckv, lax_, zero=True),
        "cross_v": f.param(ckv, lax_, zero=True),
        "pos": f.param((), (), zero=True, dtype=jnp.int32),
    }


def prefill_cross_kv(cfg, params: dict, frames: jax.Array):
    """Encoder pass + per-layer cross K/V (the static part of the cache)."""
    memory = encdec_encode(cfg, params, frames)

    def body(_, lp):
        k, v = precompute_cross_kv(cfg, lp["cross_attn"], memory)
        return None, (k, v)

    _, (ck, cv) = _scan_or_unroll(cfg, body, None, params["dec"])
    return memory, ck, cv


def encdec_decode_step(cfg, params: dict, token: jax.Array, cache: dict, max_seq: int):
    """One decoder step against precomputed cross K/V."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.activation_dtype)
    x = shard_hint(x, ("batch", "seq", "embed"))
    pos = cache["pos"]
    layout = cache_layout(cfg, max_seq)

    def body(x, xs):
        lp, kc, vc, ck, cv = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, kc, vc = attn_decode(cfg, lp["self_attn"], h, kc, vc, pos, layout)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + cross_attn_decode(cfg, lp["cross_attn"], h, ck, cv)
        h = rms_norm(x, lp["ln3"], cfg.norm_eps)
        return x + mlp_apply(cfg, lp["mlp"], h), (kc, vc)

    x, (k, v) = _scan_or_unroll(
        cfg, body, x,
        (params["dec"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["unembed"])
    logits = shard_hint(logits, ("batch", "seq", "vocab"))
    new_cache = dict(cache, k=k, v=v, pos=pos + 1)
    return logits, new_cache

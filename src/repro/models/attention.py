"""Attention: GQA/MQA/MHA, sliding-window, prefix-LM, cross-attn, KV cache.

Full-sequence (train/prefill) and single-token decode paths.  Decode uses a
pre-allocated cache (B, S_max, KV, D) updated in place at ``pos`` — for
sliding-window attention the cache is a ring buffer of size ``window``.
Softmax runs in f32; matmuls in the activation dtype.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .common import ParamFactory, apply_rope, make_rope

__all__ = [
    "init_attention",
    "attn_full",
    "attn_decode",
    "init_cross_attention",
    "cross_attn_full",
    "precompute_cross_kv",
    "cross_attn_decode",
]

_NEG_INF = -1e30


def init_attention(cfg, f: ParamFactory, layers: int | None = None) -> dict:
    """QKV + output projections; optional leading stacked-layer dim."""
    d, H, KV, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    p = {
        "wq": f.param(L + (d, H, D), lax_ + ("embed", "heads", "head_dim")),
        "wk": f.param(L + (d, KV, D), lax_ + ("embed", "kv_heads", "head_dim")),
        "wv": f.param(L + (d, KV, D), lax_ + ("embed", "kv_heads", "head_dim")),
        "wo": f.param(L + (H, D, d), lax_ + ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = f.param(L + (H, D), lax_ + ("heads", "head_dim"), zero=True)
        p["bk"] = f.param(L + (KV, D), lax_ + ("kv_heads", "head_dim"), zero=True)
        p["bv"] = f.param(L + (KV, D), lax_ + ("kv_heads", "head_dim"), zero=True)
    return p


def _qkv(cfg, p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard_hint(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_hint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_hint(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _gqa_scores(q, k, scale):
    """q (B,S,H,D), k (B,T,KV,D) -> scores (B,KV,G,S,T) in f32."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    return s * scale


def _attend(probs, v):
    """probs (B,KV,G,S,T) f32, v (B,T,KV,D) -> (B,S,H,D)."""
    B, KV, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, KV * G, -1)


def attn_full(
    cfg,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
) -> jax.Array:
    """Full self-attention. ``prefix_len`` > 0 gives a bidirectional prefix
    (prefix-LM, used by the VLM's image tokens)."""
    B, S, d = x.shape
    q, k, v = _qkv(cfg, p, x)
    cos, sin = make_rope(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = _gqa_scores(q, k, 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32))

    ii = positions[:, None]  # (S, 1) query pos
    jj = positions[None, :]  # (1, S) key pos
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= jj <= ii
        if prefix_len > 0:  # bidirectional over the prefix block
            mask |= (ii < prefix_len) & (jj < prefix_len)
    if window is not None:
        mask &= ii - jj < window
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _attend(probs, v)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return shard_hint(out, ("batch", "seq", "embed"))


class DecodeCacheLayout(NamedTuple):
    """Static description of one layer's KV cache."""

    seq: int  # allocated slots (= window for SWA, else max seq)
    ring: bool


def cache_layout(cfg, max_seq: int) -> DecodeCacheLayout:
    if cfg.sliding_window is not None and cfg.sliding_window < max_seq:
        return DecodeCacheLayout(cfg.sliding_window, True)
    return DecodeCacheLayout(max_seq, False)


def attn_decode(
    cfg,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    k_cache: jax.Array,  # (B, S_alloc, KV, D)
    v_cache: jax.Array,
    pos: jax.Array,  # scalar int32 — current decode position
    layout: DecodeCacheLayout,
):
    """One decode step; returns (out (B,1,d), new_k_cache, new_v_cache)."""
    B = x.shape[0]
    q, k, v = _qkv(cfg, p, x)  # (B,1,H,D)/(B,1,KV,D)
    posv = jnp.full((1,), pos, dtype=jnp.int32)
    cos, sin = make_rope(posv, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = jnp.mod(pos, layout.seq) if layout.ring else pos
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
    k_cache = shard_hint(k_cache, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))
    v_cache = shard_hint(v_cache, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))

    scores = _gqa_scores(q, k_cache, 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32))
    t = jnp.arange(layout.seq)
    valid = t <= slot if not layout.ring else (t <= slot) | (pos >= layout.seq)
    scores = jnp.where(valid[None, None, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _attend(probs, v_cache)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return shard_hint(out, ("batch", "seq", "embed")), k_cache, v_cache


# ---------------------------------------------------------------- cross-attention
def init_cross_attention(cfg, f: ParamFactory, layers: int | None = None) -> dict:
    return init_attention(cfg, f, layers)


def cross_attn_full(cfg, p: dict, x: jax.Array, memory: jax.Array) -> jax.Array:
    """Decoder attends to encoder ``memory`` (B, T, d). No RoPE, no mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    scores = _gqa_scores(q, k, 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    out = _attend(probs, v)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return shard_hint(out, ("batch", "seq", "embed"))


def precompute_cross_kv(cfg, p: dict, memory: jax.Array):
    """Cross-attention K/V are static per sequence — computed once at prefill."""
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def cross_attn_decode(cfg, p: dict, x: jax.Array, ck: jax.Array, cv: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    scores = _gqa_scores(q, ck, 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    out = _attend(probs, cv)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return shard_hint(out, ("batch", "seq", "embed"))

"""Unified model API over all families: init / loss / forward / decode.

Every architecture exposes the same four callables regardless of family,
so the launcher, dry-run, trainer and serving engine are arch-agnostic:

  init_params(cfg, key|mode)        -> params pytree (arrays/abstract/axes)
  loss_fn(cfg)(params, batch)       -> scalar loss        [train shapes]
  forward_fn(cfg)(params, batch)    -> logits             [prefill shapes]
  decode_fn(cfg, max_seq)(params, token, cache) -> (logits, cache) [decode]
  make_cache(cfg, batch, max_seq, mode) -> cache pytree
  input_specs(cfg, shape)           -> ShapeDtypeStruct batch for lowering
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .common import ParamFactory
from . import encdec as _encdec
from . import hybrid as _hybrid
from . import transformer as _lm

__all__ = [
    "init_params",
    "param_axes",
    "loss_fn",
    "forward_fn",
    "decode_fn",
    "make_cache",
    "cache_axes",
    "input_specs",
    "batch_axes",
]


def _factory(cfg, key=None, mode="init"):
    dtype = cfg.activation_dtype
    return ParamFactory(key, dtype=dtype, mode=mode)


def init_params(cfg: ModelConfig, key: Optional[jax.Array] = None, mode="init"):
    f = _factory(cfg, key, mode)
    if cfg.family == "encdec":
        return _encdec.init_encdec(cfg, f)
    if cfg.family == "hybrid":
        return _hybrid.init_hybrid(cfg, f)
    return _lm.init_lm(cfg, f)


def param_axes(cfg: ModelConfig):
    return init_params(cfg, mode="axes")


def abstract_params(cfg: ModelConfig):
    return init_params(cfg, mode="abstract")


# ------------------------------------------------------------------ train
def loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":

        def loss(params, batch):
            return _encdec.encdec_loss(
                cfg, params, batch["frames"], batch["dec_tokens"], batch["labels"]
            )

        return loss
    if cfg.family == "hybrid":

        def loss(params, batch):
            return _hybrid.hybrid_loss(cfg, params, batch["tokens"], batch["labels"])

        return loss
    if cfg.family == "vlm":

        def loss(params, batch):
            return _lm.lm_loss(
                cfg, params, batch["tokens"], batch["labels"],
                prefix_embeds=batch["patches"],
            )

        return loss

    def loss(params, batch):
        return _lm.lm_loss(cfg, params, batch["tokens"], batch["labels"])

    return loss


# ------------------------------------------------------------------ prefill
def forward_fn(cfg: ModelConfig):
    if cfg.family == "encdec":

        def fwd(params, batch):
            return _encdec.encdec_forward(
                cfg, params, batch["frames"], batch["dec_tokens"]
            )

        return fwd
    if cfg.family == "hybrid":

        def fwd(params, batch):
            return _hybrid.hybrid_forward(cfg, params, batch["tokens"])[0]

        return fwd
    if cfg.family == "vlm":

        def fwd(params, batch):
            return _lm.lm_forward(
                cfg, params, batch["tokens"], prefix_embeds=batch["patches"]
            )[0]

        return fwd

    def fwd(params, batch):
        return _lm.lm_forward(cfg, params, batch["tokens"])[0]

    return fwd


# ------------------------------------------------------------------ decode
def decode_fn(cfg: ModelConfig, max_seq: int):
    if cfg.family == "encdec":

        def step(params, token, cache):
            return _encdec.encdec_decode_step(cfg, params, token, cache, max_seq)

        return step
    if cfg.family == "hybrid":

        def step(params, token, cache):
            return _hybrid.hybrid_decode_step(cfg, params, token, cache, max_seq)

        return step

    def step(params, token, cache):
        return _lm.lm_decode_step(cfg, params, token, cache, max_seq)

    return step


def make_cache(cfg: ModelConfig, batch: int, max_seq: int, mode="init",
               enc_len: Optional[int] = None):
    f = _factory(cfg, jax.random.PRNGKey(0) if mode == "init" else None, mode)
    if cfg.family == "encdec":
        return _encdec.make_encdec_cache(cfg, f, batch, max_seq, enc_len or max_seq)
    if cfg.family == "hybrid":
        return _hybrid.make_hybrid_cache(cfg, f, batch, max_seq)
    return _lm.make_decode_cache(cfg, f, batch, max_seq)


def cache_axes(cfg: ModelConfig, batch: int, max_seq: int, enc_len=None):
    return make_cache(cfg, batch, max_seq, mode="axes", enc_len=enc_len)


# ------------------------------------------------------------------ input specs
def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for one step's data inputs (no allocation).

    train:   token/label batch            -> loss_fn
    prefill: token batch (no labels)      -> forward_fn
    decode:  one new token (cache separate; see ``make_cache(mode='abstract')``)
    """
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    act = cfg.activation_dtype
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": _tok((B, S, d), act),
                "dec_tokens": _tok((B, S)),
                "labels": _tok((B, S)),
            }
        if cfg.family == "vlm":
            P = cfg.n_prefix
            return {
                "patches": _tok((B, P, d), act),
                "tokens": _tok((B, S - P)),
                "labels": _tok((B, S - P)),
            }
        return {"tokens": _tok((B, S)), "labels": _tok((B, S))}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": _tok((B, S, d), act), "dec_tokens": _tok((B, S))}
        if cfg.family == "vlm":
            P = cfg.n_prefix
            return {"patches": _tok((B, P, d), act), "tokens": _tok((B, S - P))}
        return {"tokens": _tok((B, S))}
    if shape.kind == "decode":
        return {"token": _tok((B, 1))}
    raise ValueError(shape.kind)


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical axes for each input (drives in_shardings)."""
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            ax = {
                "frames": ("batch", "seq", "embed"),
                "dec_tokens": ("batch", "seq"),
            }
            if shape.kind == "train":
                ax["labels"] = ("batch", "seq")
            return ax
        if cfg.family == "vlm":
            ax = {"patches": ("batch", "seq", "embed"), "tokens": ("batch", "seq")}
            if shape.kind == "train":
                ax["labels"] = ("batch", "seq")
            return ax
        ax = {"tokens": ("batch", "seq")}
        if shape.kind == "train":
            ax["labels"] = ("batch", "seq")
        return ax
    return {"token": ("batch", "seq")}

"""Token-choice top-k Mixture-of-Experts with capacity-bounded dispatch.

Dispatch uses the sort-free scatter formulation: per-token top-k expert ids
-> position-in-expert via a cumulative count -> scatter into the (E*C, d)
expert buffer -> grouped expert FFN -> gather-combine weighted by the
(renormalized) router gates.  Tokens past an expert's capacity are dropped
(standard token-choice semantics).

This mirrors the paper's bucket/workload-queue structure exactly: experts
are buckets, the router assigns work units, capacity is the workload-queue
bound, and the dense-batched expert FFN is the shared sequential pass.  The
hybrid gather-vs-dense execution lives in ``kernels/grouped_matmul``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .common import ParamFactory
from .mlp import act_fn, is_gated

__all__ = ["init_moe", "moe_apply", "moe_capacity"]


def init_moe(cfg, f: ParamFactory, layers: int | None = None) -> dict:
    d, E = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    L = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    p = {"router": f.param(L + (d, E), lax_ + ("embed", "experts"), scale=0.02)}
    if is_gated(cfg.activation):
        p["wg"] = f.param(L + (E, d, ff), lax_ + ("experts", "embed", "expert_ff"))
        p["wu"] = f.param(L + (E, d, ff), lax_ + ("experts", "embed", "expert_ff"))
    else:
        p["wu"] = f.param(L + (E, d, ff), lax_ + ("experts", "embed", "expert_ff"))
    p["wd"] = f.param(L + (E, ff, d), lax_ + ("experts", "expert_ff", "embed"))
    return p


def moe_capacity(cfg, n_tokens: int) -> int:
    """Static per-expert capacity C = ceil(k*T*cf/E), padded to 256.

    The 256 padding (a) tile-aligns the grouped-matmul kernel and (b) keeps
    C divisible by the 16-way data axis so the 'expert_cap' sharding rule
    can shard the capacity dim (§Perf: a silent 8-padding made the rule a
    no-op on mixtral)."""
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return -(-c // 256) * 256


def moe_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Also returns aux losses via cfg hook-free
    summation (load-balance loss is returned as second output by
    ``moe_apply_with_aux``)."""
    out, _ = moe_apply_with_aux(cfg, p, x)
    return out


def _dispatch_onehot(top_idx, E: int, C: int):
    """Baseline dispatch: position-in-expert via one-hot cumsum.

    O(T*k*E) intermediate — the classic Mesh-TF formulation.  Dominates
    compiled flops for large E (moonshot: 64 experts); see §Perf."""
    oh = jax.nn.one_hot(top_idx.reshape(-1), E, dtype=jnp.float32)  # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh  # exclusive prefix count
    pos_in_e = jnp.sum(pos * oh, axis=-1)  # (T*k,)
    within = pos_in_e < C
    expert_flat = top_idx.reshape(-1)
    dest = (expert_flat * C + pos_in_e.astype(jnp.int32)).astype(jnp.int32)
    dest = jnp.where(within, dest, E * C)  # overflow slot (dropped)
    return dest, within


def _dispatch_sort(top_idx, E: int, C: int):
    """Optimized dispatch: O(T*k log) sort instead of the one-hot cumsum.

    Sort (expert, token) pairs by expert; rank within expert = position -
    first-position-of-expert (via searchsorted on the sorted keys)."""
    Tk = top_idx.size
    flat_e = top_idx.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat_e, stable=True)  # (T*k,)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32))
    rank = jnp.arange(Tk, dtype=jnp.int32) - starts[sorted_e]
    within_sorted = rank < C
    dest_sorted = jnp.where(within_sorted, sorted_e * C + rank, E * C)
    # Scatter back to (token, choice) order.
    dest = jnp.zeros((Tk,), jnp.int32).at[order].set(dest_sorted)
    within = jnp.zeros((Tk,), bool).at[order].set(within_sorted)
    return dest, within


def moe_apply_with_aux(cfg, p: dict, x: jax.Array):
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = moe_capacity(cfg, T)
    act = act_fn(cfg.activation)

    xf = x.reshape(T, d)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xf, p["router"], preferred_element_type=jnp.float32),
        axis=-1,
    )  # (T, E) f32
    top_vals, top_idx = jax.lax.top_k(gates, k)  # (T, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e.
    me = jnp.mean(gates, axis=0)  # (E,)
    one_hot_top1 = jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32)
    fe = jnp.mean(one_hot_top1, axis=0)
    aux_loss = E * jnp.sum(fe * me)

    if getattr(cfg, "moe_dispatch", "onehot") == "sort":
        dest, within = _dispatch_sort(top_idx, E, C)
    else:
        dest, within = _dispatch_onehot(top_idx, E, C)
    e_idx = jnp.minimum(dest // C, E - 1).astype(jnp.int32)
    # overflow -> rank C: out-of-bounds scatter indices are DROPPED under
    # jit, which implements capacity dropping with no overflow row.
    rank = jnp.where(within, dest - e_idx * C, C).astype(jnp.int32)

    x_rep = jnp.repeat(xf, k, axis=0)  # (T*k, d)
    buf = shard_hint(
        jnp.zeros((E, C, d), dtype=x.dtype), ("experts", "expert_cap", "embed")
    )
    xe = buf.at[e_idx, rank].add(x_rep, mode="drop")
    xe = shard_hint(xe, ("experts", "expert_cap", "embed"))

    # Grouped expert FFN — a dense batched pass per expert bucket.
    up = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    up = shard_hint(up, ("experts", "expert_cap", "expert_ff"))
    if is_gated(cfg.activation):
        gate = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
        h = act(gate) * up
    else:
        h = act(up)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    ye = shard_hint(ye, ("experts", "expert_cap", "embed"))

    y_rep = ye[e_idx, jnp.minimum(rank, C - 1)]  # (T*k, d)
    w = (top_vals.reshape(-1) * within).astype(x.dtype)[:, None]  # overflow -> 0
    y = (y_rep * w).reshape(T, k, d).sum(axis=1)
    y = shard_hint(y.reshape(B, S, d), ("batch", "seq", "embed"))
    return y, aux_loss

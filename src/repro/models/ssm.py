"""Mamba-1 selective state-space block (falcon-mamba / jamba mixer).

Full-sequence path runs the selective scan with ``jax.lax.scan`` over time
(O(1) compile in sequence length); decode keeps O(1) state per layer:
a (conv-1)-sample convolution tail and the (d_inner, N) SSM state — this is
why SSM archs run the ``long_500k`` shape that full attention cannot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .common import ParamFactory

__all__ = ["init_mamba", "mamba_full", "mamba_decode", "mamba_state_shapes"]


def init_mamba(cfg, f: ParamFactory, layers: int | None = None) -> dict:
    d, di, ns, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
    cw = cfg.ssm_conv
    L = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    return {
        "in_proj": f.param(L + (d, 2 * di), lax_ + ("embed", "inner")),
        "conv_w": f.param(L + (cw, di), lax_ + ("conv", "inner"), scale=0.5),
        "conv_b": f.param(L + (di,), lax_ + ("inner",), zero=True),
        "x_proj": f.param(L + (di, dr + 2 * ns), lax_ + ("inner", None)),
        "dt_proj": f.param(L + (dr, di), lax_ + ("dt", "inner")),
        "dt_bias": f.const(0.1, L + (di,), lax_ + ("inner",), dtype=jnp.float32),
        "A_log": f.const(0.5, L + (di, ns), lax_ + ("inner", "state"), dtype=jnp.float32),
        "D": f.const(1.0, L + (di,), lax_ + ("inner",), dtype=jnp.float32),
        "out_proj": f.param(L + (di, d), lax_ + ("inner", "embed")),
    }


def _conv_full(p, xs):
    """Causal depthwise conv over time. xs: (B, S, di)."""
    cw = p["conv_w"].shape[0]
    pad = jnp.pad(xs, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(xs)
    for i in range(cw):  # tiny static loop (cw=4)
        out = out + pad[:, i : i + xs.shape[1], :] * p["conv_w"][i]
    return out + p["conv_b"]


def _ssm_params(cfg, p, xc):
    """Project to (delta, B, C) and discretize. xc: (B, S, di)."""
    dr, ns = cfg.dt_rank_actual, cfg.ssm_state
    dbc = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"])
    dt, B_, C_ = jnp.split(dbc, [dr, dr + ns], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )  # (B, S, di) f32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ns)
    return delta, B_.astype(jnp.float32), C_.astype(jnp.float32), A


def mamba_full(cfg, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = shard_hint(xz, ("batch", "seq", "inner"))
    xp, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_full(p, xp))

    delta, B_, C_, A = _ssm_params(cfg, p, xc)
    dA = jnp.exp(delta[..., None] * A)  # (B, S, di, ns)
    dBx = delta[..., None] * B_[:, :, None, :] * xc.astype(jnp.float32)[..., None]

    def step(h, t):
        dA_t, dBx_t, C_t = t
        h = h * dA_t + dBx_t  # (B, di, ns)
        y = jnp.einsum("bin,bn->bi", h, C_t)
        return h, y

    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        h0,
        (
            jnp.moveaxis(dA, 1, 0),
            jnp.moveaxis(dBx, 1, 0),
            jnp.moveaxis(C_, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, di)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return shard_hint(out, ("batch", "seq", "embed"))


def mamba_state_shapes(cfg, batch: int):
    """Decode state: conv tail (B, conv-1, di) + SSM state (B, di, ns)."""
    return (
        (batch, cfg.ssm_conv - 1, cfg.d_inner),
        (batch, cfg.d_inner, cfg.ssm_state),
    )


def mamba_decode(cfg, p: dict, x: jax.Array, conv_state: jax.Array, h: jax.Array):
    """One token. x: (B, 1, d); returns (out, new_conv_state, new_h)."""
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]  # (B, 2di)
    xp, z = jnp.split(xz, 2, axis=-1)

    # conv ring: state holds the last (cw-1) inputs.
    cw = cfg.ssm_conv
    hist = jnp.concatenate([conv_state, xp[:, None, :]], axis=1)  # (B, cw, di)
    xc = jnp.einsum("bci,ci->bi", hist, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv = hist[:, 1:, :]

    delta, B_, C_, A = _ssm_params(cfg, p, xc[:, None, :])
    delta, B_, C_ = delta[:, 0], B_[:, 0], C_[:, 0]
    dA = jnp.exp(delta[..., None] * A)  # (B, di, ns)
    h = h * dA + delta[..., None] * B_[:, None, :] * xc.astype(jnp.float32)[..., None]
    y = jnp.einsum("bin,bn->bi", h, C_) + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return shard_hint(out, ("batch", "seq", "embed")), new_conv, h

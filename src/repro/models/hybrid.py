"""Hybrid Mamba+Attention+MoE backbone (Jamba-style, 1:7 attn:mamba).

Layers are grouped into *periods* of ``attn_period`` layers: one attention
layer (at ``attn_offset``) and ``attn_period-1`` Mamba mixers; the MLP is a
MoE on layers where ``global_idx % moe_every == moe_offset``.  The stack
scans over periods (compile cost O(1) in depth); within a period the
fixed layer pattern is unrolled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint
from .attention import attn_decode, attn_full, cache_layout, init_attention
from .common import ParamFactory, pad_vocab, rms_norm
from .mlp import init_mlp, mlp_apply
from .moe import init_moe, moe_apply_with_aux
from .ssm import init_mamba, mamba_decode, mamba_full, mamba_state_shapes
from .transformer import _scan_or_unroll, cross_entropy

__all__ = [
    "init_hybrid",
    "hybrid_forward",
    "hybrid_loss",
    "make_hybrid_cache",
    "hybrid_decode_step",
]


def _n_periods(cfg) -> int:
    assert cfg.n_layers % cfg.attn_period == 0
    return cfg.n_layers // cfg.attn_period


def init_hybrid(cfg, f: ParamFactory) -> dict:
    V = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    P = _n_periods(cfg)
    period: dict[str, dict] = {}
    for i in range(cfg.attn_period):
        lp: dict = {"ln1": f.const(1.0, (P, d), ("layers", "embed"))}
        if i == cfg.attn_offset:
            lp["attn"] = init_attention(cfg, f, layers=P)
        else:
            lp["mixer"] = init_mamba(cfg, f, layers=P)
        lp["ln2"] = f.const(1.0, (P, d), ("layers", "embed"))
        if cfg.n_experts and (i % cfg.moe_every == cfg.moe_offset):
            lp["moe"] = init_moe(cfg, f, layers=P)
        else:
            lp["mlp"] = init_mlp(cfg, f, cfg.d_ff, layers=P)
        period[f"layer{i}"] = lp
    return {
        "embed": f.param((V, d), ("vocab", "embed"), scale=0.02),
        "periods": period,
        "final_norm": f.const(1.0, (d,), ("embed",)),
        "unembed": f.param((V, d), ("vocab", "embed"), scale=0.02),
    }


def hybrid_forward(cfg, params: dict, tokens: jax.Array, return_hidden: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    x = shard_hint(x, ("batch", "seq", "embed"))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def period_body(carry, pp):
        x, aux = carry
        for i in range(cfg.attn_period):
            lp = pp[f"layer{i}"]
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if "attn" in lp:
                x = x + attn_full(cfg, lp["attn"], h, positions, causal=True,
                                  window=cfg.sliding_window)
            else:
                x = x + mamba_full(cfg, lp["mixer"], h)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                m, a = moe_apply_with_aux(cfg, lp["moe"], h)
                aux = aux + a
            else:
                m = mlp_apply(cfg, lp["mlp"], h)
            x = x + m
        return (x, aux), None

    fn = jax.checkpoint(period_body) if cfg.remat else period_body
    (x, aux), _ = _scan_or_unroll(
        cfg, fn, (x, jnp.zeros((), jnp.float32)), params["periods"]
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux
    logits = jnp.einsum("bsd,vd->bsv", x, params["unembed"])
    return shard_hint(logits, ("batch", "seq", "vocab")), aux


def hybrid_loss(cfg, params, tokens, labels, aux_weight: float = 0.01):
    hidden, aux = hybrid_forward(cfg, params, tokens, return_hidden=True)
    nll = cross_entropy(cfg, hidden, params["unembed"], labels)
    return nll + aux_weight * aux


def make_hybrid_cache(cfg, f: ParamFactory, batch: int, max_seq: int) -> dict:
    P = _n_periods(cfg)
    n_mamba = cfg.attn_period - 1
    layout = cache_layout(cfg, max_seq)
    (cs, hs) = mamba_state_shapes(cfg, batch)
    kv = (P, batch, layout.seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": f.param(kv, ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim"), zero=True),
        "v": f.param(kv, ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim"), zero=True),
        "conv": f.param((P, n_mamba, *cs), ("layers", None, "batch", "conv", "inner"), zero=True),
        "h": f.param((P, n_mamba, *hs), ("layers", None, "batch", "inner", "state"),
                     zero=True, dtype=jnp.float32),
        "pos": f.param((), (), zero=True, dtype=jnp.int32),
    }


def hybrid_decode_step(cfg, params: dict, token: jax.Array, cache: dict, max_seq: int):
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.activation_dtype)
    x = shard_hint(x, ("batch", "seq", "embed"))
    pos = cache["pos"]
    layout = cache_layout(cfg, max_seq)

    def period_body(x, xs):
        pp, kc, vc, conv, h = xs
        mi = 0
        new_conv, new_h = [], []
        for i in range(cfg.attn_period):
            lp = pp[f"layer{i}"]
            hn = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if "attn" in lp:
                a, kc, vc = attn_decode(cfg, lp["attn"], hn, kc, vc, pos, layout)
                x = x + a
            else:
                out, c2, h2 = mamba_decode(cfg, lp["mixer"], hn, conv[mi], h[mi])
                new_conv.append(c2)
                new_h.append(h2)
                mi += 1
                x = x + out
            hn = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                m, _ = moe_apply_with_aux(cfg, lp["moe"], hn)
            else:
                m = mlp_apply(cfg, lp["mlp"], hn)
            x = x + m
        return x, (kc, vc, jnp.stack(new_conv), jnp.stack(new_h))

    x, (k, v, conv, h) = _scan_or_unroll(
        cfg, period_body, x, (params["periods"], cache["k"], cache["v"],
                              cache["conv"], cache["h"])
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["unembed"])
    logits = shard_hint(logits, ("batch", "seq", "vocab"))
    return logits, {"k": k, "v": v, "conv": conv, "h": h, "pos": pos + 1}

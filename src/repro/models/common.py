"""Shared model building blocks: param factory, norms, rope, embeddings.

Models are functional: ``init(cfg, factory) -> params`` (nested dicts) and
``apply(cfg, params, ...) -> outputs``.  The ``ParamFactory`` runs in three
modes so the same init code yields:
  * ``init``     — real arrays (smoke tests, examples)
  * ``abstract`` — ShapeDtypeStructs (dry-run lowering: never allocates)
  * ``axes``     — logical-axis tuples (sharding spec derivation)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..sharding.logical import shard_hint

__all__ = [
    "ParamFactory",
    "rms_norm",
    "layer_norm",
    "make_rope",
    "apply_rope",
    "pad_vocab",
    "VOCAB_MULTIPLE",
]

VOCAB_MULTIPLE = 256


def pad_vocab(vocab_size: int, multiple: int = VOCAB_MULTIPLE) -> int:
    """Pad vocab so the embedding always shards over the model axis."""
    return -(-vocab_size // multiple) * multiple


class ParamFactory:
    """Builds param pytrees; mode selects array/abstract/axes leaves."""

    def __init__(self, key: Optional[jax.Array], dtype=jnp.bfloat16, mode="init"):
        assert mode in ("init", "abstract", "axes")
        self.key = key
        self.dtype = dtype
        self.mode = mode
        self._counter = 0

    def param(
        self,
        shape: Sequence[int],
        logical: Sequence[Optional[str]],
        scale: Optional[float] = None,
        zero: bool = False,
        dtype=None,
    ):
        shape = tuple(int(s) for s in shape)
        assert len(shape) == len(logical), (shape, logical)
        dtype = dtype or self.dtype
        if self.mode == "axes":
            return tuple(logical)
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        self._counter += 1
        if zero:
            return jnp.zeros(shape, dtype)
        k = jax.random.fold_in(self.key, self._counter)
        if scale is None:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    def const(self, value: float, shape, logical, dtype=None):
        shape = tuple(int(s) for s in shape)
        dtype = dtype or self.dtype
        if self.mode == "axes":
            return tuple(logical)
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.full(shape, value, dtype)


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- rope
def make_rope(positions: jax.Array, head_dim: int, theta: float = 10_000.0):
    """cos/sin tables for rotary embedding; positions (..., S) int32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:  # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------- embeddings
def embed_init(factory: ParamFactory, vocab: int, d_model: int):
    return factory.param((vocab, d_model), ("vocab", "embed"), scale=0.02)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return shard_hint(out, ("batch", "seq", "embed"))


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits; head sharded over vocab."""
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return shard_hint(logits, ("batch", "seq", "vocab"))

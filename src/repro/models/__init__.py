"""Model zoo: functional JAX backbones for the 10 assigned architectures."""
from . import attention, common, encdec, hybrid, mlp, moe, registry, ssm, transformer

__all__ = [
    "attention", "common", "encdec", "hybrid", "mlp", "moe",
    "registry", "ssm", "transformer",
]

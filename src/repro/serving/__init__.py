from .engine import AdapterSpec, LifeRaftEngine, Request, ServeConfig
from .kvcache import PagePool, SequenceAllocation

__all__ = ["AdapterSpec", "LifeRaftEngine", "Request", "ServeConfig",
           "PagePool", "SequenceAllocation"]

from .daemon import CrossMatchHost, RecoveryError, ServiceDaemon, ServingHost
from .engine import (
    AdapterSpec,
    AdapterWorkload,
    LifeRaftEngine,
    Request,
    ServeConfig,
    ShardedServingEngine,
)
from .kvcache import PagePool, SequenceAllocation

__all__ = ["AdapterSpec", "AdapterWorkload", "LifeRaftEngine", "Request",
           "ServeConfig", "ShardedServingEngine", "PagePool",
           "SequenceAllocation", "ServiceDaemon", "ServingHost",
           "CrossMatchHost", "RecoveryError"]

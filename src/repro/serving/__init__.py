from .engine import AdapterSpec, AdapterWorkload, LifeRaftEngine, Request, ServeConfig
from .kvcache import PagePool, SequenceAllocation

__all__ = ["AdapterSpec", "AdapterWorkload", "LifeRaftEngine", "Request",
           "ServeConfig", "PagePool", "SequenceAllocation"]

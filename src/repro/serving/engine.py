"""LifeRaft continuous-batching serving engine (multi-tenant LLM decode).

The paper's scheduler, re-instantiated for TPU serving:

  bucket          = a LoRA adapter's weights (expensive resident state)
  T_b             = adapter load cost (host->HBM transfer at hbm_bw)
  T_m             = marginal decode cost per request in the batch
  workload queue  = pending requests per adapter
  bucket cache    = fixed number of HBM adapter slots (LRU)
  hybrid strategy = tiny batches run the gathered multi-adapter path
                    (indexed join); contended adapters run a dense batch
                    (sequential scan) — kernels/grouped_matmul
  U_a             = Eq. 2 drives which adapter's batch runs next;
                    NoShare == per-request FCFS, RR == adapter round-robin

The scheduling round itself is the shared ``DispatchLoop``
(core/dispatch.py) — the same inner loop the cross-match engine and the
simulator run.  ``AdapterWorkload`` implements the WorkloadManager
protocol (change subscriptions, spill marks) over the per-adapter request
queues, so the incremental lazy-heap scheduler index applies to serving's
``normalized=True`` default instead of the historical O(B) rescan façade.

§6 future work is implemented through the control plane: straggler
absorption (an aged bucket's priority grows until scheduled) and workload
overflow (``ServeConfig.spill_budget`` — pending queues spill to host
when the budget is exceeded, paying ``spill_penalty_s`` to page back in).
With ``adaptive=True`` a ``ControlLoop`` retunes alpha / fuse_k / spill
every round from live queue state.

The engine runs in two modes: the default advances a virtual clock with
the roofline cost model (capacity planning, Fig. 7/8-style sweeps);
``decode_batch_fn`` executes real decode steps of a (small) model on the
current devices alongside.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..core.cache import BucketCache
from ..core.control import (
    ControlConfig,
    ControlLoop,
    TenantControlPlane,
    TenantPolicy,
)
from ..core.dispatch import DispatchLoop
from ..core.metrics import CostModel, dispatch_stats, per_tenant_latency
from ..core.prefetch import PrefetchConfig, build_pipeline, prefetch_stats
from ..core.scheduler import LifeRaftScheduler, RoundRobinScheduler
from ..core.shard import ShardMap, StealConfig, StealEvent, split_slots
from ..core.spillq import SpillBookkeepingMixin, SpillQueue
from ..core.workload import DEFAULT_TENANT

__all__ = [
    "Request",
    "AdapterSpec",
    "ServeConfig",
    "AdapterWorkload",
    "LifeRaftEngine",
    "ShardedServingEngine",
]


@dataclasses.dataclass
class Request:
    request_id: int
    adapter_id: int
    arrival_time: float
    prompt_len: int
    max_new_tokens: int
    tokens_done: int = 0
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.tokens_done >= self.max_new_tokens


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    adapter_id: int
    nbytes: int  # adapter weight bytes (sets T_b via hbm_bw)
    tenant: str = DEFAULT_TENANT  # tenant class (interactive vs batch)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    policy: str = "liferaft"  # liferaft | rr | noshare
    alpha: float = 0.25
    adapter_slots: int = 4  # HBM bucket-cache capacity
    max_batch: int = 32
    decode_quantum: int = 16  # tokens decoded per scheduled batch
    hbm_bw: float = 819e9
    per_token_cost: float = 2e-4  # T_m seconds per request-token (marginal)
    hybrid_threshold: int = 2  # batches below this use the gathered path
    fuse_k: int = 1  # adapters serviced per dispatch (grouped-matmul fusion)
    # -- shared query plans ----------------------------------------------------
    # Group the round's adapter batches into ONE masked decode call per
    # share_width-sized chunk (grouped matmul over the adapter axis)
    # instead of one device call per adapter.  In shared mode
    # ``decode_batch_fn`` is called as ``fn(group, quantum)`` with
    # ``group = [(adapter_id, batch), ...]``.  Cost accounting per
    # decision is unchanged, so decisions and completions are identical
    # with the switch off or on.
    shared_plan: bool = False
    share_width: int = 4  # adapters per shared decode call (static ceiling)
    share_width_max: int = 0  # >0 with adaptive: ControlLoop sizes the width
    # -- closed-loop control plane (core/control.py) --------------------------
    adaptive: bool = False  # retune alpha/fuse_k/spill every round
    fuse_k_max: int = 8
    alpha_step: float = 0.1
    control_halflife_s: float = 2.0  # arrival EWMA halflife (request scale)
    rate_knee: float = 200.0  # req/s at which saturation maxes out
    depth_knee: float = 64.0  # pending requests at which backlog maxes out
    spill_budget: Optional[int] = None  # §6 overflow: resident request budget
    spill_budget_bytes: Optional[float] = None  # byte-accurate §6 budget
    spill_penalty_s: float = 0.0  # T_spill host read-back surcharge
    kv_bytes_per_token: float = 1.0  # spillable host state per prompt token
    min_unit_bytes: float = 1.0  # floor per request (no zero-byte free-riders)
    # Legacy §6 unspill: page a queue's whole spilled suffix back in one
    # shot (on service and under low-water) instead of the paged
    # oldest-first protocol.  Wholesale paging can re-exceed the budget
    # the moment it lands — keep it off unless replaying old traces.
    wholesale_unspill: bool = False
    # -- scan-horizon prefetch (core/prefetch.py) ------------------------------
    # Stage the next adapters' weights into HBM ahead of their dispatch
    # (host->HBM DMA modeled as one serial channel overlapping decode).
    # Off by default: the reactive LRU path replays bit-identically.
    prefetch: bool = False
    prefetch_horizon: int = 4  # planner lookahead H (static, or AIMD init)
    prefetch_depth: int = 2  # stages in flight (2 == double buffering)
    prefetch_horizon_max: int = 0  # >0 with adaptive: ControlLoop sizes H
    # -- multi-tenant control plane (one ControlVector per adapter class) ------
    tenant_policies: Optional[tuple[TenantPolicy, ...]] = None


class _AdapterQueue(SpillQueue):
    """One adapter's pending request list on the shared ``SpillQueue``
    primitive (``core/spillq.py``) — the same resident-prefix /
    spilled-suffix container the core WorkloadQueue runs on, so the §6
    spill mechanics exist exactly once.  §6 overflow pages the *youngest*
    requests' prompt state to host (``prompt_len * kv_bytes_per_token``
    each, floored at ``min_unit_bytes`` so zero-length prompts cannot
    free-ride the budget); the oldest keep their state resident."""

    __slots__ = ("_probe_bytes", "_min_unit_bytes")

    def __init__(
        self,
        bucket_id: int,
        probe_bytes: float = 1.0,
        min_unit_bytes: float = 1.0,
    ) -> None:
        super().__init__(
            bucket_id,
            bytes_of=self._rbytes,
            arrival_of=lambda r: r.arrival_time,
            order_of=lambda r: (r.arrival_time, r.request_id),
        )
        self._probe_bytes = probe_bytes
        self._min_unit_bytes = min_unit_bytes

    def _rbytes(self, r: Request) -> float:
        return max(r.prompt_len * self._probe_bytes, self._min_unit_bytes)

    # Historical names for the two sides (the engine and the property
    # suite read these directly).
    @property
    def requests(self) -> list[Request]:
        """Resident prefix (the oldest pending requests)."""
        return self.resident

    @property
    def spilled_requests(self) -> list[Request]:
        """Spilled suffix (the youngest, on host)."""
        return self.spilled

    def all_requests(self) -> list[Request]:
        """Resident prefix first (the oldest work), then the spilled tail."""
        return self.resident + self.spilled

    def _drop_finished(self) -> None:
        """Trim finished requests (resident only — retire pages serviced
        requests in first) and rebase the byte counter."""
        self.prune_resident(lambda r: not r.done)


class AdapterWorkload(SpillBookkeepingMixin):
    """WorkloadManager protocol (subscriptions, ages, §6 spill marks) over
    per-adapter request queues.

    Having a stable, subscribable workload object — instead of the façades
    the old ``_select`` helper rebuilt on every call — is what lets the
    serving engine ride the scheduler's incremental heap index.

    ``probe_bytes`` prices one prompt token's spillable host state (KV /
    prompt cache) for the §6 byte budget (``min_unit_bytes`` floors the
    per-request price — a zero-length prompt still occupies request
    state); ``tenant_of_adapter`` maps each adapter to its tenant class
    for the multi-tenant control plane.  ``wholesale_unspill`` restores
    the legacy whole-suffix paging on service."""

    def __init__(
        self,
        adapter_ids=(),
        probe_bytes: float = 1.0,
        tenants: Optional[dict[int, str]] = None,
        min_unit_bytes: float = 1.0,
        wholesale_unspill: bool = False,
    ) -> None:
        self.probe_bytes = float(probe_bytes)
        self.min_unit_bytes = float(min_unit_bytes)
        self.wholesale_unspill = bool(wholesale_unspill)
        self.queues: dict[int, _AdapterQueue] = {
            a: _AdapterQueue(a, self.probe_bytes, self.min_unit_bytes)
            for a in adapter_ids
        }
        self._tenants: dict[int, str] = dict(tenants or {})
        self._listeners: list[Callable[[int], None]] = []
        self._spilled: set[int] = set()

    # -- change notification ---------------------------------------------------
    def subscribe(self, fn: Callable[[int], None]) -> Callable[[int], None]:
        self._listeners.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[int], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def _notify(self, adapter_id: int) -> None:
        for fn in self._listeners:
            fn(adapter_id)

    # -- intake / service ------------------------------------------------------
    def push(self, req: Request) -> None:
        self.queue(req.adapter_id).push(req)
        self._notify(req.adapter_id)

    def take(self, adapter_id: int, n: int) -> list[Request]:
        """The next batch, oldest (resident) work first (does not remove;
        ``retire`` trims finished).  Taking spilled requests is fine —
        servicing pays the T_spill surcharge and pages them back in."""
        return self.queues[adapter_id].all_requests()[:n]

    def retire(self, adapter_id: int, serviced=None) -> None:
        """Drop finished requests after a dispatch.  Servicing pages back
        in only the requests that were actually in the batch
        (``serviced``) — paging the *whole* spilled suffix on every
        dispatch was the §6 wholesale-unspill budget overshoot: one
        serviced adapter could re-exceed ``spill_budget_bytes`` in one
        shot and re-engage spill next round.  Only the explicit
        ``wholesale_unspill`` legacy flag restores that whole-suffix
        paging (mirroring WorkloadManager.complete_bucket's drain); a
        caller that does not know its batch (``serviced=None``) pages in
        nothing rather than everything."""
        q = self.queues[adapter_id]
        if self.wholesale_unspill:
            q.unspill_all()
        elif serviced is not None:
            q.unspill_items(serviced)
        q._drop_finished()
        if not q.spilled_requests:
            self._spilled.discard(adapter_id)
        self._notify(adapter_id)

    # -- shard migration (work stealing) ---------------------------------------
    def migrate_out(self, adapter_id: int) -> list[Request]:
        """Drain one adapter's whole pending queue (resident prefix first,
        then the spilled tail) for migration to another shard.  The queue
        object is dropped — ``queue()`` recreates it lazily if the
        adapter's future arrivals ever route back here."""
        q = self.queues.pop(adapter_id, None)
        if q is None:
            return []
        self._spilled.discard(adapter_id)
        reqs = q.drain()
        if reqs:
            self._notify(adapter_id)
        return reqs

    def migrate_in(self, requests: list[Request]) -> list[Request]:
        """Land migrated requests: resident, original arrival times (the
        §6 spill state does not migrate — the thief's own control loop
        re-spills under its budget if it must)."""
        touched: set[int] = set()
        for r in requests:
            self.queue(r.adapter_id).push(r)
            touched.add(r.adapter_id)
        for a in sorted(touched):
            self._notify(a)
        return requests

    # -- scheduler-facing protocol ---------------------------------------------
    def nonempty_queues(self) -> list[_AdapterQueue]:
        return [q for q in self.queues.values() if q]

    def queue(self, adapter_id: int) -> _AdapterQueue:
        # get-or-create without constructing a throwaway queue per call
        # (this sits on the per-request intake hot path).
        q = self.queues.get(adapter_id)
        if q is None:
            q = self.queues[adapter_id] = _AdapterQueue(
                adapter_id, self.probe_bytes, self.min_unit_bytes
            )
        return q

    def ages_ms(self, now: float) -> dict[int, float]:
        return {
            a: (now - q.oldest_arrival) * 1e3
            for a, q in self.queues.items()
            if q
        }

    def pending_objects(self) -> int:
        return sum(q.size for q in self.queues.values())

    def resident_objects(self) -> int:
        return sum(q.resident_size for q in self.queues.values() if q)

    def pending_bytes(self) -> float:
        return sum(q.nbytes for q in self.queues.values() if q)

    def resident_bytes(self) -> float:
        return sum(q.resident_bytes for q in self.queues.values() if q)

    def tenant_of_adapter(self, adapter_id: int) -> str:
        return self._tenants.get(adapter_id, DEFAULT_TENANT)

    def tenant_pending(self, tenant: str) -> tuple[int, float]:
        """(pending requests, pending prompt-state bytes) for one tenant
        class, both residency sides — the admission controller's view
        (spilling must not launder quota headroom)."""
        objs, nbytes = 0, 0.0
        for a, q in self.queues.items():
            if self.tenant_of_adapter(a) != tenant or not q:
                continue
            objs += q.size
            nbytes += q.nbytes
        return objs, nbytes

    # -- state snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-data view of the full workload state (queue contents +
        order on both residency sides, spill marks) for the durability
        tier's replayed-state == live-state assertions."""

        def req(r: Request) -> list:
            return [
                int(r.request_id), int(r.adapter_id), float(r.arrival_time),
                int(r.prompt_len), int(r.tokens_done),
            ]

        return {
            "queues": {
                int(a): q.snapshot(req)
                for a, q in sorted(self.queues.items())
                if q
            },
            "spilled": sorted(int(a) for a in self._spilled),
        }

    # -- §6 workload overflow ---------------------------------------------------
    # is_spilled / spilled_fraction / spill_bucket / unspill_bucket /
    # spilled_buckets come from SpillBookkeepingMixin — ONE copy of the
    # §6 bucket protocol, shared with the core WorkloadManager.


class LifeRaftEngine:
    def __init__(
        self,
        adapters: list[AdapterSpec],
        config: ServeConfig = ServeConfig(),
        decode_batch_fn: Optional[Callable] = None,
        control: Optional[ControlLoop | TenantControlPlane] = None,
        obs=None,
    ) -> None:
        self.cfg = config
        self.adapters = {a.adapter_id: a for a in adapters}
        mean_bytes = float(np.mean([a.nbytes for a in adapters])) if adapters else 1.0
        self.cost = CostModel(
            T_b=mean_bytes / config.hbm_bw,
            T_m=config.per_token_cost,
            T_spill=config.spill_penalty_s,
            probe_bytes=config.kv_bytes_per_token,
            min_unit_bytes=config.min_unit_bytes,
        )
        if config.policy == "rr":
            self.scheduler = RoundRobinScheduler(self.cost)
        else:
            alpha = 1.0 if config.policy == "noshare" else config.alpha
            self.scheduler = LifeRaftScheduler(self.cost, alpha=alpha, normalized=True)
        self.cache = BucketCache(config.adapter_slots)
        self.workload = AdapterWorkload(
            [a.adapter_id for a in adapters],
            probe_bytes=self.cost.probe_bytes,
            tenants={a.adapter_id: a.tenant for a in adapters},
            min_unit_bytes=self.cost.min_unit_bytes,
            wholesale_unspill=config.wholesale_unspill,
        )
        self.decode_batch_fn = decode_batch_fn
        self.completed: list[Request] = []
        self.indexed_batches = 0
        self.tokens_served = 0
        self._inflight: dict[int, list[Request]] = {}
        if control is None and config.tenant_policies:
            # Multi-tenant plane: one ControlVector per adapter class, the
            # global §6 byte budget arbitrated across classes.
            control = TenantControlPlane(
                list(config.tenant_policies),
                global_budget_bytes=config.spill_budget_bytes,
                halflife_s=config.control_halflife_s,
            )
        elif control is None and config.adaptive:
            control = ControlLoop(
                ControlConfig(
                    alpha_init=config.alpha,
                    alpha_step=config.alpha_step,
                    halflife_s=config.control_halflife_s,
                    rate_knee=config.rate_knee,
                    depth_knee=config.depth_knee,
                    fuse_k_init=config.fuse_k,
                    fuse_k_max=config.fuse_k_max,
                    spill_budget_objects=config.spill_budget,
                    spill_budget_bytes=config.spill_budget_bytes,
                    wholesale_unspill=config.wholesale_unspill,
                    prefetch_horizon_init=config.prefetch_horizon,
                    prefetch_horizon_max=(
                        config.prefetch_horizon_max if config.prefetch else 0
                    ),
                    share_width_init=max(1, config.share_width),
                    share_width_max=(
                        config.share_width_max if config.shared_plan else 0
                    ),
                )
            )
        self.control = control
        pf_cfg = (
            PrefetchConfig(
                horizon=config.prefetch_horizon, depth=config.prefetch_depth
            )
            if config.prefetch
            else False
        )
        self.loop = DispatchLoop(
            self.scheduler,
            self.workload,
            self.cache,
            self._execute,
            control=control,
            tenant_of=self.workload.tenant_of_adapter,
            fuse_k=config.fuse_k,
            complete=self._complete,
            batch_capacity=config.max_batch,
            # Staging cost is per adapter: its weight bytes over HBM bw
            # (exactly the t_load the demand path would have paid inline).
            prefetch=build_pipeline(
                pf_cfg, self.scheduler, self.cache,
                lambda a: self.adapters[a].nbytes / self.cfg.hbm_bw,
            ),
        )
        self.obs = None
        if obs:
            # Lazy import: with obs off (the default) the hot path never
            # touches repro.obs.  The tap is a pure add_round_tap consumer.
            from ..obs import ensure as _obs_ensure

            self.obs = _obs_ensure(obs)
            self.obs.attach_loop(self.loop, track=0, clock="virtual")

    # ------------------------------------------------------------- views
    @property
    def clock(self) -> float:
        return self.loop.clock

    @clock.setter
    def clock(self, value: float) -> None:
        self.loop.clock = value

    @property
    def batches(self) -> int:
        return self.loop.batches

    @property
    def queues(self) -> dict[int, list[Request]]:
        return {a: q.requests for a, q in self.workload.queues.items()}

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.clock = max(self.clock, req.arrival_time)
        self.workload.push(req)
        self.loop.observe_arrival(req.arrival_time)

    # ------------------------------------------------------------- execution
    def _prepare_decision(self, d) -> tuple[int, list[Request], float]:
        """Per-decision accounting shared by both executor paths: take the
        batch, charge adapter load + §6 read-back + quantum decode time,
        and advance token state.  Returns (adapter, batch, step_time)."""
        adapter = d.bucket_id
        batch = self.workload.take(adapter, self.cfg.max_batch)
        self._inflight[adapter] = batch
        t_load = 0.0
        if not self.cache.contains(adapter):
            t_load = self.adapters[adapter].nbytes / self.cfg.hbm_bw
        if self.workload.is_spilled(adapter):
            # §6 host read-back surcharge, pro-rated by the spilled
            # byte fraction (== T_spill for a fully spilled queue).
            t_load += self.cost.T_spill * self.workload.spilled_fraction(
                adapter
            )
        use_indexed = (
            len(batch) < self.cfg.hybrid_threshold
            and not self.cache.contains(adapter)
        )
        if use_indexed:
            # Gathered multi-adapter path: no residency established, but
            # hit_rate must see the miss (symmetric accounting, same as
            # CrossMatchEngine._plan_and_fetch).
            self.indexed_batches += 1
            self.cache.note_bypass_miss()
            t_load = t_load * 0.25  # stream only the rows touched
        else:
            self.cache.access(adapter)

        quantum = self.cfg.decode_quantum
        # Load + quantum decode steps for the batch.
        step_time = t_load + quantum * self.cfg.per_token_cost * max(
            len(batch), 1
        )
        for r in batch:
            r.tokens_done += quantum
            self.tokens_served += quantum
        return adapter, batch, step_time

    def _execute(self, decisions, vector) -> float:
        """DispatchLoop executor: load + quantum decode for each selected
        adapter's batch — one device call per adapter, or one masked
        grouped call per share_width chunk under ``shared_plan``."""
        if self.cfg.shared_plan:
            return self.execute_shared(decisions, vector)
        step_time = 0.0
        self._inflight = {}
        for d in decisions:
            adapter, batch, t = self._prepare_decision(d)
            step_time += t
            if self.decode_batch_fn is not None:
                self.decode_batch_fn(adapter, batch, self.cfg.decode_quantum)
        self.loop.note_device_dispatches(len(decisions))
        return step_time

    def execute_shared(self, decisions, vector=None) -> float:
        """Shared-plan executor: the round's adapter batches decode in
        ceil(k / share_width) masked grouped calls instead of k private
        ones.  Per-decision cost accounting is identical to the off path
        (the virtual clock and every completion time are unchanged); only
        the device-call grouping — and the real ``decode_batch_fn``
        invocation shape, ``fn([(adapter, batch), ...], quantum)`` —
        differs."""
        width = max(
            1, getattr(vector, "share_width", 0) or self.cfg.share_width
        )
        step_time = 0.0
        self._inflight = {}
        prepared: list[tuple[int, list[Request]]] = []
        for d in decisions:
            adapter, batch, t = self._prepare_decision(d)
            step_time += t
            prepared.append((adapter, batch))
        chunks = [
            prepared[i : i + width] for i in range(0, len(prepared), width)
        ]
        for group in chunks:
            if self.decode_batch_fn is not None:
                self.decode_batch_fn(group, self.cfg.decode_quantum)
        occupancy = (
            len(prepared) / (len(chunks) * width) if prepared else 0.0
        )
        self.loop.note_device_dispatches(
            len(chunks), shared_occupancy=occupancy
        )
        return step_time

    def _complete(self, decisions, now: float) -> None:
        """Completions share the dispatch finish time (the fused call
        returns all segments at once)."""
        for d in decisions:
            adapter = d.bucket_id
            batch = self._inflight.get(adapter, ())
            for r in batch:
                if r.done and r.finish_time is None:
                    r.finish_time = now
                    self.completed.append(r)
            # Only the serviced requests page back in — the unserviced
            # spilled tail stays on host, within the §6 budget.
            self.workload.retire(adapter, batch)
        self._inflight = {}

    # ------------------------------------------------------------- scheduling
    def step(self) -> Optional[int]:
        """Schedule + execute one dispatch (one adapter batch, or the top-k
        adapters fused into a single grouped call when ``fuse_k > 1``).
        Returns the highest-priority adapter id, or None when idle."""
        if self.cfg.policy == "noshare":
            return self._step_noshare()
        outcome = self.loop.round()
        return None if outcome is None else outcome.decisions[0].bucket_id

    def _step_noshare(self) -> Optional[int]:
        """Paper's NoShare baseline: FCFS across all adapters, one request
        at a time, no batching; every request pays its own state load."""
        pending = [
            (q.requests[0].arrival_time, a)
            for a, q in self.workload.queues.items()
            if q
        ]
        if not pending:
            return None
        _, adapter = min(pending)
        req = self.workload.queues[adapter].requests[0]
        t_load = self.adapters[adapter].nbytes / self.cfg.hbm_bw
        quantum = self.cfg.decode_quantum
        if self.decode_batch_fn is not None:
            self.decode_batch_fn(adapter, [req], quantum)
        req.tokens_done += quantum
        self.tokens_served += quantum
        step_time = t_load + quantum * self.cfg.per_token_cost
        self.clock += step_time
        self.loop.busy += step_time
        self.loop.batches += 1
        self.loop.dispatches += 1
        self.loop.device_dispatches += 1
        if req.done and req.finish_time is None:
            req.finish_time = self.clock
            self.completed.append(req)
        self.workload.retire(adapter, [req])
        return adapter

    def run(self, requests: list[Request]) -> dict:
        """Replay a request trace to completion; returns summary metrics."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        while i < len(pending) or self.workload.nonempty_queues():
            if not self.workload.nonempty_queues():
                self.clock = max(self.clock, pending[i].arrival_time)
            while i < len(pending) and pending[i].arrival_time <= self.clock:
                self.submit(pending[i])
                i += 1
            if self.workload.nonempty_queues():
                self.step()
        return self.summary()

    def summary(self) -> dict:
        resp = [r.finish_time - r.arrival_time for r in self.completed]
        vec = self.loop.last_vector
        dstats = dispatch_stats(self.loop)
        response_by_id = {
            r.request_id: r.finish_time - r.arrival_time for r in self.completed
        }
        adapter_of = {r.request_id: r.adapter_id for r in self.completed}
        tenants = sorted({a.tenant for a in self.adapters.values()})
        per_tenant = (
            per_tenant_latency(
                response_by_id,
                lambda rid: self.workload.tenant_of_adapter(adapter_of[rid]),
                max(self.clock, 1e-9),
                tenants,
            )
            if len(tenants) > 1
            else {}
        )
        return {
            "policy": self.cfg.policy,
            "alpha": getattr(self.scheduler, "alpha", None),
            "adaptive": self.control is not None,
            "multi_tenant": isinstance(self.control, TenantControlPlane),
            "fuse_k": vec.fuse_k if vec is not None else self.cfg.fuse_k,
            "n_completed": len(self.completed),
            "makespan": self.clock,
            "token_throughput": self.tokens_served / max(self.clock, 1e-9),
            "request_throughput": len(self.completed) / max(self.clock, 1e-9),
            "mean_response": float(np.mean(resp)) if resp else 0.0,
            "p95_response": float(np.percentile(resp, 95)) if resp else 0.0,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "batches": self.batches,
            "device_dispatches": dstats["device_dispatches"],
            "shared_batch_occupancy": dstats["shared_batch_occupancy"],
            "indexed_batches": self.indexed_batches,
            "spilled": self.workload.spilled_buckets(),
            "per_tenant": per_tenant,
            "prefetch": (
                prefetch_stats(self.loop.prefetch, self.cache)
                if self.loop.prefetch is not None
                else {}
            ),
        }


class ShardedServingEngine:
    """Multi-shard serving: S :class:`LifeRaftEngine` replicas, adapters
    partitioned by weight bytes.

    Here the shard key is the adapter id (serving's bucket): each
    adapter's whole request queue lives on exactly one shard, so no
    request ever needs a cross-shard join — routing is a lookup and
    stealing migrates an adapter's entire pending queue.  Every replica
    holds the full adapter spec table (identical ``T_b``); only the HBM
    ``adapter_slots`` are split so aggregate cache stays equal to the
    single-engine baseline.

    The drive is virtual lockstep (the simulator's transport): the
    least-clock shard with work steps next, idle shards at the steal
    low-water mark take the byte-heaviest victim's top adapter —
    scheduler state forgotten on the victim, in-flight weight stage
    canceled for the residual channel time, requests landing resident
    with original arrivals and the thief's clock advanced to the newest
    one (no time travel, no free cache warmth).
    """

    def __init__(
        self,
        adapters: list[AdapterSpec],
        config: ServeConfig = ServeConfig(),
        n_shards: int = 2,
        *,
        shard_map: Optional[ShardMap] = None,
        steal: Optional[StealConfig] = None,
        decode_batch_fn: Optional[Callable] = None,
        obs=None,
    ) -> None:
        self.n_shards = max(1, int(n_shards))
        self.shard_map = shard_map or ShardMap.from_bucket_bytes(
            {a.adapter_id: float(a.nbytes) for a in adapters}, self.n_shards
        )
        self.steal = steal
        self.steals: list[StealEvent] = []
        # Aggregate HBM slots are conserved across the split: the first
        # ``slots % S`` shards carry one extra (plain ``slots // S``
        # silently dropped the remainder — shards are NOT interchangeable
        # replicas of capacity).
        slot_split = split_slots(config.adapter_slots, self.n_shards)
        self.engines = [
            LifeRaftEngine(
                adapters,
                dataclasses.replace(config, adapter_slots=slot_split[sid]),
                decode_batch_fn=decode_batch_fn,
            )
            for sid in range(self.n_shards)
        ]
        # Decision-log taps for the durability tier (and any recorder):
        # ``on_round(shard_id, outcome)`` fires after each shard-local
        # round, ``on_steal(event)`` after each migration, preserving the
        # cross-shard interleaving order.
        self.on_round: Optional[Callable] = None
        self.on_steal: Optional[Callable] = None
        for sid, eng in enumerate(self.engines):
            eng.loop.add_round_tap(self._make_round_tap(sid))
        self._obs = None
        if obs:
            from ..obs import ensure as _obs_ensure  # lazy: off-path clean

            self._obs = _obs_ensure(obs)
            for sid, eng in enumerate(self.engines):
                self._obs.attach_loop(eng.loop, track=sid, clock="virtual")

    def _make_round_tap(self, sid: int):
        def tap(outcome):
            if self.on_round is not None:
                self.on_round(sid, outcome)

        return tap

    # -- routing ---------------------------------------------------------------
    def _owner(self, req: Request) -> LifeRaftEngine:
        return self.engines[self.shard_map.shard_of(req.adapter_id)]

    def submit(self, req: Request) -> None:
        self._owner(req).submit(req)

    # -- work stealing ---------------------------------------------------------
    def _maybe_steal(self) -> None:
        cfg = self.steal
        if cfg is None or self.n_shards < 2:
            return
        for sid, thief in enumerate(self.engines):
            if thief.workload.pending_bytes() > cfg.low_water_bytes:
                continue
            victims = [
                (vid, v)
                for vid, v in enumerate(self.engines)
                if vid != sid
                and len(v.workload.nonempty_queues()) >= cfg.min_victim_queues
            ]
            if not victims:
                continue
            vid, victim = max(
                victims, key=lambda t: (t[1].workload.pending_bytes(), -t[0])
            )
            peek = getattr(victim.scheduler, "peek_topk", None)
            if peek is not None:
                top = peek(victim.workload, victim.cache, victim.clock, 1)
                adapter = top[0].bucket_id if top else None
            else:
                queues = victim.workload.nonempty_queues()
                adapter = (
                    max(
                        queues, key=lambda q: (q.nbytes, -q.bucket_id)
                    ).bucket_id
                    if queues
                    else None
                )
            if adapter is None:
                continue
            reqs = victim.workload.migrate_out(adapter)
            if not reqs:
                continue
            if hasattr(victim.scheduler, "forget"):
                victim.scheduler.forget(adapter)
            reclaimed = 0.0
            if victim.loop.prefetch is not None:
                reclaimed = victim.loop.prefetch.cancel(adapter, victim.clock)
            thief.workload.migrate_in(reqs)
            self.shard_map.reassign(adapter, sid)
            newest = max(r.arrival_time for r in reqs)
            thief.clock = max(thief.clock, newest)
            thief.loop.observe_arrival(newest)
            ev = StealEvent(
                bucket_id=adapter,
                victim=vid,
                thief=sid,
                n_units=len(reqs),
                nbytes=float(
                    sum(
                        max(
                            r.prompt_len * victim.workload.probe_bytes,
                            victim.workload.min_unit_bytes,
                        )
                        for r in reqs
                    )
                ),
                reclaimed_stage_s=reclaimed,
                clock=thief.clock,
            )
            self.steals.append(ev)
            if self.on_steal is not None:
                self.on_steal(ev)
            if self._obs is not None:
                self._obs.note_steal(ev)

    # -- virtual lockstep drive ------------------------------------------------
    def step(self) -> Optional[int]:
        """One lockstep iteration, the unit the service daemon pumps: a
        steal sweep, then one round on the least-clock shard with work.
        Returns that shard's serviced adapter id, or None when every shard
        is idle.  (``run`` keeps its own historical loop — it interleaves
        trace admission between the sweep and the round.)"""
        self._maybe_steal()
        runnable = [e for e in self.engines if e.workload.nonempty_queues()]
        if not runnable:
            return None
        eng = min(runnable, key=lambda e: (e.clock, self.engines.index(e)))
        return eng.step()

    def run(self, requests: list[Request]) -> dict:
        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        while True:
            self._maybe_steal()
            # Admit every arrival its owner's clock has reached.
            while (
                i < len(pending)
                and pending[i].arrival_time <= self._owner(pending[i]).clock
            ):
                self.submit(pending[i])
                i += 1
            runnable = [
                e for e in self.engines if e.workload.nonempty_queues()
            ]
            if runnable:
                eng = min(
                    runnable, key=lambda e: (e.clock, self.engines.index(e))
                )
                eng.step()
                continue
            if i < len(pending):
                nxt = pending[i]
                owner = self._owner(nxt)
                owner.clock = max(owner.clock, nxt.arrival_time)
                self.submit(nxt)
                i += 1
                continue
            return self.summary()

    # -- metrics ---------------------------------------------------------------
    def summary(self) -> dict:
        completed = [r for eng in self.engines for r in eng.completed]
        resp = [r.finish_time - r.arrival_time for r in completed]
        hits = sum(eng.cache.stats.hits for eng in self.engines)
        accesses = sum(eng.cache.stats.accesses for eng in self.engines)
        makespan = max(eng.clock for eng in self.engines)
        tokens = sum(eng.tokens_served for eng in self.engines)
        return {
            "policy": f"{self.engines[0].cfg.policy}+S{self.n_shards}"
            + ("st" if self.steal is not None else ""),
            "n_shards": self.n_shards,
            "n_completed": len(completed),
            "makespan": makespan,
            "token_throughput": tokens / max(makespan, 1e-9),
            "request_throughput": len(completed) / max(makespan, 1e-9),
            "mean_response": float(np.mean(resp)) if resp else 0.0,
            "p95_response": float(np.percentile(resp, 95)) if resp else 0.0,
            "cache_hit_rate": hits / accesses if accesses else 0.0,
            "batches": sum(eng.batches for eng in self.engines),
            "steals": len(self.steals),
        }

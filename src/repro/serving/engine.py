"""LifeRaft continuous-batching serving engine (multi-tenant LLM decode).

The paper's scheduler, re-instantiated for TPU serving:

  bucket          = a LoRA adapter's weights (expensive resident state)
  T_b             = adapter load cost (host->HBM transfer at hbm_bw)
  T_m             = marginal decode cost per request in the batch
  workload queue  = pending requests per adapter
  bucket cache    = fixed number of HBM adapter slots (LRU)
  hybrid strategy = tiny batches run the gathered multi-adapter path
                    (indexed join); contended adapters run a dense batch
                    (sequential scan) — kernels/grouped_matmul
  U_a             = Eq. 2 drives which adapter's batch runs next;
                    NoShare == per-request FCFS, RR == adapter round-robin

The scheduling round itself is the shared ``DispatchLoop``
(core/dispatch.py) — the same inner loop the cross-match engine and the
simulator run.  ``AdapterWorkload`` implements the WorkloadManager
protocol (change subscriptions, spill marks) over the per-adapter request
queues, so the incremental lazy-heap scheduler index applies to serving's
``normalized=True`` default instead of the historical O(B) rescan façade.

§6 future work is implemented through the control plane: straggler
absorption (an aged bucket's priority grows until scheduled) and workload
overflow (``ServeConfig.spill_budget`` — pending queues spill to host
when the budget is exceeded, paying ``spill_penalty_s`` to page back in).
With ``adaptive=True`` a ``ControlLoop`` retunes alpha / fuse_k / spill
every round from live queue state.

The engine runs in two modes: the default advances a virtual clock with
the roofline cost model (capacity planning, Fig. 7/8-style sweeps);
``decode_batch_fn`` executes real decode steps of a (small) model on the
current devices alongside.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..core.cache import BucketCache
from ..core.control import (
    ControlConfig,
    ControlLoop,
    TenantControlPlane,
    TenantPolicy,
)
from ..core.dispatch import DispatchLoop
from ..core.metrics import CostModel, per_tenant_latency
from ..core.scheduler import LifeRaftScheduler, RoundRobinScheduler
from ..core.workload import DEFAULT_TENANT

__all__ = [
    "Request",
    "AdapterSpec",
    "ServeConfig",
    "AdapterWorkload",
    "LifeRaftEngine",
]


@dataclasses.dataclass
class Request:
    request_id: int
    adapter_id: int
    arrival_time: float
    prompt_len: int
    max_new_tokens: int
    tokens_done: int = 0
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.tokens_done >= self.max_new_tokens


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    adapter_id: int
    nbytes: int  # adapter weight bytes (sets T_b via hbm_bw)
    tenant: str = DEFAULT_TENANT  # tenant class (interactive vs batch)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    policy: str = "liferaft"  # liferaft | rr | noshare
    alpha: float = 0.25
    adapter_slots: int = 4  # HBM bucket-cache capacity
    max_batch: int = 32
    decode_quantum: int = 16  # tokens decoded per scheduled batch
    hbm_bw: float = 819e9
    per_token_cost: float = 2e-4  # T_m seconds per request-token (marginal)
    hybrid_threshold: int = 2  # batches below this use the gathered path
    fuse_k: int = 1  # adapters serviced per dispatch (grouped-matmul fusion)
    # -- closed-loop control plane (core/control.py) --------------------------
    adaptive: bool = False  # retune alpha/fuse_k/spill every round
    fuse_k_max: int = 8
    alpha_step: float = 0.1
    control_halflife_s: float = 2.0  # arrival EWMA halflife (request scale)
    rate_knee: float = 200.0  # req/s at which saturation maxes out
    depth_knee: float = 64.0  # pending requests at which backlog maxes out
    spill_budget: Optional[int] = None  # §6 overflow: resident request budget
    spill_budget_bytes: Optional[float] = None  # byte-accurate §6 budget
    spill_penalty_s: float = 0.0  # T_spill host read-back surcharge
    kv_bytes_per_token: float = 1.0  # spillable host state per prompt token
    # -- multi-tenant control plane (one ControlVector per adapter class) ------
    tenant_policies: Optional[tuple[TenantPolicy, ...]] = None


class _AdapterQueue:
    """WorkloadQueue façade over one adapter's pending request list, with
    the same resident-prefix / spilled-suffix split as the core
    WorkloadQueue: §6 overflow pages the *youngest* requests' prompt state
    to host (``prompt_len * kv_bytes_per_token`` each); the oldest keep
    their state resident.

    NOTE: this mirrors ``core.workload.WorkloadQueue``'s spill mechanics
    (push boundary rule, youngest-first eviction, O(1) maintained byte
    counters) over ``Request`` items — keep the two in lockstep; the
    partial-spill property suite runs against both
    (tests/test_partial_spill.py::TestServingQueueMirrorsCore)."""

    __slots__ = (
        "bucket_id", "requests", "spilled_requests", "_probe_bytes",
        "_bytes", "_spilled_bytes", "_spilled_oldest",
    )

    def __init__(self, bucket_id: int, probe_bytes: float = 1.0) -> None:
        self.bucket_id = bucket_id
        self.requests: list[Request] = []  # resident prefix (oldest)
        self.spilled_requests: list[Request] = []  # youngest, on host
        self._probe_bytes = probe_bytes
        self._bytes = 0.0
        self._spilled_bytes = 0.0
        self._spilled_oldest = float("inf")

    def _rbytes(self, r: Request) -> float:
        return r.prompt_len * self._probe_bytes

    @property
    def size(self) -> int:
        return len(self.requests) + len(self.spilled_requests)

    @property
    def resident_size(self) -> int:
        return len(self.requests)

    @property
    def nbytes(self) -> float:
        return self._bytes

    @property
    def resident_bytes(self) -> float:
        return self._bytes - self._spilled_bytes

    @property
    def spilled_bytes(self) -> float:
        return self._spilled_bytes

    @property
    def spilled_fraction(self) -> float:
        """Exactly 0.0 / 1.0 at the ends, like the core queue (a fully
        spilled adapter pays exactly T_spill)."""
        if not self.spilled_requests:
            return 0.0
        if not self.requests:
            return 1.0
        return self._spilled_bytes / self._bytes if self._bytes else 0.0

    @property
    def oldest_arrival(self) -> float:
        pending = self.requests + self.spilled_requests
        if not pending:
            return float("inf")
        return min(r.arrival_time for r in pending)

    def all_requests(self) -> list[Request]:
        """Resident prefix first (the oldest work), then the spilled tail."""
        return self.requests + self.spilled_requests

    def push(self, req: Request) -> None:
        # Overflowing queues take new (youngest) work on the spilled side,
        # keeping the resident prefix an age-contiguous cut (same rule as
        # core WorkloadQueue.push); late out-of-order arrivals older than
        # the spill boundary still join the resident prefix.
        if self.spilled_requests and req.arrival_time >= self._spilled_oldest:
            self.spilled_requests.append(req)
            self._spilled_bytes += self._rbytes(req)
        else:
            self.requests.append(req)
        self._bytes += self._rbytes(req)

    def spill_youngest(self, frac: float = 1.0) -> int:
        """Move the youngest resident requests to host until the spilled
        byte fraction reaches ``frac``; for ``frac < 1`` the oldest request
        always stays resident.  Returns requests moved."""
        if not self.requests:
            return 0
        target = min(max(frac, 0.0), 1.0) * self._bytes
        keep_oldest = frac < 1.0
        order = sorted(
            range(len(self.requests)),
            key=lambda i: (self.requests[i].arrival_time, i),
        )
        taken: list[int] = []
        while self._spilled_bytes < target and order:
            if keep_oldest and len(order) == 1:
                break
            i = order.pop()
            self._spilled_bytes += self._rbytes(self.requests[i])
            taken.append(i)
        if not taken:
            return 0
        keep = set(order)
        moved = [r for i, r in enumerate(self.requests) if i not in keep]
        self.requests = [self.requests[i] for i in sorted(keep)]
        moved.sort(key=lambda r: r.arrival_time)
        self.spilled_requests.extend(moved)
        self._spilled_oldest = min(self._spilled_oldest, moved[0].arrival_time)
        return len(taken)

    def unspill_all(self) -> int:
        moved = len(self.spilled_requests)
        if moved:
            merged = self.requests + self.spilled_requests
            merged.sort(key=lambda r: (r.arrival_time, r.request_id))
            self.requests = merged
            self.spilled_requests = []
            self._spilled_bytes = 0.0
            self._spilled_oldest = float("inf")
        return moved

    def _drop_finished(self) -> None:
        """Trim finished requests (resident only — retire unspills first)
        and rebase the byte counter."""
        self.requests = [r for r in self.requests if not r.done]
        self._bytes = sum(self._rbytes(r) for r in self.requests)

    def __len__(self) -> int:
        return self.size

    def __bool__(self) -> bool:
        return self.size > 0


class AdapterWorkload:
    """WorkloadManager protocol (subscriptions, ages, §6 spill marks) over
    per-adapter request queues.

    Having a stable, subscribable workload object — instead of the façades
    the old ``_select`` helper rebuilt on every call — is what lets the
    serving engine ride the scheduler's incremental heap index.

    ``probe_bytes`` prices one prompt token's spillable host state (KV /
    prompt cache) for the §6 byte budget; ``tenant_of_adapter`` maps each
    adapter to its tenant class for the multi-tenant control plane."""

    def __init__(
        self,
        adapter_ids=(),
        probe_bytes: float = 1.0,
        tenants: Optional[dict[int, str]] = None,
    ) -> None:
        self.probe_bytes = float(probe_bytes)
        self.queues: dict[int, _AdapterQueue] = {
            a: _AdapterQueue(a, self.probe_bytes) for a in adapter_ids
        }
        self._tenants: dict[int, str] = dict(tenants or {})
        self._listeners: list[Callable[[int], None]] = []
        self._spilled: set[int] = set()

    # -- change notification ---------------------------------------------------
    def subscribe(self, fn: Callable[[int], None]) -> Callable[[int], None]:
        self._listeners.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[int], None]) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def _notify(self, adapter_id: int) -> None:
        for fn in self._listeners:
            fn(adapter_id)

    # -- intake / service ------------------------------------------------------
    def push(self, req: Request) -> None:
        q = self.queues.setdefault(
            req.adapter_id, _AdapterQueue(req.adapter_id, self.probe_bytes)
        )
        q.push(req)
        self._notify(req.adapter_id)

    def take(self, adapter_id: int, n: int) -> list[Request]:
        """The next batch, oldest (resident) work first (does not remove;
        ``retire`` trims finished).  Taking spilled requests is fine —
        servicing pays the T_spill surcharge and pages them back in."""
        return self.queues[adapter_id].all_requests()[:n]

    def retire(self, adapter_id: int) -> None:
        """Drop finished requests after a dispatch; servicing also pages a
        spilled adapter back in (mirrors WorkloadManager.complete_bucket)."""
        q = self.queues[adapter_id]
        q.unspill_all()
        q._drop_finished()
        self._spilled.discard(adapter_id)
        self._notify(adapter_id)

    # -- scheduler-facing protocol ---------------------------------------------
    def nonempty_queues(self) -> list[_AdapterQueue]:
        return [q for q in self.queues.values() if q]

    def queue(self, adapter_id: int) -> _AdapterQueue:
        return self.queues.setdefault(
            adapter_id, _AdapterQueue(adapter_id, self.probe_bytes)
        )

    def ages_ms(self, now: float) -> dict[int, float]:
        return {
            a: (now - q.oldest_arrival) * 1e3
            for a, q in self.queues.items()
            if q
        }

    def pending_objects(self) -> int:
        return sum(q.size for q in self.queues.values())

    def resident_objects(self) -> int:
        return sum(q.resident_size for q in self.queues.values() if q)

    def pending_bytes(self) -> float:
        return sum(q.nbytes for q in self.queues.values() if q)

    def resident_bytes(self) -> float:
        return sum(q.resident_bytes for q in self.queues.values() if q)

    def tenant_of_adapter(self, adapter_id: int) -> str:
        return self._tenants.get(adapter_id, DEFAULT_TENANT)

    # -- §6 workload overflow ---------------------------------------------------
    def is_spilled(self, adapter_id: int) -> bool:
        return adapter_id in self._spilled

    def spilled_fraction(self, adapter_id: int) -> float:
        q = self.queues.get(adapter_id)
        return q.spilled_fraction if q else 0.0

    def spill_bucket(self, adapter_id: int, frac: float = 1.0) -> bool:
        """Spill the youngest ``frac`` of the adapter's pending request
        state (prompt KV bytes) to host; ``frac=1`` spills the whole queue
        (legacy semantics)."""
        q = self.queues.get(adapter_id)
        if q is None or not q:
            return False
        if not q.spill_youngest(frac):
            return False
        self._spilled.add(adapter_id)
        self._notify(adapter_id)
        return True

    def unspill_bucket(self, adapter_id: int) -> bool:
        if adapter_id not in self._spilled:
            return False
        q = self.queues.get(adapter_id)
        if q is not None:
            q.unspill_all()
        self._spilled.discard(adapter_id)
        self._notify(adapter_id)
        return True

    def spilled_buckets(self) -> list[int]:
        return sorted(self._spilled)


class LifeRaftEngine:
    def __init__(
        self,
        adapters: list[AdapterSpec],
        config: ServeConfig = ServeConfig(),
        decode_batch_fn: Optional[Callable] = None,
        control: Optional[ControlLoop | TenantControlPlane] = None,
    ) -> None:
        self.cfg = config
        self.adapters = {a.adapter_id: a for a in adapters}
        mean_bytes = float(np.mean([a.nbytes for a in adapters])) if adapters else 1.0
        self.cost = CostModel(
            T_b=mean_bytes / config.hbm_bw,
            T_m=config.per_token_cost,
            T_spill=config.spill_penalty_s,
            probe_bytes=config.kv_bytes_per_token,
        )
        if config.policy == "rr":
            self.scheduler = RoundRobinScheduler(self.cost)
        else:
            alpha = 1.0 if config.policy == "noshare" else config.alpha
            self.scheduler = LifeRaftScheduler(self.cost, alpha=alpha, normalized=True)
        self.cache = BucketCache(config.adapter_slots)
        self.workload = AdapterWorkload(
            [a.adapter_id for a in adapters],
            probe_bytes=self.cost.probe_bytes,
            tenants={a.adapter_id: a.tenant for a in adapters},
        )
        self.decode_batch_fn = decode_batch_fn
        self.completed: list[Request] = []
        self.indexed_batches = 0
        self.tokens_served = 0
        self._inflight: dict[int, list[Request]] = {}
        if control is None and config.tenant_policies:
            # Multi-tenant plane: one ControlVector per adapter class, the
            # global §6 byte budget arbitrated across classes.
            control = TenantControlPlane(
                list(config.tenant_policies),
                global_budget_bytes=config.spill_budget_bytes,
                halflife_s=config.control_halflife_s,
            )
        elif control is None and config.adaptive:
            control = ControlLoop(
                ControlConfig(
                    alpha_init=config.alpha,
                    alpha_step=config.alpha_step,
                    halflife_s=config.control_halflife_s,
                    rate_knee=config.rate_knee,
                    depth_knee=config.depth_knee,
                    fuse_k_init=config.fuse_k,
                    fuse_k_max=config.fuse_k_max,
                    spill_budget_objects=config.spill_budget,
                    spill_budget_bytes=config.spill_budget_bytes,
                )
            )
        self.control = control
        self.loop = DispatchLoop(
            self.scheduler,
            self.workload,
            self.cache,
            self._execute,
            control=control,
            tenant_of=self.workload.tenant_of_adapter,
            fuse_k=config.fuse_k,
            complete=self._complete,
            batch_capacity=config.max_batch,
        )

    # ------------------------------------------------------------- views
    @property
    def clock(self) -> float:
        return self.loop.clock

    @clock.setter
    def clock(self, value: float) -> None:
        self.loop.clock = value

    @property
    def batches(self) -> int:
        return self.loop.batches

    @property
    def queues(self) -> dict[int, list[Request]]:
        return {a: q.requests for a, q in self.workload.queues.items()}

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.clock = max(self.clock, req.arrival_time)
        self.workload.push(req)
        self.loop.observe_arrival(req.arrival_time)

    # ------------------------------------------------------------- execution
    def _execute(self, decisions, vector) -> float:
        """DispatchLoop executor: load + quantum decode for each selected
        adapter's batch (one grouped device call when fused)."""
        step_time = 0.0
        self._inflight = {}
        for d in decisions:
            adapter = d.bucket_id
            batch = self.workload.take(adapter, self.cfg.max_batch)
            self._inflight[adapter] = batch
            t_load = 0.0
            if not self.cache.contains(adapter):
                t_load = self.adapters[adapter].nbytes / self.cfg.hbm_bw
            if self.workload.is_spilled(adapter):
                # §6 host read-back surcharge, pro-rated by the spilled
                # byte fraction (== T_spill for a fully spilled queue).
                t_load += self.cost.T_spill * self.workload.spilled_fraction(
                    adapter
                )
            use_indexed = (
                len(batch) < self.cfg.hybrid_threshold
                and not self.cache.contains(adapter)
            )
            if use_indexed:
                # Gathered multi-adapter path: no residency established, but
                # hit_rate must see the miss (symmetric accounting, same as
                # CrossMatchEngine._plan_and_fetch).
                self.indexed_batches += 1
                self.cache.note_bypass_miss()
                t_load = t_load * 0.25  # stream only the rows touched
            else:
                self.cache.access(adapter)

            quantum = self.cfg.decode_quantum
            if self.decode_batch_fn is not None:
                self.decode_batch_fn(adapter, batch, quantum)

            # Load + quantum decode steps for the batch.
            step_time += t_load + quantum * self.cfg.per_token_cost * max(
                len(batch), 1
            )
            for r in batch:
                r.tokens_done += quantum
                self.tokens_served += quantum
        return step_time

    def _complete(self, decisions, now: float) -> None:
        """Completions share the dispatch finish time (the fused call
        returns all segments at once)."""
        for d in decisions:
            adapter = d.bucket_id
            for r in self._inflight.get(adapter, ()):
                if r.done and r.finish_time is None:
                    r.finish_time = now
                    self.completed.append(r)
            self.workload.retire(adapter)
        self._inflight = {}

    # ------------------------------------------------------------- scheduling
    def step(self) -> Optional[int]:
        """Schedule + execute one dispatch (one adapter batch, or the top-k
        adapters fused into a single grouped call when ``fuse_k > 1``).
        Returns the highest-priority adapter id, or None when idle."""
        if self.cfg.policy == "noshare":
            return self._step_noshare()
        outcome = self.loop.round()
        return None if outcome is None else outcome.decisions[0].bucket_id

    def _step_noshare(self) -> Optional[int]:
        """Paper's NoShare baseline: FCFS across all adapters, one request
        at a time, no batching; every request pays its own state load."""
        pending = [
            (q.requests[0].arrival_time, a)
            for a, q in self.workload.queues.items()
            if q
        ]
        if not pending:
            return None
        _, adapter = min(pending)
        req = self.workload.queues[adapter].requests[0]
        t_load = self.adapters[adapter].nbytes / self.cfg.hbm_bw
        quantum = self.cfg.decode_quantum
        if self.decode_batch_fn is not None:
            self.decode_batch_fn(adapter, [req], quantum)
        req.tokens_done += quantum
        self.tokens_served += quantum
        step_time = t_load + quantum * self.cfg.per_token_cost
        self.clock += step_time
        self.loop.busy += step_time
        self.loop.batches += 1
        self.loop.dispatches += 1
        if req.done and req.finish_time is None:
            req.finish_time = self.clock
            self.completed.append(req)
        self.workload.retire(adapter)
        return adapter

    def run(self, requests: list[Request]) -> dict:
        """Replay a request trace to completion; returns summary metrics."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        while i < len(pending) or self.workload.nonempty_queues():
            if not self.workload.nonempty_queues():
                self.clock = max(self.clock, pending[i].arrival_time)
            while i < len(pending) and pending[i].arrival_time <= self.clock:
                self.submit(pending[i])
                i += 1
            if self.workload.nonempty_queues():
                self.step()
        return self.summary()

    def summary(self) -> dict:
        resp = [r.finish_time - r.arrival_time for r in self.completed]
        vec = self.loop.last_vector
        response_by_id = {
            r.request_id: r.finish_time - r.arrival_time for r in self.completed
        }
        adapter_of = {r.request_id: r.adapter_id for r in self.completed}
        tenants = {a.tenant for a in self.adapters.values()}
        per_tenant = (
            per_tenant_latency(
                response_by_id,
                lambda rid: self.workload.tenant_of_adapter(adapter_of[rid]),
                max(self.clock, 1e-9),
                tenants,
            )
            if len(tenants) > 1
            else {}
        )
        return {
            "policy": self.cfg.policy,
            "alpha": getattr(self.scheduler, "alpha", None),
            "adaptive": self.control is not None,
            "multi_tenant": isinstance(self.control, TenantControlPlane),
            "fuse_k": vec.fuse_k if vec is not None else self.cfg.fuse_k,
            "n_completed": len(self.completed),
            "makespan": self.clock,
            "token_throughput": self.tokens_served / max(self.clock, 1e-9),
            "request_throughput": len(self.completed) / max(self.clock, 1e-9),
            "mean_response": float(np.mean(resp)) if resp else 0.0,
            "p95_response": float(np.percentile(resp, 95)) if resp else 0.0,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "batches": self.batches,
            "indexed_batches": self.indexed_batches,
            "spilled": self.workload.spilled_buckets(),
            "per_tenant": per_tenant,
        }

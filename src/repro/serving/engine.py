"""LifeRaft continuous-batching serving engine (multi-tenant LLM decode).

The paper's scheduler, re-instantiated for TPU serving:

  bucket          = a LoRA adapter's weights (expensive resident state)
  T_b             = adapter load cost (host->HBM transfer at hbm_bw)
  T_m             = marginal decode cost per request in the batch
  workload queue  = pending requests per adapter
  bucket cache    = fixed number of HBM adapter slots (LRU)
  hybrid strategy = tiny batches run the gathered multi-adapter path
                    (indexed join); contended adapters run a dense batch
                    (sequential scan) — kernels/grouped_matmul
  U_a             = Eq. 2 drives which adapter's batch runs next;
                    NoShare == per-request FCFS, RR == adapter round-robin

Also implements the paper's §6 future work: straggler absorption (an aged
bucket's priority grows until scheduled — slow workers cannot starve a
tenant) and workload overflow (pending queues spill to host when the
device batch budget is exceeded).

The engine runs in two modes: ``simulate=True`` advances a virtual clock
with the roofline cost model (capacity planning, Fig. 7/8-style sweeps);
``simulate=False`` executes real decode steps of a (small) model on the
current devices.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..core.cache import BucketCache
from ..core.hybrid import HybridCostModel, HybridPlanner
from ..core.metrics import CostModel
from ..core.scheduler import LifeRaftScheduler, RoundRobinScheduler

__all__ = ["Request", "AdapterSpec", "ServeConfig", "LifeRaftEngine"]


@dataclasses.dataclass
class Request:
    request_id: int
    adapter_id: int
    arrival_time: float
    prompt_len: int
    max_new_tokens: int
    tokens_done: int = 0
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.tokens_done >= self.max_new_tokens


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    adapter_id: int
    nbytes: int  # adapter weight bytes (sets T_b via hbm_bw)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    policy: str = "liferaft"  # liferaft | rr | noshare
    alpha: float = 0.25
    adapter_slots: int = 4  # HBM bucket-cache capacity
    max_batch: int = 32
    decode_quantum: int = 16  # tokens decoded per scheduled batch
    hbm_bw: float = 819e9
    per_token_cost: float = 2e-4  # T_m seconds per request-token (marginal)
    hybrid_threshold: int = 2  # batches below this use the gathered path
    fuse_k: int = 1  # adapters serviced per dispatch (grouped-matmul fusion)


class LifeRaftEngine:
    def __init__(
        self,
        adapters: list[AdapterSpec],
        config: ServeConfig = ServeConfig(),
        decode_batch_fn: Optional[Callable] = None,
    ) -> None:
        self.cfg = config
        self.adapters = {a.adapter_id: a for a in adapters}
        mean_bytes = float(np.mean([a.nbytes for a in adapters])) if adapters else 1.0
        self.cost = CostModel(
            T_b=mean_bytes / config.hbm_bw, T_m=config.per_token_cost
        )
        if config.policy == "rr":
            self.scheduler = RoundRobinScheduler(self.cost)
        else:
            alpha = 1.0 if config.policy == "noshare" else config.alpha
            self.scheduler = LifeRaftScheduler(self.cost, alpha=alpha, normalized=True)
        self.cache = BucketCache(config.adapter_slots)
        self.queues: dict[int, list[Request]] = {a.adapter_id: [] for a in adapters}
        self.decode_batch_fn = decode_batch_fn
        self.clock = 0.0
        self.completed: list[Request] = []
        self.batches = 0
        self.indexed_batches = 0
        self.tokens_served = 0

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.clock = max(self.clock, req.arrival_time)
        self.queues.setdefault(req.adapter_id, []).append(req)

    # ------------------------------------------------------------- scheduling
    def _queue_view(self):
        sizes = {a: len(q) for a, q in self.queues.items() if q}
        ages = {
            a: (self.clock - min(r.arrival_time for r in q)) * 1e3
            for a, q in self.queues.items()
            if q
        }
        cached = {a: self.cache.contains(a) for a in sizes}
        return sizes, ages, cached

    def step(self) -> Optional[int]:
        """Schedule + execute one dispatch (one adapter batch, or the top-k
        adapters fused into a single grouped call when ``fuse_k > 1``).
        Returns the highest-priority adapter id, or None when idle."""
        sizes, ages, cached = self._queue_view()
        if not sizes:
            return None
        if self.cfg.policy == "noshare":
            # FCFS across all adapters, one request at a time, no batching.
            adapter, req = min(
                ((a, q[0]) for a, q in self.queues.items() if q),
                key=lambda ar: ar[1].arrival_time,
            )
            selected = [adapter]
            batches = {adapter: [req]}
        else:
            # Reuse the bucket scheduler via a lightweight façade over the
            # adapter queues (the grouped-matmul kernel is the execution
            # analogue: k adapters' batches run as one segmented matmul).
            selected = _select(
                self.scheduler, sizes, ages, cached, self.clock,
                k=max(1, self.cfg.fuse_k),
            )
            batches = {a: self.queues[a][: self.cfg.max_batch] for a in selected}

        step_time = 0.0
        for adapter in selected:
            batch = batches[adapter]
            if self.cfg.policy == "noshare":
                # Paper's NoShare: every request pays its own state load; no
                # residency is shared between requests.
                t_load = self.adapters[adapter].nbytes / self.cfg.hbm_bw
            else:
                t_load = 0.0
                if not self.cache.contains(adapter):
                    t_load = self.adapters[adapter].nbytes / self.cfg.hbm_bw
                use_indexed = (
                    len(batch) < self.cfg.hybrid_threshold
                    and not self.cache.contains(adapter)
                )
                if use_indexed:
                    # Gathered multi-adapter path: no residency established.
                    self.indexed_batches += 1
                    t_load = t_load * 0.25  # stream only the rows touched
                else:
                    self.cache.access(adapter)

            quantum = self.cfg.decode_quantum
            if self.decode_batch_fn is not None:
                self.decode_batch_fn(adapter, batch, quantum)

            # Load + quantum decode steps for the batch.
            step_time += t_load + quantum * self.cfg.per_token_cost * max(
                len(batch), 1
            )
            self.batches += 1
            for r in batch:
                r.tokens_done += quantum
                self.tokens_served += quantum

        # Advance virtual time once per dispatch; completions share the
        # dispatch finish time (the fused call returns all segments at once).
        self.clock += step_time
        for adapter in selected:
            for r in batches[adapter]:
                if r.done and r.finish_time is None:
                    r.finish_time = self.clock
                    self.completed.append(r)
            self.queues[adapter] = [
                r for r in self.queues[adapter] if not r.done
            ]
        return selected[0]

    def run(self, requests: list[Request]) -> dict:
        """Replay a request trace to completion; returns summary metrics."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        while i < len(pending) or any(self.queues.values()):
            if not any(self.queues.values()):
                self.clock = max(self.clock, pending[i].arrival_time)
            while i < len(pending) and pending[i].arrival_time <= self.clock:
                self.submit(pending[i])
                i += 1
            if any(self.queues.values()):
                self.step()
        return self.summary()

    def summary(self) -> dict:
        resp = [r.finish_time - r.arrival_time for r in self.completed]
        return {
            "policy": self.cfg.policy,
            "alpha": getattr(self.scheduler, "alpha", None),
            "n_completed": len(self.completed),
            "makespan": self.clock,
            "token_throughput": self.tokens_served / max(self.clock, 1e-9),
            "request_throughput": len(self.completed) / max(self.clock, 1e-9),
            "mean_response": float(np.mean(resp)) if resp else 0.0,
            "p95_response": float(np.percentile(resp, 95)) if resp else 0.0,
            "cache_hit_rate": self.cache.stats.hit_rate,
            "batches": self.batches,
            "indexed_batches": self.indexed_batches,
        }


def _select(scheduler, sizes, ages, cached, now, k: int = 1) -> list[int]:
    """Adapter-queue façade for the bucket schedulers.

    Returns the top-k adapter ids (best first).  The façade does not
    support change subscriptions, so the incremental LifeRaft scheduler
    transparently falls back to its full-rescan path here."""

    class _Q:
        def __init__(self, b, n, age):
            self.bucket_id = b
            self.size = n
            self._age = age

        @property
        def oldest_arrival(self):
            return now - self._age / 1e3

        def __bool__(self):
            return self.size > 0

    class _WM:
        def nonempty_queues(self):
            return [_Q(b, sizes[b], ages[b]) for b in sizes]

        def queue(self, b):
            return _Q(b, sizes[b], ages[b])

        def ages_ms(self, t):
            return dict(ages)

    class _Cache:
        def contains(self, b):
            return cached.get(b, False)

    if k > 1 and hasattr(scheduler, "select_topk"):
        return [d.bucket_id for d in scheduler.select_topk(_WM(), _Cache(), now, k)]
    return [scheduler.select(_WM(), _Cache(), now).bucket_id]

"""Durable service tier: a restartable daemon over the batch engines.

LifeRaft's production descendant (CasJobs) is a *service*: queries arrive
over the network, the submitter goes away, and the system owes them an
answer even across process crashes.  This module is that contract for the
repo's engines:

* **Write-ahead ack** — ``ServiceDaemon.submit`` appends the submission
  to an on-disk :class:`~repro.core.journal.Journal` and ``fsync``\\ s it
  *before* the engine sees the query.  The returned ack therefore implies
  durability: a ``kill -9`` one instruction later loses nothing that was
  acked.
* **Decision journal** — every scheduling round (and steal) the engine
  executes is appended to the same journal through the golden-trace codec
  (``encode_outcome`` / ``encode_steal``), so the journal doubles as a
  decision log diffable against goldens with ``diff_entries``.
* **Crash recovery by replay** — on startup the daemon replays the
  journal: submissions are re-applied in order and, for each journaled
  round, the engine is stepped and its re-executed decision compared
  bit-for-bit against the journaled one (:class:`RecoveryError` on any
  divergence — a recovery that silently re-decides differently is worse
  than a crash).  Rounds that executed before the crash but whose journal
  record was torn off simply re-execute — deterministically, since the
  engines are pure functions of the (submission, round) sequence — and
  are re-journaled.
* **Idempotent resubmission** — clients supply (or the host derives)
  stable keys.  Resubmitting an acked key returns a ``duplicate`` ack
  without re-enqueueing; resubmitting a rejected key re-raises the
  journaled :class:`~repro.core.control.AdmissionRejected` unless
  ``retry=True``.  A client that crashed mid-ack can therefore blindly
  resubmit everything in flight.
* **Admission control** — an optional
  :class:`~repro.core.control.AdmissionController` is consulted *before*
  the write-ahead append, against the tenant's total pending state (both
  residency sides — §6 spill must not launder quota headroom).
  Rejections are journaled with the same fsync barrier so replay
  reproduces every 429 exactly.

Engines plug in through small host adapters (:class:`ServingHost` for
``LifeRaftEngine`` / ``ShardedServingEngine``, :class:`CrossMatchHost`
for ``CrossMatchEngine``) that own item serialization, tenant accounting,
and the decision tap — the daemon itself is engine-agnostic.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.control import AdmissionController, AdmissionRejected
from ..core.journal import (
    Journal,
    diff_entries,
    encode_outcome,
    encode_steal,
)

__all__ = [
    "RecoveryError",
    "ServingHost",
    "CrossMatchHost",
    "ServiceDaemon",
]


class RecoveryError(RuntimeError):
    """Journal replay re-executed a round whose decision diverged from the
    journaled one (or ran out of work before reproducing it).  The engines
    are deterministic given the journaled operation order, so this means
    the code changed underneath the journal — refuse to 'recover' into a
    different schedule."""


# ------------------------------------------------------------------ hosts
class ServingHost:
    """Daemon adapter for :class:`~repro.serving.engine.LifeRaftEngine`
    and :class:`~repro.serving.engine.ShardedServingEngine` (duck-typed on
    the sharded coordinator's ``engines`` list).  Items are
    :class:`~repro.serving.engine.Request` objects — all fields are
    JSON-simple, so the codec is the plain field list."""

    kind = "serving"

    def __init__(self, engine) -> None:
        self.engine = engine
        self._sharded = hasattr(engine, "engines")
        self._engines = engine.engines if self._sharded else [engine]

    # -- decision tap --------------------------------------------------------
    def install_tap(self, emit) -> None:
        if self._sharded:
            self.engine.on_round = (
                lambda sid, outcome: emit(encode_outcome(outcome, shard=sid))
            )
            self.engine.on_steal = lambda ev: emit(encode_steal(ev))
        else:
            self.engine.loop.add_round_tap(
                lambda outcome: emit(encode_outcome(outcome))
            )

    # -- engine drive --------------------------------------------------------
    def submit(self, req) -> None:
        self.engine.submit(req)

    def step(self):
        return self.engine.step()

    def has_work(self) -> bool:
        return any(e.workload.nonempty_queues() for e in self._engines)

    def clock(self) -> float:
        return max(e.clock for e in self._engines)

    # -- item codec ----------------------------------------------------------
    @staticmethod
    def encode_item(req) -> dict:
        return {
            "request_id": int(req.request_id),
            "adapter_id": int(req.adapter_id),
            "arrival_time": float(req.arrival_time),
            "prompt_len": int(req.prompt_len),
            "max_new_tokens": int(req.max_new_tokens),
        }

    @staticmethod
    def decode_item(item: dict):
        from .engine import Request

        return Request(
            request_id=int(item["request_id"]),
            adapter_id=int(item["adapter_id"]),
            arrival_time=float(item["arrival_time"]),
            prompt_len=int(item["prompt_len"]),
            max_new_tokens=int(item["max_new_tokens"]),
        )

    @staticmethod
    def item_key(req) -> str:
        return f"req-{int(req.request_id)}"

    # -- admission accounting ------------------------------------------------
    def tenant_of(self, req) -> str:
        return self._engines[0].workload.tenant_of_adapter(req.adapter_id)

    def size_of(self, req) -> tuple[int, float]:
        wl = self._engines[0].workload
        return 1, max(req.prompt_len * wl.probe_bytes, wl.min_unit_bytes)

    def pending_for_tenant(self, tenant: str) -> tuple[int, float]:
        objs, nbytes = 0, 0.0
        for e in self._engines:
            o, b = e.workload.tenant_pending(tenant)
            objs += o
            nbytes += b
        return objs, nbytes

    # -- completion / state --------------------------------------------------
    def completed_ids(self) -> set:
        return {
            int(r.request_id)
            for e in self._engines
            for r in e.completed
            if r.finish_time is not None
        }

    def state_fingerprint(self) -> dict:
        fp = {"shards": [_engine_fingerprint(e) for e in self._engines]}
        if self._sharded:
            fp["overrides"] = {
                int(b): int(s)
                for b, s in sorted(self.engine.shard_map.overrides.items())
            }
        return fp


class CrossMatchHost:
    """Daemon adapter for the batch cross-match engine
    (:class:`~repro.crossmatch.engine.CrossMatchEngine`).  Items are
    :class:`~repro.core.workload.Query` objects; the codec carries the key
    ranges and payload/meta arrays as typed nested lists."""

    kind = "crossmatch"

    def __init__(self, engine) -> None:
        self.engine = engine

    # -- decision tap --------------------------------------------------------
    def install_tap(self, emit) -> None:
        self.engine.loop.add_round_tap(
            lambda outcome: emit(encode_outcome(outcome))
        )

    # -- engine drive --------------------------------------------------------
    def submit(self, query) -> None:
        # Batch intake bumps the virtual clock like CrossMatchEngine.run —
        # arrivals never travel backwards in time.
        self.engine.sim_clock = max(
            self.engine.sim_clock, query.arrival_time
        )
        self.engine.submit(query)

    def step(self):
        return self.engine.step()

    def has_work(self) -> bool:
        return bool(self.engine.wm.nonempty_queues())

    def clock(self) -> float:
        return self.engine.sim_clock

    # -- item codec ----------------------------------------------------------
    @staticmethod
    def encode_item(query) -> dict:
        return {
            "query_id": int(query.query_id),
            "arrival_time": float(query.arrival_time),
            "keys_lo": np.asarray(query.keys_lo).tolist(),
            "keys_hi": np.asarray(query.keys_hi).tolist(),
            "payload": {
                k: {"dtype": str(np.asarray(v).dtype),
                    "data": np.asarray(v).tolist()}
                for k, v in (query.payload or {}).items()
            },
            "meta": dict(query.meta or {}),
        }

    @staticmethod
    def decode_item(item: dict):
        from ..core.workload import Query

        return Query(
            query_id=int(item["query_id"]),
            arrival_time=float(item["arrival_time"]),
            keys_lo=np.asarray(item["keys_lo"], dtype=np.int64),
            keys_hi=np.asarray(item["keys_hi"], dtype=np.int64),
            payload={
                k: np.asarray(v["data"], dtype=v["dtype"])
                for k, v in item.get("payload", {}).items()
            },
            meta=dict(item.get("meta", {})),
        )

    @staticmethod
    def item_key(query) -> str:
        return f"q-{int(query.query_id)}"

    # -- admission accounting ------------------------------------------------
    @staticmethod
    def tenant_of(query) -> str:
        return query.tenant

    def size_of(self, query) -> tuple[int, float]:
        wm = self.engine.wm
        return query.n_objects, max(
            query.n_objects * wm.probe_bytes, wm.min_unit_bytes
        )

    def pending_for_tenant(self, tenant: str) -> tuple[int, float]:
        return self.engine.wm.tenant_pending(tenant)

    # -- completion / state --------------------------------------------------
    def completed_ids(self) -> set:
        return {int(qid) for qid in self.engine.wm.completed}

    def state_fingerprint(self) -> dict:
        eng = self.engine
        fp = {
            "clock": float(eng.sim_clock),
            "workload": eng.wm.snapshot(),
            "cache": [int(b) for b in eng.cache._entries],
        }
        state = getattr(eng.loop.control, "state", None)
        if callable(state):
            fp["control"] = state()
        fp["sched"] = _sched_fingerprint(
            eng.scheduler, eng.wm, eng.cache, eng.loop.clock
        )
        return fp


def _sched_fingerprint(scheduler, workload, cache, clock, k: int = 8):
    """Top-k (bucket, score) pairs from the scheduler's non-mutating
    oracle — pins the priority index without disturbing it."""
    peek = getattr(scheduler, "peek_topk", None)
    if peek is None:
        return None
    return [
        [int(d.bucket_id), float(d.score)]
        for d in peek(workload, cache, clock, k)
    ]


def _engine_fingerprint(e) -> dict:
    fp = {
        "clock": float(e.clock),
        "workload": e.workload.snapshot(),
        "cache": [int(a) for a in e.cache._entries],
        "completed": sorted(
            int(r.request_id) for r in e.completed
        ),
    }
    state = getattr(e.control, "state", None)
    if callable(state):
        fp["control"] = state()
    fp["sched"] = _sched_fingerprint(
        e.scheduler, e.workload, e.cache, e.clock
    )
    return fp


# ------------------------------------------------------------------ daemon
class ServiceDaemon:
    """Restartable service wrapper: write-ahead acks, decision journal,
    idempotent resubmission, replay recovery, admission control.

    Construction *is* recovery: if ``journal_dir`` holds segments from a
    previous incarnation, they are replayed into the (fresh) engine before
    the constructor returns, and the daemon continues exactly where the
    journaled schedule left off.  Drive it with ``submit`` + ``pump``::

        daemon = ServiceDaemon(ServingHost(engine), "journal/")
        for req in trace:
            daemon.pump(until=req.arrival_time)   # decode up to arrival
            daemon.submit(req)                    # durable ack
        daemon.pump()                             # drain

    The same driver re-run after a crash-and-recover fast-forwards through
    already-acked work (``pump`` no-ops while the recovered clock is
    ahead; ``submit`` dedupes on the key) and continues bit-identically to
    a never-crashed run.
    """

    def __init__(
        self,
        host,
        journal_dir,
        *,
        admission: Optional[AdmissionController] = None,
        segment_bytes: int = 1 << 20,
        obs=None,
    ) -> None:
        self.host = host
        self.admission = admission
        self.journal = Journal(
            journal_dir, segment_bytes=segment_bytes, kind=host.kind
        )
        self.obs = None
        if obs:
            # Lazy import: the default (obs off) never touches repro.obs.
            # The daemon's contribution is the journal append/fsync
            # latency tap, admission verdict counters, and the served
            # metrics_text/metrics_snapshot endpoints; to also see the
            # engine's round metrics, construct the engine with the same
            # Observability instance.
            from ..obs import ensure as _obs_ensure

            self.obs = _obs_ensure(obs)
            self.obs.attach_journal(self.journal)
        # Full in-memory decision log (same entries the journal holds,
        # including rounds recovered by replay) — diffable against a
        # golden via ``diff_entries``.
        self.entries: list[dict] = []
        self.acked: dict[str, dict] = {}  # key -> journaled item
        self.rejected: dict[str, AdmissionRejected] = {}
        self._recovering = False
        self._tap_buf: list[dict] = []
        host.install_tap(self._emit)
        self._recover()
        if self.obs is not None:
            self.obs.note_recovery(
                self._recovered_records, self._recovered_rounds
            )

    # -- decision tap --------------------------------------------------------
    def _emit(self, entry: dict) -> None:
        self.entries.append(entry)
        if self._recovering:
            self._tap_buf.append(entry)
        else:
            self.journal.append({"type": "entry", "entry": entry})

    # -- recovery ------------------------------------------------------------
    def _recover(self) -> None:
        records = self.journal.replay()
        self._recovered_records = len(records)
        self._recovered_rounds = 0
        if not records:
            return
        self._recovering = True
        try:
            for rec in records:
                rtype = rec.get("type")
                if rtype == "submit":
                    self.host.submit(self.host.decode_item(rec["item"]))
                    self.acked[rec["key"]] = rec["item"]
                    # A journaled resubmission supersedes an earlier 429
                    # for the same key (the client retried into headroom).
                    self.rejected.pop(rec["key"], None)
                elif rtype == "reject":
                    self.rejected[rec["key"]] = AdmissionRejected(
                        rec["tenant"], rec["reason"],
                        rec["observed"], rec["limit"],
                    )
                elif rtype == "entry":
                    self._recovered_rounds += 1
                    expect = rec["entry"]
                    while not self._tap_buf:
                        if self.host.step() is None:
                            raise RecoveryError(
                                "journal holds more rounds than the "
                                "replayed workload can produce — journal "
                                "and engine disagree"
                            )
                    got = self._tap_buf.pop(0)
                    diff = diff_entries([expect], [got])
                    if diff:
                        raise RecoveryError(
                            "replayed decision diverged from journal:\n"
                            + "\n".join(diff)
                        )
        finally:
            self._recovering = False
        # Rounds that executed pre-crash but whose journal record was torn
        # off were just re-executed (deterministically) during the final
        # journaled round's catch-up stepping; persist them now.
        for entry in self._tap_buf:
            self.journal.append({"type": "entry", "entry": entry})
        self._tap_buf = []

    # -- intake --------------------------------------------------------------
    def submit(self, item, *, key: Optional[str] = None,
               retry: bool = False) -> dict:
        """Durable, idempotent intake.  Returns ``{"key", "status"}`` with
        status ``acked`` (newly durable) or ``duplicate`` (key already
        acked — the engine is not touched).  Raises
        :class:`~repro.core.control.AdmissionRejected` on quota (journaled
        before raising; resubmits re-raise the cached rejection unless
        ``retry=True``)."""
        key = key if key is not None else self.host.item_key(item)
        if key in self.acked:
            return {"key": key, "status": "duplicate"}
        cached = self.rejected.get(key)
        if cached is not None and not retry:
            raise cached
        if self.admission is not None:
            tenant = self.host.tenant_of(item)
            add_objs, add_bytes = self.host.size_of(item)
            objs, nbytes = self.host.pending_for_tenant(tenant)
            try:
                self.admission.check(
                    tenant, objs, nbytes,
                    add_objects=add_objs, add_bytes=add_bytes,
                )
            except AdmissionRejected as exc:
                # 429s are decisions too: journal with the same fsync
                # barrier so replay reproduces them exactly.
                self.journal.append(
                    {
                        "type": "reject", "key": key, "tenant": exc.tenant,
                        "reason": exc.reason, "observed": exc.observed,
                        "limit": exc.limit,
                    },
                    sync=True,
                )
                self.rejected[key] = exc
                if self.obs is not None:
                    self.obs.note_admission(exc.tenant, False, exc.reason)
                raise
            if self.obs is not None:
                self.obs.note_admission(tenant, True)
        # Write-ahead barrier: the record is fsync'd before the engine
        # sees the item, so the ack below implies durability.
        self.journal.append(
            {"type": "submit", "key": key, "item": self.host.encode_item(item)},
            sync=True,
        )
        self.host.submit(item)
        self.acked[key] = self.host.encode_item(item)
        self.rejected.pop(key, None)
        return {"key": key, "status": "acked"}

    # -- drive ---------------------------------------------------------------
    def pump(self, until: Optional[float] = None) -> int:
        """Run scheduling rounds while work is pending (and, with
        ``until``, while the engine clock is behind it).  Returns the
        number of rounds serviced."""
        serviced = 0
        while self.host.has_work():
            if until is not None and self.host.clock() >= until:
                break
            if self.host.step() is None:
                break
            serviced += 1
        return serviced

    # -- introspection -------------------------------------------------------
    def disposition(self, key: str) -> Optional[str]:
        if key in self.acked:
            return "acked"
        if key in self.rejected:
            return "rejected"
        return None

    def completed(self) -> set:
        """Ids of items whose work has fully completed."""
        return self.host.completed_ids()

    def state_fingerprint(self) -> dict:
        """Plain-data view of the engine's full scheduling state — the
        durability property tests assert replayed == live at every
        truncation point of a recorded run."""
        return self.host.state_fingerprint()

    # -- observability endpoints ---------------------------------------------
    def metrics_text(self) -> str:
        """Prometheus text exposition of the attached Observability (empty
        without ``obs=`` — scraping a dark daemon is not an error)."""
        return self.obs.prometheus() if self.obs is not None else ""

    def metrics_snapshot(self) -> dict:
        """JSON-safe metrics + ControlExplain + trace rollup snapshot."""
        return self.obs.snapshot() if self.obs is not None else {}

    def close(self) -> None:
        self.journal.close()

"""Paged KV cache pool: fixed-size pages, per-sequence page tables.

Pages are LifeRaft buckets on the serving side: uniform-size units of
expensive device state.  The pool hands out pages, tracks free lists, and
supports prefix sharing (several sequences referencing the same pages,
refcounted) — the serving analogue of multiple queries batched on one
bucket.  ``repro.kernels.paged_attention`` consumes the pool's tensors.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["PagePool", "SequenceAllocation"]


@dataclasses.dataclass
class SequenceAllocation:
    seq_id: int
    pages: list[int]
    length: int = 0


class PagePool:
    def __init__(self, n_pages: int, page_size: int, n_kv: int, head_dim: int,
                 dtype=jnp.bfloat16):
        self.n_pages = n_pages
        self.page_size = page_size
        self.k_pages = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self.v_pages = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self._free = list(range(n_pages - 1, -1, -1))
        self._refcount = np.zeros(n_pages, dtype=np.int64)
        self._seqs: dict[int, SequenceAllocation] = {}

    # -- allocation ---------------------------------------------------------
    def create(self, seq_id: int, prefix_of: int | None = None) -> SequenceAllocation:
        if prefix_of is not None and prefix_of in self._seqs:
            parent = self._seqs[prefix_of]
            pages = list(parent.pages)  # shared, copy-on-write at append
            for p in pages:
                self._refcount[p] += 1
            alloc = SequenceAllocation(seq_id, pages, parent.length)
        else:
            alloc = SequenceAllocation(seq_id, [])
        self._seqs[seq_id] = alloc
        return alloc

    def append_token_slot(self, seq_id: int) -> tuple[int, int]:
        """Reserve the slot for one new token; returns (page, offset)."""
        alloc = self._seqs[seq_id]
        off = alloc.length % self.page_size
        if off == 0:  # need a fresh page
            page = self._alloc_page()
            alloc.pages.append(page)
        else:
            page = alloc.pages[-1]
            if self._refcount[page] > 1:  # copy-on-write for shared tails
                new = self._alloc_page()
                self.k_pages = self.k_pages.at[new].set(self.k_pages[page])
                self.v_pages = self.v_pages.at[new].set(self.v_pages[page])
                self._refcount[page] -= 1
                alloc.pages[-1] = new
                page = new
        alloc.length += 1
        return page, off

    def _alloc_page(self) -> int:
        if not self._free:
            raise MemoryError("page pool exhausted")
        p = self._free.pop()
        self._refcount[p] = 1
        return p

    def release(self, seq_id: int) -> None:
        alloc = self._seqs.pop(seq_id, None)
        if alloc is None:
            return
        for p in alloc.pages:
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)

    # -- views ---------------------------------------------------------------
    def write_kv(self, page: int, off: int, k, v) -> None:
        """k/v: (n_kv, head_dim) for one token."""
        self.k_pages = self.k_pages.at[page, off].set(k)
        self.v_pages = self.v_pages.at[page, off].set(v)

    def page_table(self, seq_ids: list[int], pad_to: int) -> tuple:
        """(B, pad_to) page table + (B,) lengths for the attention kernel."""
        B = len(seq_ids)
        pt = np.zeros((B, pad_to), dtype=np.int32)
        lens = np.zeros(B, dtype=np.int32)
        for i, sid in enumerate(seq_ids):
            alloc = self._seqs[sid]
            pt[i, : len(alloc.pages)] = alloc.pages
            lens[i] = alloc.length
        return jnp.asarray(pt), jnp.asarray(lens)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        return 1.0 - self.free_pages / self.n_pages

# Convenience entry points; CI runs the same commands (.github/workflows).
PYTHON ?= python
export PYTHONPATH := src

.PHONY: lint lint-baseline test bench

lint:
	$(PYTHON) -m tools.analysis src tests --baseline tools/analysis/baseline.json

# Regenerate the grandfathered-findings baseline (shrink-only by policy:
# see docs/static-analysis.md).
lint-baseline:
	$(PYTHON) -m tools.analysis src tests --baseline tools/analysis/baseline.json --write-baseline

test:
	$(PYTHON) -m pytest -x -q

bench:
	$(PYTHON) -m benchmarks.run

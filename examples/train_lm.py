"""End-to-end training driver: data pipeline -> train step -> checkpoints.

Trains a reduced codeqwen-family decoder on the synthetic Markov stream and
demonstrates checkpoint/restart (kill it mid-run; rerun resumes).  Use
``--big`` for a ~100M-parameter config (slow on CPU — sized for a real chip).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import smoke_config
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/liferaft_train_ckpt")
    ap.add_argument("--big", action="store_true",
                    help="~100M params (intended for accelerator runs)")
    args = ap.parse_args()

    cfg = smoke_config("codeqwen1.5-7b")
    if args.big:
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, d_ff=3072, n_heads=12,
            n_kv_heads=12, head_dim=64, vocab_size=32768,
        )
    print(f"arch={cfg.name} (reduced) params~"
          f"{cfg.param_count() / 1e6:.1f}M optimizer={cfg.optimizer}")
    tcfg = TrainerConfig(
        steps=args.steps,
        checkpoint_every=50,
        checkpoint_dir=args.ckpt_dir,
        log_every=20,
        lr=1e-3,
        global_batch=8,
        seq_len=128,
    )
    trainer = Trainer(cfg, tcfg)
    history = trainer.run()
    losses = [h["loss"] for h in history]
    if len(losses) >= 20:
        first, last = np.mean(losses[:10]), np.mean(losses[-10:])
        print(f"loss {first:.3f} -> {last:.3f} "
              f"({'improved' if last < first else 'NOT improving'})")


if __name__ == "__main__":
    main()

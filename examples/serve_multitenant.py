"""Multi-tenant serving with LifeRaft batching + REAL decode steps.

A reduced moonshot-family MoE model decodes actual tokens while the
LifeRaft engine schedules which tenant's (adapter's) batch runs next —
buckets are adapter weight groups, the cache is HBM adapter slots.

With ``--adaptive`` the closed-loop control plane (docs/adaptive.md)
retunes alpha / fuse_k / §6 spill every scheduling round from live queue
telemetry instead of running the static knobs.  ``--per-tenant`` goes one
further: adapters 0-1 are the *interactive* class (alpha pinned high —
arrival order), the rest are *batch* (alpha low — data-driven), each
class running its own control vector with the §6 byte budget arbitrated
between them.

``--metrics`` attaches the observability layer (docs/observability.md)
and dumps the Prometheus text exposition after the run; add
``--metrics-json PATH`` for the consolidated JSON snapshot (metrics +
control-explain + trace rollup).  Metrics ride the side-channel taps, so
the schedule is identical with or without them.

    PYTHONPATH=src python examples/serve_multitenant.py [--policy liferaft]
    PYTHONPATH=src python examples/serve_multitenant.py --adaptive
    PYTHONPATH=src python examples/serve_multitenant.py --per-tenant
    PYTHONPATH=src python examples/serve_multitenant.py --adaptive --metrics
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import ControlConfig, TenantPolicy
from repro.models import registry as R
from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig
from repro.training.train_step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="liferaft",
                    choices=["liferaft", "rr", "noshare"])
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--adaptive", action="store_true",
                    help="closed-loop alpha/fuse_k/spill control per round")
    ap.add_argument("--per-tenant", action="store_true",
                    help="one control vector per adapter class "
                         "(interactive vs batch) + arbitrated byte budget")
    ap.add_argument("--metrics", action="store_true",
                    help="attach observability taps and print the "
                         "Prometheus text exposition after the run")
    ap.add_argument("--metrics-json", metavar="PATH", default=None,
                    help="also write the consolidated obs snapshot "
                         "(implies --metrics)")
    args = ap.parse_args()
    if args.metrics_json:
        args.metrics = True

    obs = None
    if args.metrics:
        from repro.obs import Observability

        obs = Observability()

    cfg = smoke_config("moonshot-v1-16b-a3b")
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    max_seq, max_batch = 64, 8
    serve_step = jax.jit(make_serve_step(cfg, max_seq))

    n_adapters = 6
    # Per-tenant adapters: additive deltas on the unembed (kept tiny here;
    # rank-decomposed in a real deployment).
    adapters_delta = [
        0.01 * jax.random.normal(jax.random.PRNGKey(10 + a), params["unembed"].shape)
        for a in range(n_adapters)
    ]
    decoded_tokens = {a: 0 for a in range(n_adapters)}

    def decode_batch(adapter_id, batch, quantum):
        """Real decode: swap in the tenant delta, run `quantum` steps."""
        p = dict(params)
        p["unembed"] = params["unembed"] + adapters_delta[adapter_id].astype(
            params["unembed"].dtype
        )
        B = max_batch
        cache = R.make_cache(cfg, B, max_seq)
        tok = jnp.zeros((B, 1), jnp.int32)
        for _ in range(quantum):
            tok, cache = serve_step(p, tok, cache)
        decoded_tokens[adapter_id] += quantum * len(batch)

    rng = np.random.default_rng(0)
    zipf = 1.0 / np.arange(1, n_adapters + 1) ** 1.5
    zipf /= zipf.sum()
    t, reqs = 0.0, []
    for i in range(args.requests):
        t += rng.exponential(1 / 50.0)
        reqs.append(Request(i, int(rng.choice(n_adapters, p=zipf)), t,
                            int(rng.integers(8, 32)), 16))

    tenant_policies = None
    if args.per_tenant:
        tenant_policies = (
            TenantPolicy("interactive", ControlConfig(
                alpha_init=0.9, alpha_min=0.7, alpha_max=1.0,
                rate_knee=200.0, depth_knee=64.0, fuse_k_max=2,
            )),
            TenantPolicy("batch", ControlConfig(
                alpha_init=0.2, alpha_min=0.0, alpha_max=0.4,
                rate_knee=200.0, depth_knee=64.0, fuse_k_max=4,
            ), weight=2.0),
        )
    engine = LifeRaftEngine(
        [AdapterSpec(a, 2 << 30,
                     tenant=("interactive" if a < 2 else "batch")
                     if args.per_tenant else "default")
         for a in range(n_adapters)],
        ServeConfig(policy=args.policy, alpha=args.alpha, adapter_slots=2,
                    max_batch=max_batch, decode_quantum=16,
                    adaptive=args.adaptive, fuse_k_max=4,
                    spill_budget=4 * max_batch, spill_penalty_s=5e-3,
                    tenant_policies=tenant_policies,
                    spill_budget_bytes=4096.0 if args.per_tenant else None,
                    kv_bytes_per_token=2.0),
        decode_batch_fn=decode_batch,
        obs=obs,
    )
    mode = ("per-tenant control plane" if args.per_tenant
            else "adaptive closed-loop" if args.adaptive else args.policy)
    print(f"serving {len(reqs)} requests across {n_adapters} tenants "
          f"({mode}, reduced moonshot MoE, real decode)...")
    s = engine.run(reqs)
    print(f"  completed         : {s['n_completed']}")
    print(f"  token throughput  : {s['token_throughput']:.1f} tok/s (simulated clock)")
    print(f"  mean response     : {s['mean_response']:.3f}s  p95={s['p95_response']:.3f}s")
    print(f"  adapter cache hit : {s['cache_hit_rate']:.2f}")
    if args.per_tenant and s["per_tenant"]:
        print("  per-tenant stats  :")
        print(json.dumps(s["per_tenant"], indent=4))
    elif args.adaptive and engine.control is not None and engine.control.last:
        vec = engine.control.last
        print(f"  controller        : alpha={vec.alpha:.2f} fuse_k={vec.fuse_k} "
              f"rounds={engine.control.rounds} spilled={s['spilled']}")
    print(f"  real tokens decoded per tenant: {decoded_tokens}")
    if obs is not None:
        print("\n--- Prometheus exposition " + "-" * 40)
        print(obs.prometheus(), end="")
        if args.metrics_json:
            with open(args.metrics_json, "w") as fh:
                json.dump(obs.snapshot(), fh, indent=1)
                fh.write("\n")
            print(f"--- snapshot written to {args.metrics_json}")


if __name__ == "__main__":
    main()

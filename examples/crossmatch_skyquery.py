"""End-to-end cross-match: real join compute through the LifeRaft engine.

Unlike quickstart.py (pure scheduling simulation), this drives the full
Fig. 3 architecture: Query Pre-Processor -> Workload Manager -> LifeRaft
Scheduler -> Join Evaluator (the cross-match kernel) -> Bucket Cache, and
reports both scheduling metrics and actual match results.

    PYTHONPATH=src python examples/crossmatch_skyquery.py [--pallas]
"""
import argparse

import numpy as np

from repro.core import (
    CostModel,
    HybridCostModel,
    HybridPlanner,
    LifeRaftScheduler,
)
from repro.crossmatch import CrossMatchEngine, TraceConfig, make_catalog, make_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pallas", action="store_true",
                    help="run the Pallas kernel (interpret mode) instead of jnp")
    ap.add_argument("--queries", type=int, default=60)
    ap.add_argument("--alpha", type=float, default=0.25)
    args = ap.parse_args()

    cat = make_catalog(n_objects=40_000, objects_per_bucket=400, htm_level=8, seed=3)
    trace = make_trace(
        cat, TraceConfig(n_queries=args.queries, arrival_rate=1.0,
                         objects_median=200, seed=4),
    )
    cost = CostModel(T_b=1.2, T_m=0.13e-3)
    hybrid = HybridPlanner(
        HybridCostModel(T_b=1.2, T_m=0.13e-3, T_probe=4.13e-3),
        objects_per_bucket=400,
    )
    engine = CrossMatchEngine(
        cat,
        scheduler=LifeRaftScheduler(cost, alpha=args.alpha),
        cost_model=cost,
        cache_capacity=20,
        match_radius_rad=5e-3,
        hybrid=hybrid,
        use_pallas=args.pallas,
    )
    print(f"running {len(trace)} cross-match queries "
          f"({'pallas-interpret' if args.pallas else 'jnp'} join path)...")
    results = engine.run(trace)
    n_matches = sum(len(r.probe_idx) for groups in results.values() for r in groups)
    s = engine.summary()
    print(f"  queries completed : {s['n_queries']}")
    print(f"  bucket batches    : {s['n_batches']}")
    print(f"  matched objects   : {n_matches}")
    print(f"  mean response     : {s['mean_response']:.1f}s (simulated)")
    print(f"  cache hit rate    : {s['cache_hit_rate']:.2f}")
    # probabilistic-join sanity: matched pairs really are within the radius
    dots = [
        float(r.best_dot.min())
        for groups in results.values()
        for r in groups
        if len(r.best_dot)
    ]
    if dots:
        print(f"  min matched cos   : {min(dots):.6f} "
              f"(threshold {np.cos(5e-3):.6f})")


if __name__ == "__main__":
    main()

"""Quickstart: the LifeRaft scheduler in 40 lines.

Builds a small bucketed sky catalog, generates a SkyQuery-like query trace,
and compares NoShare / RR / LifeRaft schedulers on throughput and response
time using the paper's cost model.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import PAPER_COST_MODEL, run_policy
from repro.crossmatch import TraceConfig, make_catalog, make_trace


def main():
    print("building catalog (50k objects, 500 buckets)...")
    cat = make_catalog(n_objects=50_000, objects_per_bucket=100, htm_level=8)
    trace = make_trace(
        cat,
        TraceConfig(n_queries=400, arrival_rate=0.5, zipf_s=1.6, seed=1),
    )
    print(f"replaying {len(trace)} queries under three schedulers:\n")
    bok = cat.partitioner.bucket_of_keys
    rows = []
    for policy, alpha in [("noshare", 0.0), ("rr", 0.0),
                          ("liferaft", 0.0), ("liferaft", 0.5)]:
        r = run_policy(
            policy, trace, cat.partitioner.buckets_for_range, PAPER_COST_MODEL,
            alpha=alpha, cache_capacity=20, bucket_of_keys=bok,
        )
        rows.append(r)
        print(
            f"  {r.policy:16s} throughput={r.query_throughput:7.4f} q/s  "
            f"mean-response={r.mean_response:8.1f}s  cache-hit={r.cache_hit_rate:.2f}"
        )
    base = rows[0].query_throughput
    best = max(rows, key=lambda r: r.query_throughput)
    print(
        f"\nLifeRaft speedup over NoShare: "
        f"{best.query_throughput / base:.2f}x  (paper reports ~2x)"
    )


if __name__ == "__main__":
    main()

"""Repo tooling namespace (static analysis lives in tools.analysis)."""

"""Command-line front end: ``python -m tools.analysis`` / ``liferaft-lint``.

Exit codes: 0 clean (or everything baselined), 1 new findings, 2 usage
error.  The CI tier-1 job runs::

    python -m tools.analysis src tests --baseline tools/analysis/baseline.json
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .framework import AnalyzerConfig, Baseline, analyze_paths
from .passes import ALL_PASSES, rule_catalog
from .passes.journal_schema import JournalSchemaPass, default_manifest_path

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="liferaft-lint",
        description="AST invariant analyzer: determinism, lock order, "
        "tracing safety, journal schema drift.",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src", "tests"],
        help="files/directories to analyze (default: src tests)",
    )
    ap.add_argument(
        "--baseline", default=None,
        help="baseline JSON; findings in it are grandfathered",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="write current findings to --baseline and exit 0",
    )
    ap.add_argument(
        "--rules", default=None,
        help="comma-separated rule ids to report (default: all)",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit",
    )
    ap.add_argument(
        "--schema-manifest", default=None,
        help="journal schema manifest path (default: bundled)",
    )
    ap.add_argument(
        "--update-schema-manifest", metavar="JOURNAL_PY", nargs="?",
        const="src/repro/core/journal.py", default=None,
        help="regenerate the schema manifest from the journal module "
        "(use together with a TRACE_SCHEMA_VERSION bump) and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, (pname, why) in sorted(rule_catalog().items()):
            print(f"{rule:26s} [{pname}] {why}")
        return 0

    if args.update_schema_manifest:
        doc = JournalSchemaPass.write_manifest(
            args.update_schema_manifest, args.schema_manifest
        )
        dest = args.schema_manifest or default_manifest_path()
        print(
            f"schema manifest -> {dest}: version {doc['version']}, "
            f"{len(doc['fields'])} fields"
        )
        return 0

    config = AnalyzerConfig(schema_manifest=args.schema_manifest)
    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(f"error: no such path(s): {', '.join(missing)}",
              file=sys.stderr)
        return 2

    findings = analyze_paths(args.paths, ALL_PASSES, config)
    if args.rules:
        keep = {r.strip() for r in args.rules.split(",")}
        findings = [f for f in findings if f.rule in keep]

    if args.write_baseline:
        if not args.baseline:
            print("error: --write-baseline requires --baseline",
                  file=sys.stderr)
            return 2
        Baseline.from_findings(findings).save(args.baseline)
        print(f"baseline written: {len(findings)} finding(s) grandfathered")
        return 0

    baseline = Baseline.load(args.baseline) if args.baseline else Baseline()
    fresh = baseline.new_findings(findings)
    for f in fresh:
        print(f.render())
    suppressed = len(findings) - len(fresh)
    tail = f" ({suppressed} baselined)" if suppressed else ""
    print(
        f"liferaft-lint: {len(fresh)} new finding(s){tail} over "
        f"{', '.join(args.paths)}"
    )
    return 1 if fresh else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())

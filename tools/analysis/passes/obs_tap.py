"""Obs-tap purity pass: taps must never mutate what they observe.

PR 10's observability layer rides entirely on the side-channel taps —
``DispatchLoop.add_round_tap``, the sharded coordinators' ``on_round`` /
``on_steal`` — and its whole correctness story is that taps only *read*.
The journal and the golden recorder consume the **same**
``DispatchOutcome`` / ``StealEvent`` objects after (or before, depending
on chain order) the obs taps fire, so a tap that mutates its argument
corrupts the decision log bit-identically-replayed goldens depend on,
and does it silently: the scheduler itself never looks at an outcome
again, so no runtime check catches it.

``obs-tap-pure``
    A callable registered as a tap (``x.add_round_tap(f)``, an
    ``on_round=`` / ``on_steal=`` keyword argument, or an assignment to
    an ``.on_round`` / ``.on_steal`` attribute) must treat its delivered
    arguments as read-only: no attribute/item assignment, augmented
    assignment, or deletion rooted at a tap parameter (or a local alias
    of one), and no known-mutator method call (``append``/``update``/
    ``sort``/...) on such a chain.  Copies are fine — a name bound to
    anything other than a plain attribute/subscript chain off a tainted
    root (``mine = list(outcome.decisions)``) is untainted, and a
    parameter rebound to a copy drops its taint.

Resolution is deliberately static and conservative-in-the-don't-flag
direction: lambdas are analyzed inline; a plain name resolves to ``def``
statements in the registering scope (falling back to same-named defs
anywhere in the file); a name bound to ``ClassName(...)`` for a class
defined in the file resolves to that class's ``__call__`` (and
``inst.method`` references resolve to the method), with ``self``
untainted.  Bound methods of out-of-file classes, call results, and
parameters forwarded by name are skipped.  Parameters *with defaults*
are treated as closure captures (the ``entries=entries`` binding idiom),
not tap-delivered arguments.
"""
from __future__ import annotations

import ast

from ..framework import AnalyzerConfig, Finding, LintPass, ParsedFile

__all__ = ["ObsTapPurityPass"]

_TAP_ATTRS = ("on_round", "on_steal")

# Method names that mutate their receiver in place.
_MUTATORS = {
    "append", "extend", "insert", "remove", "pop", "clear", "sort",
    "reverse", "update", "setdefault", "popitem", "add", "discard",
}


def _walk_scope(body):
    """Yield nodes of one scope without descending into nested scopes
    (nested defs/lambdas/classes are resolved separately if registered)."""
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(
            node,
            (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda, ast.ClassDef),
        ):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _chain_root(node):
    """Name at the root of an attribute/subscript chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _tainted_params(args: ast.arguments) -> list:
    """Parameters the tap machinery actually delivers: positional ones
    without defaults (defaulted params are the ``x=x`` capture idiom),
    plus ``*args``."""
    pos = list(args.posonlyargs) + list(args.args)
    n_defaults = len(args.defaults)
    if n_defaults:
        pos = pos[:-n_defaults]
    names = [a.arg for a in pos]
    if args.vararg is not None:
        names.append(args.vararg.arg)
    return names


class ObsTapPurityPass(LintPass):
    name = "obs-tap"
    rules = {
        "obs-tap-pure": (
            "registered observability taps must not mutate the "
            "outcome/event objects they observe"
        ),
    }

    def run(self, pf: ParsedFile, config: AnalyzerConfig) -> list:
        defs: dict = {}
        classes: dict = {}
        for node in ast.walk(pf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
            elif isinstance(node, ast.ClassDef):
                classes[node.name] = node

        findings: list = []
        checked: set = set()  # id() of analyzed callables — dedup
        scopes = [pf.tree] + [
            n
            for n in ast.walk(pf.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        for scope in scopes:
            body = scope.body
            local_defs: dict = {}
            instances: dict = {}  # local name -> ClassDef (ambiguous drop)
            regs: list = []
            for node in _walk_scope(body):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    local_defs.setdefault(node.name, []).append(node)
                elif isinstance(node, ast.Call):
                    if (
                        isinstance(node.func, ast.Attribute)
                        and node.func.attr == "add_round_tap"
                        and node.args
                    ):
                        regs.append(node.args[0])
                    for kw in node.keywords:
                        if kw.arg in _TAP_ATTRS:
                            regs.append(kw.value)
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if (
                            isinstance(t, ast.Attribute)
                            and t.attr in _TAP_ATTRS
                        ):
                            regs.append(node.value)
                    if (
                        len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Call)
                        and isinstance(node.value.func, ast.Name)
                        and node.value.func.id in classes
                    ):
                        nm = node.targets[0].id
                        cls = classes[node.value.func.id]
                        if nm in instances and instances[nm] is not cls:
                            instances[nm] = None  # ambiguous — skip
                        elif nm not in instances:
                            instances[nm] = cls
            for arg in regs:
                for fn_args, fn_body, skip_first in self._resolve(
                    arg, local_defs, defs, classes, instances
                ):
                    key = id(fn_body[0]) if fn_body else 0
                    if key in checked:
                        continue
                    checked.add(key)
                    params = _tainted_params(fn_args)
                    if skip_first and params:
                        params = params[1:]
                    findings.extend(self._check(pf, params, fn_body))
        return findings

    # -- resolution ---------------------------------------------------------
    def _resolve(self, arg, local_defs, defs, classes, instances) -> list:
        """Resolve a registration argument to [(arguments, body,
        skip_first)] callables; empty when not statically resolvable."""
        if isinstance(arg, ast.Lambda):
            return [(arg.args, [arg.body], False)]
        if isinstance(arg, ast.Name):
            cands = local_defs.get(arg.id) or defs.get(arg.id)
            if cands:
                return [(fn.args, fn.body, False) for fn in cands]
            cls = instances.get(arg.id)
            if cls is not None:
                return self._method(cls, "__call__", classes)
            return []
        if (  # direct ClassName(...) registration
            isinstance(arg, ast.Call)
            and isinstance(arg.func, ast.Name)
            and arg.func.id in classes
        ):
            return self._method(classes[arg.func.id], "__call__", classes)
        if (  # inst.method reference
            isinstance(arg, ast.Attribute)
            and isinstance(arg.value, ast.Name)
        ):
            cls = instances.get(arg.value.id)
            if cls is not None:
                return self._method(cls, arg.attr, classes)
        return []

    def _method(self, cls, name, classes, depth=0) -> list:
        for node in cls.body:
            if (
                isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name == name
            ):
                return [(node.args, node.body, True)]
        if depth < 2:  # one/two-level same-file base lookup
            for base in cls.bases:
                if isinstance(base, ast.Name) and base.id in classes:
                    got = self._method(
                        classes[base.id], name, classes, depth + 1
                    )
                    if got:
                        return got
        return []

    # -- the purity check ---------------------------------------------------
    def _check(self, pf: ParsedFile, params: list, body: list) -> list:
        taint = set(params)
        assigns = []  # (name, value) single-Name-target bindings
        for node in _walk_scope(body):
            tgt = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                tgt, val = node.target, node.value
            else:
                continue
            if isinstance(tgt, ast.Name):
                assigns.append((tgt.id, val))
        # Fixed point: aliases of tainted chains become tainted.
        while True:
            grew = False
            for nm, val in assigns:
                if nm not in taint and _chain_root(val) in taint:
                    taint.add(nm)
                    grew = True
            if not grew:
                break
        # Any binding to a non-tainted value (a copy, a fresh object)
        # un-taints the name — including a parameter rebound to a copy.
        taint -= {
            nm for nm, val in assigns if _chain_root(val) not in taint
        }
        if not taint:
            return []

        out: list = []
        for node in _walk_scope(body):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    self._flag_target(pf, t, taint, out, "writes into")
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    self._flag_target(pf, t, taint, out, "deletes from")
            elif isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute
            ):
                root = _chain_root(node.func.value)
                if root in taint and node.func.attr in _MUTATORS:
                    out.append(
                        Finding(
                            pf.path, node.lineno, "obs-tap-pure",
                            f"tap calls .{node.func.attr}() on a chain "
                            f"rooted at tap argument {root!r}; mutate a "
                            f"copy instead — the journal and goldens "
                            f"consume the same outcome/event objects",
                        )
                    )
        return out

    def _flag_target(self, pf, t, taint, out, verb) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._flag_target(pf, e, taint, out, verb)
            return
        if not isinstance(t, (ast.Attribute, ast.Subscript)):
            return  # rebinding a bare name never mutates the object
        root = _chain_root(t)
        if root in taint:
            out.append(
                Finding(
                    pf.path, t.lineno, "obs-tap-pure",
                    f"tap {verb} tap argument {root!r}; taps are "
                    f"read-only observers — the journal and goldens "
                    f"consume the same outcome/event objects after "
                    f"taps fire",
                )
            )

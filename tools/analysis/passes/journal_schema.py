"""Journal-schema pass: goldens and the recovery log cannot drift apart.

PR 8 promoted the golden-trace codec into ``core/journal.py`` so the
write-ahead journal and the goldens share ONE schema: every field the
``encode_*`` emitters write is compared during recovery by
``diff_entries`` (divergence is a hard ``RecoveryError``).  Two drift
modes survive review and every existing test:

``journal-field-unconsumed``
    A field emitted by an ``encode_*`` function that ``diff_entries``
    never compares.  The journal records it, recovery silently ignores
    it — a divergence in that field replays "bit-identically" while the
    actual state differs.  Add it to the ``diff_entries`` field tuple
    (and to goldens via re-record) or don't emit it.

``journal-version-drift``
    The emitted field set changed relative to the checked-in manifest
    (``tools/analysis/schema_manifest.json``) while
    ``TRACE_SCHEMA_VERSION`` did not.  Old goldens/journals would load
    under the same version but diff against entries with different
    shape.  Bump ``TRACE_SCHEMA_VERSION`` and refresh the manifest
    (``python -m tools.analysis --update-schema-manifest``) in the same
    change.

Scope: any module that defines both an ``encode_outcome`` function and a
``diff_entries`` function (i.e. ``core/journal.py`` and test fixtures).

Emitted fields = string keys of dict literals plus string-key subscript
stores (``entry["stall"] = ...``) inside ``encode_*`` functions.
Consumed fields = string constants in the iterable of ``for field in
(...)`` loops plus ``.get("f")``/``["f"]`` keys inside ``diff_entries``.
"""
from __future__ import annotations

import ast
import json
from pathlib import Path

from ..framework import AnalyzerConfig, Finding, LintPass, ParsedFile

__all__ = ["JournalSchemaPass", "default_manifest_path", "extract_schema"]


def default_manifest_path() -> Path:
    return Path(__file__).resolve().parent.parent / "schema_manifest.json"


def extract_schema(tree: ast.Module) -> dict:
    """(version, emitted fields w/ lines, consumed fields) from a module."""
    version = None
    version_line = 1
    emitted: dict = {}  # field -> first emit line
    consumed: set = set()
    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == "TRACE_SCHEMA_VERSION"
            and isinstance(node.value, ast.Constant)
        ):
            version = node.value.value
            version_line = node.lineno
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name.startswith("encode_"):
            for node in ast.walk(fn):
                if isinstance(node, ast.Dict):
                    for k in node.keys:
                        if isinstance(k, ast.Constant) and isinstance(
                            k.value, str
                        ):
                            emitted.setdefault(k.value, k.lineno)
                elif (
                    isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Subscript)
                    and isinstance(node.targets[0].slice, ast.Constant)
                    and isinstance(node.targets[0].slice.value, str)
                ):
                    emitted.setdefault(
                        node.targets[0].slice.value, node.lineno
                    )
        elif fn.name == "diff_entries":
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.AsyncFor)) and isinstance(
                    node.iter, (ast.Tuple, ast.List)
                ):
                    for elt in node.iter.elts:
                        if isinstance(elt, ast.Constant) and isinstance(
                            elt.value, str
                        ):
                            consumed.add(elt.value)
                elif isinstance(node, ast.Subscript) and isinstance(
                    node.slice, ast.Constant
                ):
                    if isinstance(node.slice.value, str):
                        consumed.add(node.slice.value)
                elif (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "get"
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)
                ):
                    consumed.add(node.args[0].value)
    return {
        "version": version,
        "version_line": version_line,
        "emitted": emitted,
        "consumed": consumed,
    }


class JournalSchemaPass(LintPass):
    name = "journal-schema"
    rules = {
        "journal-field-unconsumed": "journaled field never compared by "
        "diff_entries — divergence in it is invisible to recovery",
        "journal-version-drift": "journal field set changed without a "
        "TRACE_SCHEMA_VERSION bump",
    }

    def applies(self, pf: ParsedFile, config: AnalyzerConfig) -> bool:
        return (
            "def encode_outcome" in pf.source
            and "def diff_entries" in pf.source
        )

    def run(self, pf: ParsedFile, config: AnalyzerConfig) -> list:
        schema = extract_schema(pf.tree)
        findings: list = []
        for field, line in sorted(schema["emitted"].items()):
            if field not in schema["consumed"]:
                findings.append(
                    Finding(
                        pf.path, line, "journal-field-unconsumed",
                        f"encode_* emits {field!r} but diff_entries never "
                        f"compares it: recovery would ignore divergence in "
                        f"this field — add it to the diff field tuple or "
                        f"stop emitting it",
                    )
                )
        manifest_path = Path(
            config.schema_manifest or default_manifest_path()
        )
        if manifest_path.exists() and schema["version"] is not None:
            manifest = json.loads(manifest_path.read_text())
            man_fields = set(manifest.get("fields", []))
            cur_fields = set(schema["emitted"])
            if (
                cur_fields != man_fields
                and schema["version"] == manifest.get("version")
            ):
                added = sorted(cur_fields - man_fields)
                removed = sorted(man_fields - cur_fields)
                for field in added:
                    findings.append(
                        Finding(
                            pf.path, schema["emitted"][field],
                            "journal-version-drift",
                            f"field {field!r} added to the journal schema "
                            f"but TRACE_SCHEMA_VERSION is still "
                            f"{schema['version']}: old goldens/journals "
                            f"would replay against a different entry shape "
                            f"— bump the version and refresh the manifest",
                        )
                    )
                if removed:
                    findings.append(
                        Finding(
                            pf.path, schema["version_line"],
                            "journal-version-drift",
                            f"field(s) {', '.join(map(repr, removed))} "
                            f"removed from the journal schema but "
                            f"TRACE_SCHEMA_VERSION is still "
                            f"{schema['version']} — bump the version and "
                            f"refresh the manifest",
                        )
                    )
        return findings

    @staticmethod
    def write_manifest(journal_source_path, manifest_path=None) -> dict:
        """Regenerate the manifest from the journal module's current
        schema (used by --update-schema-manifest alongside a version
        bump)."""
        tree = ast.parse(Path(journal_source_path).read_text())
        schema = extract_schema(tree)
        doc = {
            "comment": (
                "Journal/golden trace field manifest: regenerate with "
                "--update-schema-manifest WHEN bumping "
                "TRACE_SCHEMA_VERSION (never to paper over drift)."
            ),
            "version": schema["version"],
            "fields": sorted(schema["emitted"]),
        }
        path = Path(manifest_path or default_manifest_path())
        path.write_text(json.dumps(doc, indent=1) + "\n")
        return doc

"""Lock-order pass: enforce the shard tier's documented lock hierarchy.

docs/sharding.md (normative as of this PR): the **steal lock is
outermost**; **shard locks nest inside it in ascending shard id order**;
nothing blocking (disk/device I/O, fsync, sleeps) runs while a shard lock
is held.  PR 7's ``ShardedCrossMatch`` follows this by construction
(victim choice under ``_steal_lock``, migration under
``with self._locks[lo], self._locks[hi]`` after ``lo, hi = sorted(...)``)
— but nothing *enforced* it, and an inverted pair deadlocks only under a
precise interleaving the tests may never hit.

Lock model (per class):

* ``self.<name> = threading.Lock()``                      -> scalar lock
* ``self.<name> = [threading.Lock() for ...]``            -> indexed family

A scalar lock whose name contains a fragment from
``AnalyzerConfig.steal_lock_names`` ranks *outermost* (level 0); indexed
families rank level 1, ordered by index.  Rules:

``lock-order-inversion``
    Acquiring a level-0 lock while holding a level-1 lock, or acquiring
    two locks of one family without static proof the indices ascend.
    Accepted proofs: integer-constant indices in ascending order, or
    index names bound by an ``a, b = sorted((x, y))`` unpacking (rank =
    tuple position) acquired in rank order.

``lock-bare-acquire``
    ``.acquire()`` on a recognized lock outside a ``with`` and without an
    immediately following ``try/finally`` that releases it — an exception
    between acquire and release leaks the lock and wedges every sibling
    shard.

``lock-blocking-io``
    A blocking call (``os.fsync``, ``time.sleep``, ``<store>.read``) made
    while a shard (level-1) lock is held: shard locks serialize the
    dispatch hot path, so I/O under one stalls stealing and sibling
    rounds.
"""
from __future__ import annotations

import ast
from typing import Optional

from ..framework import AnalyzerConfig, Finding, LintPass, ParsedFile

__all__ = ["LockOrderPass"]


def _is_lock_ctor(node: ast.AST) -> bool:
    """threading.Lock() / threading.RLock() / Lock()."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", "")
    return name in ("Lock", "RLock")


class _Acq:
    """One lock acquisition: family + (optional) index expression."""

    def __init__(self, family: str, level: int, index: Optional[ast.AST],
                 lineno: int) -> None:
        self.family = family
        self.level = level
        self.index = index
        self.lineno = lineno

    def describe(self) -> str:
        if self.index is None:
            return f"self.{self.family}"
        return f"self.{self.family}[{ast.unparse(self.index)}]"


class LockOrderPass(LintPass):
    name = "lock-order"
    rules = {
        "lock-order-inversion": "nested acquisition violates the hierarchy "
        "(steal lock outermost, shard locks ascending by id)",
        "lock-bare-acquire": "acquire() without with/try-finally leaks the "
        "lock on an exception path",
        "lock-blocking-io": "blocking I/O while holding a shard lock stalls "
        "sibling shards",
    }

    def applies(self, pf: ParsedFile, config: AnalyzerConfig) -> bool:
        return "threading" in pf.source or "Lock(" in pf.source

    def run(self, pf: ParsedFile, config: AnalyzerConfig) -> list:
        findings: list = []
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(_ClassLockAnalysis(pf, node, config).run())
        return findings


class _ClassLockAnalysis:
    def __init__(self, pf: ParsedFile, cls: ast.ClassDef,
                 config: AnalyzerConfig) -> None:
        self.pf = pf
        self.cls = cls
        self.config = config
        # family name -> level (0 = outermost scalar steal lock,
        # 1 = indexed shard family or plain scalar lock)
        self.locks: dict = {}
        self._discover()

    def _discover(self) -> None:
        for node in ast.walk(self.cls):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                continue
            val = node.value
            if _is_lock_ctor(val):
                is_steal = any(
                    frag in tgt.attr for frag in self.config.steal_lock_names
                )
                self.locks[tgt.attr] = 0 if is_steal else 1
            elif isinstance(val, (ast.List, ast.ListComp)):
                elts = (
                    [val.elt] if isinstance(val, ast.ListComp) else val.elts
                )
                if elts and all(_is_lock_ctor(e) for e in elts):
                    self.locks[tgt.attr] = 1

    # -- per-function analysis ------------------------------------------------
    def run(self) -> list:
        if not self.locks:
            return []
        out: list = []
        for node in self.cls.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(self._analyze_function(node))
        return out

    def _lock_expr(self, expr: ast.AST) -> Optional[_Acq]:
        """Recognize self.<fam> / self.<fam>[i] where <fam> is a lock."""
        index = None
        node = expr
        if isinstance(node, ast.Subscript):
            index = node.slice
            node = node.value
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in self.locks
        ):
            return _Acq(node.attr, self.locks[node.attr], index, expr.lineno)
        return None

    def _analyze_function(self, fn) -> list:
        out: list = []
        # names ranked by a `lo, hi = sorted(...)` unpack: name -> rank
        sorted_ranks: dict = {}
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Tuple)
                and isinstance(node.value, ast.Call)
                and isinstance(node.value.func, ast.Name)
                and node.value.func.id == "sorted"
            ):
                for rank, elt in enumerate(node.targets[0].elts):
                    if isinstance(elt, ast.Name):
                        sorted_ranks[elt.id] = rank
        self._walk(fn.body, held=[], sorted_ranks=sorted_ranks, out=out)
        return out

    def _walk(self, body, held: list, sorted_ranks: dict, out: list) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                acquired = []
                for item in stmt.items:
                    acq = self._lock_expr(item.context_expr)
                    if acq is not None:
                        self._check_order(held + acquired, acq, sorted_ranks,
                                          out)
                        acquired.append(acq)
                self._walk(stmt.body, held + acquired, sorted_ranks, out)
                continue
            sub_bodies = [
                getattr(stmt, f)
                for f in ("body", "orelse", "finalbody")
                if getattr(stmt, f, None)
            ] + [h.body for h in getattr(stmt, "handlers", []) or []]
            if sub_bodies:
                # Compound statement: only recurse — its leaf statements
                # are scanned at their own nesting level.
                for sub in sub_bodies:
                    self._walk(sub, held, sorted_ranks, out)
                continue
            # Simple statement: scan for bare acquire() and blocking I/O.
            for node in ast.walk(stmt):
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "acquire"
                ):
                    acq = self._lock_expr(node.func.value)
                    if acq is not None and not self._released_in_finally(
                        stmt, body, acq
                    ):
                        out.append(
                            Finding(
                                self.pf.path, node.lineno,
                                "lock-bare-acquire",
                                f"{acq.describe()}.acquire() outside "
                                f"with/try-finally: an exception before "
                                f"release() wedges every thread waiting on "
                                f"it — use a with block",
                            )
                        )
            if held and max(h.level for h in held) >= 1:
                self._check_blocking(stmt, held, out)

    def _released_in_finally(self, stmt, body, acq: _Acq) -> bool:
        """Accept `l.acquire()` immediately followed by try/finally that
        calls `l.release()` in its finalbody."""
        try:
            i = body.index(stmt)
        except ValueError:
            return False
        if i + 1 >= len(body) or not isinstance(body[i + 1], ast.Try):
            return False
        for node in ast.walk(ast.Module(body=body[i + 1].finalbody,
                                        type_ignores=[])):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "release"
            ):
                rel = self._lock_expr(node.func.value)
                if rel is not None and rel.family == acq.family:
                    return True
        return False

    def _check_order(self, held: list, acq: _Acq, sorted_ranks: dict,
                     out: list) -> None:
        for h in held:
            if acq.level < h.level:
                out.append(
                    Finding(
                        self.pf.path, acq.lineno, "lock-order-inversion",
                        f"acquiring outer-level {acq.describe()} while "
                        f"holding {h.describe()}: the steal lock is "
                        f"outermost in the documented hierarchy "
                        f"(docs/sharding.md) — take it first or not at all",
                    )
                )
            elif (
                acq.level == h.level
                and acq.family == h.family
                and acq.index is not None
                and h.index is not None
                and not self._provably_ascending(h.index, acq.index,
                                                sorted_ranks)
            ):
                out.append(
                    Finding(
                        self.pf.path, acq.lineno, "lock-order-inversion",
                        f"acquiring {acq.describe()} while holding "
                        f"{h.describe()}: cannot prove ascending index "
                        f"order — bind `lo, hi = sorted((a, b))` and "
                        f"acquire [lo] then [hi]",
                    )
                )

    @staticmethod
    def _provably_ascending(first: ast.AST, second: ast.AST,
                            sorted_ranks: dict) -> bool:
        if (
            isinstance(first, ast.Constant)
            and isinstance(second, ast.Constant)
            and isinstance(first.value, int)
            and isinstance(second.value, int)
        ):
            return first.value < second.value
        if (
            isinstance(first, ast.Name)
            and isinstance(second, ast.Name)
            and first.id in sorted_ranks
            and second.id in sorted_ranks
        ):
            return sorted_ranks[first.id] < sorted_ranks[second.id]
        return False

    def _check_blocking(self, stmt, held: list, out: list) -> None:
        shard = next(h for h in held if h.level >= 1)
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            desc = self._blocking_desc(node)
            if desc:
                out.append(
                    Finding(
                        self.pf.path, node.lineno, "lock-blocking-io",
                        f"{desc} while holding {shard.describe()}: shard "
                        f"locks serialize the dispatch hot path — do the "
                        f"I/O outside the lock and publish under it",
                    )
                )

    def _blocking_desc(self, call: ast.Call) -> Optional[str]:
        f = call.func
        parts: list = []
        node = f
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
        parts.reverse()
        dotted = ".".join(parts)
        for blk in self.config.blocking_calls:
            if dotted == blk or dotted.endswith("." + blk):
                return f"{dotted}()"
        # <...store...>.read(...): catalog/disk reads
        if (
            parts
            and parts[-1] == "read"
            and any(
                root in p
                for p in parts[:-1]
                for root in self.config.blocking_read_roots
            )
        ):
            return f"{dotted}()"
        return None

"""Determinism pass: decision paths must be bit-replayable.

PR 8 made journal replay a correctness requirement — recovery re-executes
every journaled round and diffs it against the record, and *any*
divergence is a hard ``RecoveryError``.  Goldens (tests/golden/) enforce
the same property across refactors.  Three classes of nondeterminism can
silently break that contract inside the decision-path modules
(``AnalyzerConfig.decision_paths``):

``det-wallclock``
    Wall-clock reads (``time.time``, argless ``datetime.now``,
    ``utcnow``/``today``).  A replayed process observes a different clock
    and derives different decisions.  PR 8 already fixed one of these
    (``launch/dryrun.py`` timing on ``time.time``); ``perf_counter`` /
    ``monotonic`` are allowed — they never feed decision state here and
    flagging them would only breed waivers.

``det-rng``
    Unseeded randomness: the ``random`` module's global generator,
    legacy ``np.random.*`` global-state calls, and ``default_rng()`` /
    ``SeedSequence()`` with no seed argument.  Seeded construction
    (``default_rng(seed)``, ``jax.random.PRNGKey(s)``) is fine.

``det-set-order``
    Iterating a set of strings — or letting one escape into a callee
    that iterates it — salts the order by ``PYTHONHASHSEED``.  The pass
    tracks names bound to set displays/comprehensions/``set(...)`` per
    function scope and flags (a) direct iteration (``for``/comprehension
    generators) and (b) passing the set as a call argument to anything
    that isn't order-insensitive (``sorted``/``len``/``min``/``max``/
    ``sum``/``any``/``all``/``set``/``frozenset``).  Membership tests,
    set algebra, and ``.add``/``.discard`` mutation are untouched.
    Element types are unknown statically, so int-element sets (whose
    CPython order is not hash-salted) get flagged too — waive those
    with a reason, or just sort them if order is immaterial.
"""
from __future__ import annotations

import ast

from ..framework import AnalyzerConfig, Finding, LintPass, ParsedFile

__all__ = ["DeterminismPass"]

# Callees that consume an iterable without exposing its order.
_ORDER_INSENSITIVE_CALLEES = {
    "sorted", "len", "min", "max", "sum", "any", "all", "set", "frozenset",
    "bool", "isinstance", "id", "iter",  # iter() alone exposes nothing yet
}

_WALLCLOCK_ATTRS = {
    ("time", "time"),
    ("time", "time_ns"),
    ("datetime", "utcnow"),
    ("datetime", "today"),
    ("date", "today"),
}

# np.random legacy global-state functions are all nondeterministic unless
# the process seeds them — and seeding global state is itself a hazard.
_SEEDED_RNG_CTORS = {"default_rng", "SeedSequence", "Generator", "PRNGKey"}


def _scoped_walk(body):
    """Walk statements without descending into nested function scopes
    (those are analyzed as their own ``_SetOrderScope``)."""
    stack = list(body)
    while stack:
        node = stack.pop()
        if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _attr_chain(node: ast.AST) -> list:
    """['np', 'random', 'default_rng'] for np.random.default_rng — [] if
    the expression isn't a plain name/attribute chain."""
    parts: list = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


class DeterminismPass(LintPass):
    name = "determinism"
    rules = {
        "det-wallclock": "wall-clock read in a decision path breaks replay",
        "det-rng": "unseeded RNG in a decision path breaks replay",
        "det-set-order": "set iteration order is PYTHONHASHSEED-salted",
    }

    def applies(self, pf: ParsedFile, config: AnalyzerConfig) -> bool:
        return config.is_decision_path(pf.path)

    def run(self, pf: ParsedFile, config: AnalyzerConfig) -> list:
        findings: list = []
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Call):
                findings.extend(self._check_call(pf, node))
        # Set-order tracking needs scope, not a flat walk: analyze each
        # function body (and the module body) as one scope.
        scopes = [pf.tree] + [
            n
            for n in ast.walk(pf.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        for scope in scopes:
            findings.extend(_SetOrderScope(pf, scope).findings())
        return findings

    # -- wall clock / rng ---------------------------------------------------
    def _check_call(self, pf: ParsedFile, call: ast.Call) -> list:
        chain = _attr_chain(call.func)
        if not chain:
            return []
        out: list = []
        tail2 = tuple(chain[-2:])
        if tail2 in _WALLCLOCK_ATTRS:
            out.append(
                Finding(
                    pf.path, call.lineno, "det-wallclock",
                    f"{'.'.join(chain)}() reads the wall clock; replay "
                    f"re-derives decisions in a different process — use a "
                    f"logical/sim clock (or perf_counter for pure timing)",
                )
            )
        elif chain[-1] == "now" and tail2[0] in ("datetime", "dt"):
            # datetime.now() with no tz argument is wall-clock local time;
            # datetime.now(tz=utc) is *also* wall-clock — flag both.
            out.append(
                Finding(
                    pf.path, call.lineno, "det-wallclock",
                    f"{'.'.join(chain)}() reads the wall clock; decisions "
                    f"must derive from the journaled/sim clock",
                )
            )
        if "random" in chain[:-1] and chain[0] != "jax":
            # random.x(...), np.random.x(...), numpy.random.x(...).
            # jax.random is exempt: purely functional, key-threaded.
            fn = chain[-1]
            seeded = fn in _SEEDED_RNG_CTORS and call.args
            if not seeded:
                out.append(
                    Finding(
                        pf.path, call.lineno, "det-rng",
                        f"{'.'.join(chain)}() draws from "
                        f"{'an unseeded generator' if fn in _SEEDED_RNG_CTORS else 'global RNG state'}"
                        f"; decision paths must thread an explicitly "
                        f"seeded Generator",
                    )
                )
        return out


class _SetOrderScope:
    """Track set-bound locals in one scope; flag order-exposing uses."""

    def __init__(self, pf: ParsedFile, scope: ast.AST) -> None:
        self.pf = pf
        self.out: list = []
        self.set_names: set = set()
        body = scope.body if hasattr(scope, "body") else []
        # First sweep: which locals are bound to set expressions anywhere
        # in this scope (a name rebound to a non-set anywhere is dropped —
        # conservative in the don't-flag direction).
        rebound_nonset: set = set()
        for node in _scoped_walk(body):
            tgt = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                tgt, val = node.target, node.value
            else:
                continue
            if isinstance(tgt, ast.Name):
                if self._is_set_expr(val):
                    self.set_names.add(tgt.id)
                else:
                    rebound_nonset.add(tgt.id)
        self.set_names -= rebound_nonset
        self.body = body

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        return (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset")
        )

    def _is_tracked_set(self, node: ast.AST) -> bool:
        if self._is_set_expr(node):
            return True
        return isinstance(node, ast.Name) and node.id in self.set_names

    def findings(self) -> list:
        for node in _scoped_walk(self.body):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                self._check_iter(node.iter)
            elif isinstance(
                node, (ast.ListComp, ast.SetComp, ast.DictComp,
                       ast.GeneratorExp)
            ):
                for gen in node.generators:
                    self._check_iter(gen.iter)
            elif isinstance(node, ast.Call):
                self._check_escape(node)
        return self.out

    def _check_iter(self, it: ast.AST) -> None:
        if self._is_tracked_set(it):
            label = (
                it.id if isinstance(it, ast.Name) else "a set expression"
            )
            self.out.append(
                Finding(
                    self.pf.path, it.lineno, "det-set-order",
                    f"iteration over set {label!r}: order is salted by "
                    f"PYTHONHASHSEED for str elements — iterate "
                    f"sorted({label if isinstance(it, ast.Name) else '...'})"
                    f" (or waive if elements are ints)",
                )
            )

    def _check_escape(self, call: ast.Call) -> None:
        callee = ""
        if isinstance(call.func, ast.Name):
            callee = call.func.id
        elif isinstance(call.func, ast.Attribute):
            callee = call.func.attr
        if callee in _ORDER_INSENSITIVE_CALLEES:
            return
        # A method called *on* the tracked set (s.add/.discard/.union) is
        # not an escape; the set appearing as an *argument* is.  A fresh
        # empty set() passed inline (e.g. a setdefault default) has no
        # order to leak.
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if (
                isinstance(arg, ast.Call)
                and isinstance(arg.func, ast.Name)
                and arg.func.id in ("set", "frozenset")
                and not arg.args
            ):
                continue
            if self._is_tracked_set(arg):
                label = arg.id if isinstance(arg, ast.Name) else "set expr"
                self.out.append(
                    Finding(
                        self.pf.path, arg.lineno, "det-set-order",
                        f"set {label!r} passed to {callee or 'a call'}(): "
                        f"its iteration order escapes unsorted — pass "
                        f"sorted(...) so downstream iteration is "
                        f"hash-seed-independent",
                    )
                )

"""Tracing-safety pass: jit/pallas-reachable code must stay traceable.

The fused/shared kernels are compiled once per pow2 shape pair — that
compile bound is the PR 1 invariant ``jit_cache_size()`` gates
*dynamically* in benchmarks.  This pass makes the underlying hygiene
*static*.  Roots are:

* functions decorated ``@jax.jit`` or
  ``@functools.partial(jax.jit, static_argnames=(...))`` — parameters
  not named in ``static_argnames`` are **traced**;
* kernel bodies handed to ``pl.pallas_call`` (directly or via
  ``functools.partial(kernel, **static_kwargs)``) — positional
  parameters are traced Refs, keyword-only/partial-bound parameters are
  static.

Taint propagates through assignments, arithmetic, and module-local
calls (each call site re-analyzes the callee under the actual argument
taints, memoized).  ``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``len(x)``
*clear* taint: shape math is static under jit and is exactly how the
pow2 wrappers are supposed to branch.  Results of ``jnp.* / jax.* /
pl.*`` calls are tainted (tracers) regardless of inputs.

``trace-py-branch``
    ``if``/``while``/ternary/``assert`` on a traced value: under jit
    this raises ``TracerBoolConversionError`` at best, and at worst (in
    shape-dependent helper code) silently bakes one branch into the
    compiled artifact.

``trace-concretize``
    ``float()``/``int()``/``bool()``/``.item()``/``.tolist()`` on a
    traced value — forces a device sync or a trace error.

``trace-shape-pow2``
    ``jnp.pad``/``np.pad`` inside jit-reachable code whose enclosing
    function is not a designated pow2/block helper
    (``AnalyzerConfig.pow2_helpers``) and whose arguments reference no
    such helper: ad-hoc padding mints arbitrary shapes, and every novel
    shape is a fresh XLA compile — the O(log M) compile bound only
    holds if all shape-changing pads route through the helpers.
"""
from __future__ import annotations

import ast
from typing import Optional

from ..framework import AnalyzerConfig, Finding, LintPass, ParsedFile

__all__ = ["TracingPass"]

_TAINT_ROOT_MODULES = {"jnp", "jax", "pl", "lax"}
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_CONCRETIZERS = {"float", "int", "bool", "complex"}
_CONCRETIZE_METHODS = {"item", "tolist", "__bool__", "__float__"}


def _decorator_jit_statics(dec: ast.AST) -> Optional[set]:
    """If ``dec`` is jax.jit / functools.partial(jax.jit, ...), return the
    set of static_argnames (empty set when none); else None."""
    def is_jax_jit(node):
        return (
            isinstance(node, ast.Attribute)
            and node.attr == "jit"
            and isinstance(node.value, ast.Name)
            and node.value.id == "jax"
        ) or (isinstance(node, ast.Name) and node.id == "jit")

    if is_jax_jit(dec):
        return set()
    if isinstance(dec, ast.Call):
        f = dec.func
        is_partial = (
            isinstance(f, ast.Attribute) and f.attr == "partial"
        ) or (isinstance(f, ast.Name) and getattr(f, "id", "") == "partial")
        if is_partial and dec.args and is_jax_jit(dec.args[0]):
            statics: set = set()
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    for node in ast.walk(kw.value):
                        if isinstance(node, ast.Constant) and isinstance(
                            node.value, str
                        ):
                            statics.add(node.value)
            return statics
        if is_jax_jit(f):  # @jax.jit(donate_argnums=...) style
            statics = set()
            for kw in dec.keywords:
                if kw.arg in ("static_argnames",):
                    for node in ast.walk(kw.value):
                        if isinstance(node, ast.Constant) and isinstance(
                            node.value, str
                        ):
                            statics.add(node.value)
            return statics
    return None


class TracingPass(LintPass):
    name = "tracing"
    rules = {
        "trace-py-branch": "Python control flow on a traced value",
        "trace-concretize": "host concretization of a traced value",
        "trace-shape-pow2": "ad-hoc padding bypasses the pow2 bucketing "
        "helpers, unbounding the jit compile count",
    }

    def applies(self, pf: ParsedFile, config: AnalyzerConfig) -> bool:
        return "jax" in pf.source or "pallas" in pf.source

    def run(self, pf: ParsedFile, config: AnalyzerConfig) -> list:
        functions = {
            n.name: n
            for n in ast.walk(pf.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        roots = self._find_roots(pf, functions)
        if not roots:
            return []
        analyzer = _TaintAnalyzer(pf, functions, config)
        for fn, traced_params in roots:
            analyzer.analyze(fn, traced_params)
        return analyzer.findings

    # -- root discovery -------------------------------------------------------
    def _find_roots(self, pf: ParsedFile, functions: dict) -> list:
        roots: list = []
        for fn in functions.values():
            for dec in fn.decorator_list:
                statics = _decorator_jit_statics(dec)
                if statics is not None:
                    traced = {
                        a.arg
                        for a in list(fn.args.args)
                        + list(fn.args.posonlyargs)
                        if a.arg not in statics
                    }
                    roots.append((fn, traced))
                    break
        # Local aliases: `kern = functools.partial(_kernel, **static)` —
        # record which module-level functions each local name references,
        # so `pallas_call(kern, ...)` resolves to `_kernel`.
        aliases: dict = {}
        for node in ast.walk(pf.tree):
            if (
                isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
            ):
                referenced = [
                    n.id
                    for n in ast.walk(node.value)
                    if isinstance(n, ast.Name) and n.id in functions
                ]
                if referenced:
                    aliases[node.targets[0].id] = referenced
        # pallas_call kernels: pallas_call(kern, ...) or
        # pallas_call(functools.partial(kern, **static), ...)
        for node in ast.walk(pf.tree):
            if not (isinstance(node, ast.Call)):
                continue
            f = node.func
            callee = f.attr if isinstance(f, ast.Attribute) else getattr(
                f, "id", ""
            )
            if callee != "pallas_call" or not node.args:
                continue
            kern_names: list = []
            for kname in self._kernel_names(node.args[0]):
                if kname in functions:
                    kern_names.append(kname)
                kern_names.extend(aliases.get(kname, []))
            for kname in kern_names:
                fn = functions.get(kname)
                if fn is None:
                    continue
                # positional params = traced Refs; kwonly = static
                traced = {
                    a.arg
                    for a in list(fn.args.args) + list(fn.args.posonlyargs)
                }
                roots.append((fn, traced))
        return roots

    @staticmethod
    def _kernel_names(arg: ast.AST) -> list:
        """Kernel function names referenced by pallas_call's first arg,
        following one level of local Name indirection is not attempted —
        `kern = functools.partial(_kernel, ...)` assigns are resolved by
        scanning the module for partial() binds of known functions."""
        names: list = []
        if isinstance(arg, ast.Name):
            names.append(arg.id)
        elif isinstance(arg, ast.Call):
            for sub in ast.walk(arg):
                if isinstance(sub, ast.Name):
                    names.append(sub.id)
        return names


class _TaintAnalyzer:
    """Per-function forward taint propagation with callsite-sensitive
    descent into module-local callees (memoized on taint signature)."""

    MAX_DEPTH = 6

    def __init__(self, pf: ParsedFile, functions: dict,
                 config: AnalyzerConfig) -> None:
        self.pf = pf
        self.functions = functions
        self.config = config
        self.findings: list = []
        self._seen: set = set()  # (fn-name, frozenset(traced)) memo
        self._emitted: set = set()  # dedupe identical findings

    def analyze(self, fn, traced_params: set, depth: int = 0) -> None:
        key = (fn.name, frozenset(traced_params))
        if key in self._seen or depth > self.MAX_DEPTH:
            return
        self._seen.add(key)
        # kernels resolved via functools.partial: kwonly args bound in the
        # partial are static, so drop them from the traced set.
        kwonly = {a.arg for a in fn.args.kwonlyargs}
        tainted = set(traced_params) - kwonly
        _FunctionTaint(self, fn, tainted, depth).run()

    def emit(self, line: int, rule: str, message: str) -> None:
        f = Finding(self.pf.path, line, rule, message)
        if (line, rule, message) not in self._emitted:
            self._emitted.add((line, rule, message))
            self.findings.append(f)


class _FunctionTaint:
    def __init__(self, analyzer: _TaintAnalyzer, fn, tainted: set,
                 depth: int) -> None:
        self.a = analyzer
        self.fn = fn
        self.tainted = set(tainted)
        self.depth = depth
        self.is_pow2_helper = fn.name in analyzer.config.pow2_helpers

    # -- expression taint -----------------------------------------------------
    def expr_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            # x.shape / x.ndim / x.dtype clear taint: static under trace.
            if node.attr in _SHAPE_ATTRS:
                return False
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value) or self.expr_tainted(
                node.slice
            )
        if isinstance(node, ast.Call):
            return self.call_tainted(node)
        if isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        return any(
            self.expr_tainted(c) for c in ast.iter_child_nodes(node)
        )

    def call_tainted(self, call: ast.Call) -> bool:
        f = call.func
        # len(x), int(x.shape[0]) etc: taint-clearing when used on shapes,
        # but int(traced) is concretization, handled in visit.
        if isinstance(f, ast.Name) and f.id == "len":
            return False
        chain_root = f
        while isinstance(chain_root, ast.Attribute):
            chain_root = chain_root.value
        if (
            isinstance(chain_root, ast.Name)
            and chain_root.id in _TAINT_ROOT_MODULES
        ):
            return True  # jnp/jax/pl results are tracers inside jit
        args_tainted = any(self.expr_tainted(a) for a in call.args) or any(
            self.expr_tainted(kw.value) for kw in call.keywords
        )
        return args_tainted

    # -- driver ---------------------------------------------------------------
    def run(self) -> None:
        self.visit_body(self.fn.body)

    def visit_body(self, body) -> None:
        for stmt in body:
            self.visit_stmt(stmt)

    def visit_stmt(self, stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested defs (pallas @pl.when closures) share the enclosing
            # taint environment.
            self.visit_body(stmt.body)
            return
        if isinstance(stmt, ast.Assign):
            t = self.expr_tainted(stmt.value)
            for tgt in stmt.targets:
                self.bind_target(tgt, t)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.bind_target(stmt.target, self.expr_tainted(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            t = self.expr_tainted(stmt.value) or self.expr_tainted(
                stmt.target
            )
            self.bind_target(stmt.target, t)
        elif isinstance(stmt, (ast.If, ast.While)):
            self.check_branch(stmt.test)
        elif isinstance(stmt, ast.Assert):
            self.check_branch(stmt.test, kind="assert")
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            if self.expr_tainted(stmt.iter):
                self.a.emit(
                    stmt.iter.lineno, "trace-py-branch",
                    f"in `{self.fn.name}`: Python for-loop over a traced "
                    f"value — use lax.fori_loop/scan or static shapes",
                )
        for node in ast.walk(stmt):
            if isinstance(node, ast.IfExp):
                self.check_branch(node.test, kind="ternary")
            elif isinstance(node, ast.Call):
                self.check_call(node)
        # recurse into compound bodies with the updated environment
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub and not isinstance(
                stmt, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                self.visit_body(sub)

    def bind_target(self, tgt, tainted: bool) -> None:
        if isinstance(tgt, ast.Name):
            if tainted:
                self.tainted.add(tgt.id)
            else:
                self.tainted.discard(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self.bind_target(elt, tainted)

    def check_branch(self, test: ast.AST, kind: str = "branch") -> None:
        if self.expr_tainted(test):
            self.a.emit(
                test.lineno, "trace-py-branch",
                f"in `{self.fn.name}`: Python {kind} on a traced value "
                f"(`{ast.unparse(test)}`) — jit traces one path only; use "
                f"jnp.where/lax.cond or mark the argument static",
            )

    def check_call(self, call: ast.Call) -> None:
        f = call.func
        # float()/int()/bool() on traced
        if (
            isinstance(f, ast.Name)
            and f.id in _CONCRETIZERS
            and call.args
            and self.expr_tainted(call.args[0])
        ):
            self.a.emit(
                call.lineno, "trace-concretize",
                f"in `{self.fn.name}`: {f.id}() on a traced value forces "
                f"host concretization — keep it on-device "
                f"(jnp ops) or mark the argument static",
            )
        if (
            isinstance(f, ast.Attribute)
            and f.attr in _CONCRETIZE_METHODS
            and self.expr_tainted(f.value)
        ):
            self.a.emit(
                call.lineno, "trace-concretize",
                f"in `{self.fn.name}`: .{f.attr}() on a traced value "
                f"forces host concretization",
            )
        # jnp.pad / np.pad outside the pow2 helpers
        if (
            isinstance(f, ast.Attribute)
            and f.attr == "pad"
            and isinstance(f.value, ast.Name)
            and f.value.id in ("jnp", "np", "numpy")
            and not self.is_pow2_helper
            and not self._mentions_pow2_helper(call)
        ):
            self.a.emit(
                call.lineno, "trace-shape-pow2",
                f"in `{self.fn.name}`: {f.value.id}.pad() outside the pow2 "
                f"bucketing helpers mints ad-hoc shapes — every novel "
                f"shape is a fresh jit compile; route through "
                f"{'/'.join(self.a.config.pow2_helpers[:2])}",
            )
        # descend into module-local callees with actual taints
        if isinstance(f, ast.Name) and f.id in self.a.functions:
            callee = self.a.functions[f.id]
            params = list(callee.args.posonlyargs) + list(callee.args.args)
            traced: set = set()
            for i, arg in enumerate(call.args):
                if i < len(params) and self.expr_tainted(arg):
                    traced.add(params[i].arg)
            for kw in call.keywords:
                if kw.arg and self.expr_tainted(kw.value):
                    traced.add(kw.arg)
            self.a.analyze(callee, traced, self.depth + 1)

    def _mentions_pow2_helper(self, call: ast.Call) -> bool:
        for node in ast.walk(call):
            if isinstance(node, ast.Name) and (
                node.id in self.a.config.pow2_helpers
            ):
                return True
        return False

"""Pass registry for liferaft-lint.

Adding a pass: subclass ``LintPass`` in a new module here, declare
``name`` + ``rules`` (rule-id -> rationale), implement
``applies``/``run``, and append an instance to ``ALL_PASSES``.  See
docs/static-analysis.md for the full checklist (fixtures + docs).
"""
from __future__ import annotations

from .determinism import DeterminismPass
from .journal_schema import JournalSchemaPass
from .lockorder import LockOrderPass
from .obs_tap import ObsTapPurityPass
from .tracing import TracingPass

__all__ = [
    "ALL_PASSES",
    "DeterminismPass",
    "LockOrderPass",
    "TracingPass",
    "JournalSchemaPass",
    "ObsTapPurityPass",
    "rule_catalog",
]

ALL_PASSES = (
    DeterminismPass(),
    LockOrderPass(),
    TracingPass(),
    JournalSchemaPass(),
    ObsTapPurityPass(),
)


def rule_catalog() -> dict:
    """rule-id -> (pass name, rationale), plus the framework's own rules."""
    cat = {
        "lint-bad-waiver": ("framework", "waiver without a written reason"),
        "lint-syntax-error": ("framework", "file does not parse"),
    }
    for p in ALL_PASSES:
        for rule, why in p.rules.items():
            cat[rule] = (p.name, why)
    return cat

"""liferaft-lint: AST-based invariant analysis for the LifeRaft repo.

Usage: ``python -m tools.analysis src/ tests/ [--baseline B]`` — see
docs/static-analysis.md for the rule catalog and workflow.
"""
from __future__ import annotations

from .framework import (
    AnalyzerConfig,
    Baseline,
    Finding,
    LintPass,
    ParsedFile,
    analyze_paths,
    parse_file,
    run_passes,
)
from .passes import ALL_PASSES, rule_catalog

__all__ = [
    "AnalyzerConfig",
    "Baseline",
    "Finding",
    "LintPass",
    "ParsedFile",
    "ALL_PASSES",
    "analyze_paths",
    "parse_file",
    "run_passes",
    "rule_catalog",
]

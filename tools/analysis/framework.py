"""liferaft-lint core: findings, waivers, baseline, pass registry, driver.

The analyzer enforces invariants that the test suite can only observe
*after* they corrupt a trace: journal replay determinism (PR 8 makes
divergence a hard ``RecoveryError``), the shard-tier lock hierarchy
(docs/sharding.md), tracing safety inside jit/pallas-reachable code, and
journal schema/version lockstep.  Each invariant is one *pass*; a pass
walks a parsed file's AST and returns :class:`Finding` objects.

Reporting protocol
------------------
* Findings print as ``file:line rule-id message`` and sort stably.
* A finding on line L is suppressed by an inline waiver on that line::

      expr_that_trips_rule()  # lint: allow[rule-id] why this is safe

  The reason text is mandatory — a reasonless waiver is itself a finding
  (``lint-bad-waiver``) and does *not* suppress.  Multiple rules may be
  waived with ``allow[rule-a,rule-b]``.
* A checked-in *baseline* (JSON fingerprint->count) grandfathers old
  findings: only findings beyond the baselined count for their
  fingerprint are "new" and fail the run.  Fingerprints exclude line
  numbers so unrelated edits don't churn the file.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional

__all__ = [
    "Finding",
    "ParsedFile",
    "LintPass",
    "AnalyzerConfig",
    "Baseline",
    "collect_files",
    "parse_file",
    "run_passes",
    "analyze_paths",
]

# Directories never descended into.  ``lint_fixtures`` holds deliberately
# broken snippets for tests/test_static_analysis.py — they are analyzed
# explicitly by the tests, never by a tree walk.
EXCLUDED_DIRS = {"__pycache__", ".git", "lint_fixtures", ".pytest_cache"}

_WAIVER_RE = re.compile(
    r"#\s*lint:\s*allow\[([A-Za-z0-9_,\- ]+)\]\s*(.*?)\s*$"
)


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""

    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    def fingerprint(self) -> str:
        # Line numbers excluded: a baseline entry survives unrelated edits
        # above the finding.  Message included so distinct defects on one
        # rule don't mask each other.
        return f"{self.path}::{self.rule}::{self.message}"


@dataclass
class Waiver:
    line: int
    rules: tuple
    reason: str


@dataclass
class ParsedFile:
    """A source file plus its AST and inline waivers."""

    path: str  # repo-relative posix path (stable across machines)
    abspath: str
    source: str
    tree: ast.Module
    waivers: dict = field(default_factory=dict)  # line -> Waiver

    @property
    def lines(self) -> list:
        return self.source.splitlines()


@dataclass
class AnalyzerConfig:
    """Knobs shared by the passes.

    ``decision_paths``: path fragments (posix) naming the decision-path
    modules the determinism pass guards — everything journal replay
    re-derives must be bit-stable there.  ``pow2_helpers``: functions that
    are *allowed* to build padded shapes (everything else inside
    jit-reachable code must route through them).  ``schema_manifest``:
    the checked-in record of the journal field set at the current
    ``TRACE_SCHEMA_VERSION``.
    """

    decision_paths: tuple = (
        "src/repro/core/",
        "src/repro/serving/",
        "src/repro/crossmatch/engine.py",
    )
    pow2_helpers: tuple = ("_pow2_ceil", "pow2_ceil", "_pad_rows", "pad_rows")
    steal_lock_names: tuple = ("steal",)  # scalar locks matching = outermost
    blocking_calls: tuple = ("os.fsync", "fsync", "time.sleep")
    blocking_read_roots: tuple = ("store",)  # <root>.read(...) is device/disk I/O
    schema_manifest: Optional[str] = None  # default: tools/analysis/schema_manifest.json

    def is_decision_path(self, path: str) -> bool:
        p = path.replace(os.sep, "/")
        return any(frag in p for frag in self.decision_paths)


class LintPass:
    """Base class: subclasses set ``name``/``rules`` and implement run()."""

    name: str = ""
    rules: dict = {}  # rule-id -> one-line rationale

    def applies(self, pf: ParsedFile, config: AnalyzerConfig) -> bool:
        return True

    def run(self, pf: ParsedFile, config: AnalyzerConfig) -> list:
        raise NotImplementedError


# ----------------------------------------------------------------- waivers
def _parse_waivers(source: str) -> dict:
    waivers: dict = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(line)
        if m:
            rules = tuple(
                r.strip() for r in m.group(1).split(",") if r.strip()
            )
            waivers[i] = Waiver(line=i, rules=rules, reason=m.group(2))
    return waivers


def apply_waivers(pf: ParsedFile, findings: list) -> list:
    """Suppress findings covered by a reasoned waiver on their line.

    Returns the surviving findings plus one ``lint-bad-waiver`` finding
    per reasonless waiver (which suppresses nothing — the acceptance bar
    is that every waiver carries a written reason)."""
    out = []
    for f in findings:
        w = pf.waivers.get(f.line)
        if w is not None and f.rule in w.rules and w.reason:
            continue
        out.append(f)
    for w in pf.waivers.values():
        if not w.reason:
            out.append(
                Finding(
                    pf.path,
                    w.line,
                    "lint-bad-waiver",
                    "waiver has no reason; write why the rule is safe to "
                    "ignore here",
                )
            )
    return out


# ---------------------------------------------------------------- baseline
class Baseline:
    """Fingerprint->count map of grandfathered findings."""

    def __init__(self, counts: Optional[dict] = None) -> None:
        self.counts = dict(counts or {})

    @classmethod
    def load(cls, path) -> "Baseline":
        p = Path(path)
        if not p.exists():
            return cls()
        doc = json.loads(p.read_text())
        return cls(doc.get("findings", {}))

    @classmethod
    def from_findings(cls, findings: Iterable[Finding]) -> "Baseline":
        counts: dict = {}
        for f in findings:
            counts[f.fingerprint()] = counts.get(f.fingerprint(), 0) + 1
        return cls(counts)

    def save(self, path) -> None:
        doc = {
            "comment": (
                "liferaft-lint baseline: grandfathered findings by "
                "fingerprint. Regenerate with --write-baseline; shrink it "
                "whenever you fix an old finding."
            ),
            "findings": dict(sorted(self.counts.items())),
        }
        Path(path).write_text(json.dumps(doc, indent=1) + "\n")

    def new_findings(self, findings: Iterable[Finding]) -> list:
        """Findings beyond the baselined count for their fingerprint."""
        seen: dict = {}
        fresh = []
        for f in sorted(findings):
            n = seen.get(f.fingerprint(), 0)
            seen[f.fingerprint()] = n + 1
            if n >= self.counts.get(f.fingerprint(), 0):
                fresh.append(f)
        return fresh


# ------------------------------------------------------------------ driver
def collect_files(paths: Iterable[str], root: Optional[str] = None) -> list:
    """Expand files/directories into a sorted list of .py paths."""
    out = []
    for p in paths:
        pp = Path(p)
        if pp.is_file() and pp.suffix == ".py":
            out.append(pp)
        elif pp.is_dir():
            for dirpath, dirnames, filenames in os.walk(pp):
                dirnames[:] = sorted(
                    d for d in dirnames if d not in EXCLUDED_DIRS
                )
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(Path(dirpath) / fn)
    return sorted(set(out))


def parse_file(path, root: Optional[str] = None) -> ParsedFile:
    abspath = os.path.abspath(str(path))
    rel = os.path.relpath(abspath, root or os.getcwd())
    source = Path(abspath).read_text(encoding="utf-8")
    tree = ast.parse(source, filename=rel)
    return ParsedFile(
        path=rel.replace(os.sep, "/"),
        abspath=abspath,
        source=source,
        tree=tree,
        waivers=_parse_waivers(source),
    )


def run_passes(
    pf: ParsedFile, passes: Iterable[LintPass], config: AnalyzerConfig
) -> list:
    findings: list = []
    for p in passes:
        if p.applies(pf, config):
            findings.extend(p.run(pf, config))
    return apply_waivers(pf, findings)


def analyze_paths(
    paths: Iterable[str],
    passes: Iterable[LintPass],
    config: Optional[AnalyzerConfig] = None,
    root: Optional[str] = None,
) -> list:
    """Analyze every .py file under ``paths``; returns sorted findings."""
    config = config or AnalyzerConfig()
    findings: list = []
    for fpath in collect_files(paths, root):
        try:
            pf = parse_file(fpath, root)
        except SyntaxError as exc:
            findings.append(
                Finding(
                    str(fpath),
                    int(exc.lineno or 1),
                    "lint-syntax-error",
                    f"file does not parse: {exc.msg}",
                )
            )
            continue
        findings.extend(run_passes(pf, passes, config))
    return sorted(findings)

"""Paper Fig. 5: top-ten buckets by reuse — the workload's suitability for
batching.  Paper: the top 10 buckets are accessed by 61% of all queries and
temporally-close queries overlap (which benefits caching)."""
from __future__ import annotations

import numpy as np

from repro.crossmatch import workload_stats

from .common import emit, workload

_STATS_CACHE: dict = {}


def stats():
    if "s" not in _STATS_CACHE:
        cat, trace = workload()
        _STATS_CACHE["s"] = (
            workload_stats(trace, cat.partitioner.buckets_for_range, cat.n_buckets,
                           bucket_of_keys=cat.partitioner.bucket_of_keys),
            cat,
            trace,
        )
    return _STATS_CACHE["s"]


def run(verbose: bool = True) -> dict:
    s, cat, trace = stats()
    touch = np.sort(s["touch"])[::-1]
    if verbose:
        print("  top-10 buckets by #queries touching them:", touch[:10].tolist())
        print(f"  fraction of queries touching a top-10 bucket: {s['top10_query_frac']:.2%} (paper: 61%)")
    emit(
        "fig5_bucket_reuse", 0.0,
        f"top10_query_frac={s['top10_query_frac']:.3f};paper=0.61",
    )
    return s


def main() -> None:
    run()


if __name__ == "__main__":
    main()

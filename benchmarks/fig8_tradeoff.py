"""Paper Fig. 8 + Fig. 4: throughput/response trade-off curves by workload
saturation, and the resulting adaptive-alpha selection (paper §4).

Paper anchors: at 0.1 qps, alpha 0 -> 1 cuts response ~54% for ~7%
throughput; at 0.5 qps the same move is unattractive (~20% for ~20%).
The produced TradeoffTable drives AlphaController (tolerance=0.2)."""
from __future__ import annotations

from repro.core import AlphaController, TradeoffPoint, TradeoffTable, run_policy

from .common import CACHE_CAPACITY, COST, emit, workload

ALPHAS = (0.0, 0.25, 0.5, 0.75, 1.0)
SATURATIONS = (0.1, 0.25, 0.5)


def run(verbose: bool = True, n_queries: int = 800):
    table = TradeoffTable()
    summaries = {}
    for sat in SATURATIONS:
        cat, trace = workload(n_queries=n_queries, arrival_rate=sat, seed=13)
        bor = cat.partitioner.buckets_for_range
        pts = []
        for a in ALPHAS:
            r = run_policy("liferaft", trace, bor, COST, alpha=a,
                           cache_capacity=CACHE_CAPACITY,
                           bucket_of_keys=cat.partitioner.bucket_of_keys)
            pts.append(TradeoffPoint(a, r.query_throughput, r.mean_response))
        table.add(sat, pts)
        tmax = max(p.throughput for p in pts)
        rmax = max(p.response for p in pts)
        summaries[sat] = pts
        if verbose:
            print(f"  saturation={sat} qps:")
            for p in pts:
                print(
                    f"    alpha={p.alpha:4.2f} throughput={p.throughput / tmax:6.3f} "
                    f"response={p.response / rmax:6.3f}  (abs {p.throughput:.4f}/s, {p.response:.0f}s)"
                )
    # Adaptive selection per the paper's tolerance rule
    choices = {s: table.select_alpha(s, tolerance=0.2) for s in SATURATIONS}
    if verbose:
        print(f"  alpha choices @ 20% tolerance: {choices} (paper: 1.0 @ low, 0.25 @ high)")
        ctl = AlphaController(table, tolerance=0.2, initial_alpha=0.0, halflife_s=30.0)
        a = 0.0
        for t in range(40):
            a = ctl.update_on_arrival(t * 10.0)  # 0.1 qps arrivals
        print(f"  controller drifted to alpha={a:.2f} under 0.1 qps arrivals")
    lo, hi = min(SATURATIONS), max(SATURATIONS)
    emit(
        "fig8_tradeoff", 0.0,
        f"alpha_low_sat={choices[lo]};alpha_high_sat={choices[hi]};"
        f"paper_low=1.0;paper_high=0.25",
    )
    return table, choices


def main() -> None:
    run()


if __name__ == "__main__":
    main()

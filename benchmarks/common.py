"""Shared benchmark fixtures: the SkyQuery-scale workload used by the
Fig. 5/6/7/8 reproductions, plus timing helpers."""
from __future__ import annotations

import time

import numpy as np

from repro.core import PAPER_COST_MODEL, HybridCostModel
from repro.crossmatch import TraceConfig, make_catalog, make_trace

# Scaled SkyQuery setup: the paper uses 20k x 10k-object (40 MB) buckets
# with a 20-bucket cache (0.1%) and a 2,000-query long-running trace.  We
# scale objects down 100x but keep the ratios that drive the scheduler:
# cache/buckets = 1%, Zipf bucket popularity, temporal locality, and the
# measured cost constants T_b = 1.2 s, T_m = 0.13 ms.
CATALOG_KW = dict(n_objects=200_000, objects_per_bucket=100, htm_level=8, seed=7)
TRACE_KW = dict(
    n_queries=2_000,
    arrival_rate=0.25,
    n_hotspots=24,
    zipf_s=1.6,
    hotspot_frac=0.8,
    temporal_locality=0.6,
    objects_median=300,
    objects_sigma=1.1,
    cone_radius_med=0.05,
    fullsky_frac=0.03,
    seed=11,
)
CACHE_CAPACITY = 20
COST = PAPER_COST_MODEL
HYBRID_COST = HybridCostModel(T_b=1.2, T_m=0.13e-3, T_probe=4.13e-3)

_cache = {}


def workload(n_queries: int | None = None, arrival_rate: float | None = None,
             seed: int | None = None):
    """(catalog, trace) memoized across benchmark modules."""
    kw = dict(TRACE_KW)
    if n_queries is not None:
        kw["n_queries"] = n_queries
    if arrival_rate is not None:
        kw["arrival_rate"] = arrival_rate
    if seed is not None:
        kw["seed"] = seed
    key = ("cat",)
    if key not in _cache:
        _cache[key] = make_catalog(**CATALOG_KW)
    cat = _cache[key]
    tkey = tuple(sorted(kw.items()))
    if tkey not in _cache:
        _cache[tkey] = make_trace(cat, TraceConfig(**kw))
    return cat, _cache[tkey]


def time_call(fn, *args, reps: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall micro-seconds per call (CPU; for relative comparisons)."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        try:  # block on device results
            import jax

            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")

"""BENCH_prefetch: scan-horizon prefetch pipeline vs the reactive LRU.

Emits ``BENCH_prefetch.json`` with four measurements:

1. ``staged_throughput`` — the same deep-queue trace through the
   simulator with prefetch off (reactive LRU, every miss paid inline)
   and on (scan-horizon staging overlapping compute) at EQUAL cache
   capacity (acceptance: >= 1.3x simulated object throughput).
2. ``decision_equivalence`` — incremental vs naive-oracle scheduler
   replaying the prefetch-ON trace in lockstep through the recorded
   decision logs; the staged residency, peeked horizons and stall
   accounting must not move a single decision between the two paths
   (acceptance: 0 mismatches).
3. ``adaptive_horizon`` — informational: the ControlLoop's AIMD H law on
   a stall-heavy trace (final H, stall rounds before/after deepening).
4. ``serving_overlap`` — informational: the serving engine staging
   adapter weights into HBM slots ahead of dispatch.

Run: ``PYTHONPATH=src python -m benchmarks.bench_prefetch [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core import (
    ControlConfig,
    ControlLoop,
    CostModel,
    PrefetchConfig,
    run_policy,
)
from repro.core.workload import Query

from .common import emit

THROUGHPUT_GATE = 1.3


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _deep_trace(seed, n=220, buckets=50, gap=0.05, depth=(50, 400)):
    """Deep queues make per-bucket compute comparable to T_b — the regime
    where staging the next read behind the current compute pays (a
    T_b-dominated trace is channel-bound either way; a T_m-dominated one
    barely misses)."""
    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(n):
        t += float(rng.exponential(gap))
        b = int(rng.integers(0, buckets))
        ks = np.full(int(rng.integers(*depth)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    return qs


# ------------------------------------------------------- 1. staged throughput
def bench_throughput(seed=7) -> dict:
    cost = CostModel(T_b=0.08, T_m=2e-4)
    qs = _deep_trace(seed)
    common = dict(alpha=0.25, cache_capacity=8)
    off = run_policy("liferaft", qs, _identity_range, cost, **common)
    on = run_policy(
        "liferaft", qs, _identity_range, cost, **common,
        prefetch=PrefetchConfig(horizon=4, depth=4),
    )
    assert off.n_queries == on.n_queries  # same completions, different clock
    return {
        "trace_queries": len(qs),
        "cache_capacity": 8,
        "reactive": {
            "makespan": off.makespan,
            "object_throughput": off.object_throughput,
            "cache_hit_rate": off.cache_hit_rate,
        },
        "prefetch": {
            "makespan": on.makespan,
            "object_throughput": on.object_throughput,
            "cache_hit_rate": on.cache_hit_rate,
            **on.prefetch,
        },
        "throughput_gain": on.object_throughput / off.object_throughput,
        "gate": THROUGHPUT_GATE,
        "passed": on.object_throughput >= THROUGHPUT_GATE * off.object_throughput,
    }


# ------------------------------------------------- 2. decision equivalence
def bench_equivalence(seed=23, n=160) -> dict:
    """Both schedulers drive their own full prefetch pipeline over the
    same trace; the decision logs (bucket, score, residency, cost) must
    be bit-identical — peek_topk, staged residency churn and stall
    charging all preserve the incremental-vs-oracle invariant."""
    cost = CostModel(T_b=0.08, T_m=2e-4)
    qs = _deep_trace(seed, n=n, depth=(20, 250))
    logs = {}
    for policy in ("liferaft", "liferaft-naive"):
        entries = []

        def rec(outcome, entries=entries):
            entries.append(
                (
                    tuple(
                        (d.bucket_id, d.score, d.in_cache, d.queue_size)
                        for d in outcome.decisions
                    ),
                    outcome.cost,
                    outcome.stall,
                )
            )

        run_policy(
            policy, qs, _identity_range, cost, alpha=0.25, cache_capacity=8,
            normalized=True, fuse_k=2,
            prefetch=PrefetchConfig(horizon=4, depth=4), on_round=rec,
        )
        logs[policy] = entries
    inc, nai = logs["liferaft"], logs["liferaft-naive"]
    mismatches = sum(1 for e, g in zip(inc, nai) if e != g)
    mismatches += abs(len(inc) - len(nai))
    return {
        "trace_queries": n,
        "rounds": len(inc),
        "stall_rounds": sum(1 for e in inc if e[2] > 0.0),
        "mismatches": mismatches,
        "bit_identical": mismatches == 0,
    }


# ------------------------------------------------- 3. adaptive horizon law
def bench_adaptive_horizon(seed=59) -> dict:
    cost = CostModel(T_b=0.08, T_m=2e-4)
    qs = _deep_trace(seed, n=200, buckets=48, gap=0.012, depth=(1, 60))
    ctl = ControlLoop(ControlConfig(
        alpha_init=0.5, alpha_step=0.2, halflife_s=2.0, rate_knee=12.0,
        depth_knee=1_500.0, fuse_k_max=3,
        prefetch_horizon_init=1, prefetch_horizon_max=8,
    ))
    r = run_policy(
        "liferaft", qs, _identity_range, cost, cache_capacity=8,
        normalized=True, control=ctl,
        prefetch=PrefetchConfig(horizon=1, depth=4),
    )
    return {
        "final_horizon": ctl.last.horizon if ctl.last else 0,
        "makespan": r.makespan,
        **r.prefetch,
    }


# ---------------------------------------------------- 4. serving overlap
def bench_serving(seed=61) -> dict:
    from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig

    n_adapters = 8
    w = 1.0 / np.arange(1, n_adapters + 1) ** 1.5
    w /= w.sum()
    adapters = [AdapterSpec(i, 48 << 30) for i in range(n_adapters)]
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(200):
        t += float(rng.exponential(1.0 / 300.0))
        reqs.append(
            Request(i, int(rng.choice(n_adapters, p=w)), t,
                    int(rng.integers(16, 96)), 32)
        )
    out = {}
    for label, pf in (("reactive", False), ("prefetch", True)):
        eng = LifeRaftEngine(
            adapters,
            ServeConfig(
                policy="liferaft", alpha=0.25, fuse_k=2, max_batch=8,
                prefetch=pf, prefetch_depth=4,
            ),
        )
        s = eng.run([
            Request(r.request_id, r.adapter_id, r.arrival_time,
                    r.prompt_len, r.max_new_tokens)
            for r in reqs
        ])
        out[label] = {
            "makespan": s["makespan"],
            "token_throughput": s["token_throughput"],
            "cache_hit_rate": s["cache_hit_rate"],
            **s["prefetch"],
        }
    out["speedup"] = (
        out["prefetch"]["token_throughput"] / out["reactive"]["token_throughput"]
    )
    return out


def run(out_path: str = "BENCH_prefetch.json", verbose: bool = True) -> dict:
    report = {
        "staged_throughput": bench_throughput(),
        "decision_equivalence": bench_equivalence(),
        "adaptive_horizon": bench_adaptive_horizon(),
        "serving_overlap": bench_serving(),
    }
    st = report["staged_throughput"]
    eq = report["decision_equivalence"]
    pathlib.Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    if verbose:
        print(
            f"  staged throughput: {st['throughput_gain']:.2f}x vs reactive "
            f"(gate {st['gate']}x; hit {st['prefetch']['cache_hit_rate']:.2f} "
            f"vs {st['reactive']['cache_hit_rate']:.2f})"
        )
        print(
            f"  equivalence: {eq['rounds']} rounds "
            f"({eq['stall_rounds']} stalled), {eq['mismatches']} mismatches"
        )
        print(
            f"  adaptive H -> {report['adaptive_horizon']['final_horizon']}, "
            f"serving speedup {report['serving_overlap']['speedup']:.3f}x"
        )
        print(f"  wrote {out_path}")
    emit(
        "bench_prefetch",
        st["throughput_gain"],
        f"gain={st['throughput_gain']:.2f}x;mismatches={eq['mismatches']}",
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_prefetch.json")
    # Tolerate stray argv (argparse's SystemExit would kill benchmarks.run).
    args, _ = ap.parse_known_args()
    report = run(args.out)
    assert report["staged_throughput"]["passed"], report["staged_throughput"]
    assert report["decision_equivalence"]["bit_identical"]


if __name__ == "__main__":
    main()

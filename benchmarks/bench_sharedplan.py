"""BENCH_sharedplan: shared query plans vs per-predicate dispatch.

Emits ``BENCH_sharedplan.json`` with three measurements:

1. ``device_dispatch_reduction`` — a high-overlap cross-match trace
   (few hotspots, high temporal locality) with heterogeneous per-query
   predicates, run with ``shared_plan`` off (one kernel per predicate
   class per round) and on (one masked kernel per width chunk).
   Acceptance: >= 2x fewer device dispatches AND bit-equal per-query
   results (best_dot compared at the float32 bit level).
2. ``compile_bounding`` — K distinct predicates through one shared call
   at a fixed pow2 shape pair add exactly one ``jit_cache_size`` entry.
3. ``share_width_law`` — informational: the AIMD ``share_width`` law on
   the simulator (final width, occupancy trajectory endpoints).

Run: ``PYTHONPATH=src python -m benchmarks.bench_sharedplan [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core import ControlConfig, ControlLoop, CostModel, run_policy
from repro.core.workload import Query
from repro.crossmatch import CrossMatchEngine, TraceConfig, make_catalog, make_trace
from repro.kernels.crossmatch import ops as cm_ops

from .common import emit

DISPATCH_GATE = 2.0

RADII = [2e-3, 4e-3, 8e-3]
MAG_CUTS = [23.0, 24.0, 25.0]


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _overlap_workload(seed=17):
    """High-overlap regime: 4 hotspots, strong temporal locality, so many
    live queries land on the same buckets each round — the one-stone
    sharing opportunity the paper's batch windows create."""
    catalog = make_catalog(
        n_objects=6_000, objects_per_bucket=100, htm_level=6, seed=seed
    )
    trace = make_trace(
        catalog,
        TraceConfig(
            n_queries=48, arrival_rate=6.0, n_hotspots=4, zipf_s=1.2,
            hotspot_frac=0.95, temporal_locality=0.85, objects_median=60,
            objects_sigma=0.6, cone_radius_med=0.04, fullsky_frac=0.0,
            seed=seed + 2,
        ),
    )
    rng = np.random.default_rng(seed + 4)
    for q in trace:
        q.meta["radius"] = float(rng.choice(RADII))
        q.meta["mag_cut"] = float(rng.choice(MAG_CUTS))
    return catalog, trace


def _results_bit_equal(a, b) -> bool:
    if set(a) != set(b):
        return False
    key = lambda r: int(r.probe_idx.min()) if len(r.probe_idx) else -1
    for qid in a:
        ra, rb = sorted(a[qid], key=key), sorted(b[qid], key=key)
        if len(ra) != len(rb):
            return False
        for x, y in zip(ra, rb):
            if not (
                np.array_equal(x.probe_idx, y.probe_idx)
                and np.array_equal(x.match_obj, y.match_obj)
                and np.array_equal(
                    x.best_dot.astype(np.float32).view(np.int32),
                    y.best_dot.astype(np.float32).view(np.int32),
                )
                and np.array_equal(x.n_candidates, y.n_candidates)
            ):
                return False
    return True


# -------------------------------------------- 1. device dispatch reduction
def bench_dispatch_reduction(seed=17) -> dict:
    def run(shared):
        catalog, trace = _overlap_workload(seed)
        eng = CrossMatchEngine(
            catalog, match_radius_rad=4e-3, fuse_k=4,
            shared_plan=shared, share_width=16,
        )
        results = eng.run(trace)
        return results, eng.summary()

    res_off, sum_off = run(False)
    res_on, sum_on = run(True)
    off_d = int(sum_off["device_dispatches"])
    on_d = int(sum_on["device_dispatches"])
    reduction = off_d / max(on_d, 1)
    equal = _results_bit_equal(res_off, res_on)
    return {
        "trace_queries": 48,
        "predicate_classes": len(RADII),
        "per_predicate_dispatches": off_d,
        "shared_dispatches": on_d,
        "reduction": reduction,
        "shared_batch_occupancy": sum_on["shared_batch_occupancy"],
        "results_bit_equal": equal,
        "gate": DISPATCH_GATE,
        "passed": bool(equal and reduction >= DISPATCH_GATE),
    }


# ------------------------------------------------- 2. compile bounding
def bench_compile_bounding(k=8) -> dict:
    rng = np.random.default_rng(3)
    v = rng.normal(size=(41, 3))  # pads to 64
    bucket = v / np.linalg.norm(v, axis=1, keepdims=True)
    base = cm_ops.jit_cache_size()
    for i in range(k):
        p = rng.normal(size=(11, 3))  # pads to 16
        probes = p / np.linalg.norm(p, axis=1, keepdims=True)
        thr = np.full(11, 0.9 + 0.005 * i, np.float32)
        cm_ops.crossmatch_shared(bucket, probes, np.zeros(41), np.zeros(11), thr)
    new_entries = cm_ops.jit_cache_size() - base
    return {
        "distinct_predicates": k,
        "new_cache_entries": new_entries,
        "bounded": new_entries <= 1,
    }


# ------------------------------------------------- 3. share_width AIMD law
def bench_width_law(seed=43) -> dict:
    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(200):
        t += float(rng.exponential(0.02))
        b = int(rng.integers(0, 50))
        ks = np.full(int(rng.integers(1, 14)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    widths = []
    ctl = ControlLoop(ControlConfig(
        alpha_init=0.5, alpha_step=0.2, halflife_s=3.0, rate_knee=6.0,
        depth_knee=500.0, fuse_k_max=4, share_width_init=2, share_width_max=8,
    ))
    r = run_policy(
        "liferaft", qs, _identity_range, CostModel(T_b=0.8, T_m=2e-4),
        cache_capacity=8, normalized=True, control=ctl,
        shared_plan=True, share_width=2,
        on_round=lambda o: widths.append(int(o.vector.share_width)),
    )
    return {
        "initial_width": widths[0] if widths else 0,
        "final_width": widths[-1] if widths else 0,
        "max_width": max(widths, default=0),
        "device_dispatches": r.device_dispatches,
        "shared_batch_occupancy": r.shared_batch_occupancy,
    }


def run(out_path: str = "BENCH_sharedplan.json", verbose: bool = True) -> dict:
    report = {
        "device_dispatch_reduction": bench_dispatch_reduction(),
        "compile_bounding": bench_compile_bounding(),
        "share_width_law": bench_width_law(),
    }
    dr = report["device_dispatch_reduction"]
    cb = report["compile_bounding"]
    wl = report["share_width_law"]
    pathlib.Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    if verbose:
        print(
            f"  device dispatches: {dr['per_predicate_dispatches']} -> "
            f"{dr['shared_dispatches']} ({dr['reduction']:.2f}x, gate "
            f"{dr['gate']}x; bit-equal={dr['results_bit_equal']}, "
            f"occupancy {dr['shared_batch_occupancy']:.2f})"
        )
        print(
            f"  compile bounding: {cb['distinct_predicates']} predicates -> "
            f"{cb['new_cache_entries']} cache entries"
        )
        print(
            f"  share_width law: {wl['initial_width']} -> {wl['final_width']} "
            f"(max {wl['max_width']})"
        )
        print(f"  wrote {out_path}")
    emit(
        "bench_sharedplan",
        dr["reduction"],
        f"reduction={dr['reduction']:.2f}x;bit_equal={int(dr['results_bit_equal'])}",
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sharedplan.json")
    args, _ = ap.parse_known_args()
    report = run(args.out)
    assert report["device_dispatch_reduction"]["passed"], report[
        "device_dispatch_reduction"
    ]
    assert report["compile_bounding"]["bounded"], report["compile_bounding"]


if __name__ == "__main__":
    main()

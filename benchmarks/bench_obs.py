"""BENCH_obs: the observability layer must be (nearly) free.

Emits ``BENCH_obs.json`` with two sections:

1. ``overhead`` — the same saturating adaptive simulation run obs-OFF
   and obs-ON (full tap: metrics + tracer + explain), interleaved
   min-of-N wall times.  Acceptance: obs-on throughput >= ``GATE``
   (0.97x) of obs-off — the tap budget documented in
   docs/observability.md.
2. ``artifacts`` — a skewed 4-shard run with work stealing, exported as
   the consolidated ``OBS_snapshot.json`` (metrics + control explain +
   trace rollup) and ``OBS_trace.perfetto.json`` (one track per shard,
   steal arrows).  Acceptance: >= 1 steal captured, valid JSON on disk.
   Nightly CI uploads both artifacts next to the bench reports.

Run: ``PYTHONPATH=src python -m benchmarks.bench_obs [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import pathlib
from time import perf_counter

import numpy as np

from repro.core import (
    ControlConfig,
    ControlLoop,
    CostModel,
    LifeRaftScheduler,
    StealConfig,
    simulate_batched,
    simulate_sharded,
)
from repro.core.workload import Query
from repro.obs import Observability

from .common import emit

GATE = 0.97  # obs-on / obs-off throughput ratio floor


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _trace(seed, n=1200, buckets=64, gap=0.004, depth=(10, 60), skew=False):
    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(n):
        t += float(rng.exponential(gap))
        b = int(rng.integers(0, buckets))
        if skew:
            b = b * b // buckets
        ks = np.full(int(rng.integers(*depth)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    return qs


def _cost():
    return CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)


def _control():
    return ControlLoop(ControlConfig(
        alpha_init=0.5, alpha_step=0.2, halflife_s=2.0,
        rate_knee=12.0, depth_knee=1_200.0, fuse_k_max=3,
        spill_budget_bytes=6_000.0,
    ))


def _run_once(obs=None) -> float:
    """One adaptive, spill-engaging simulation; returns wall seconds."""
    cost = _cost()
    qs = _trace(23)
    t0 = perf_counter()
    simulate_batched(
        qs, _identity_range,
        LifeRaftScheduler(cost, 0.5, normalized=True), cost,
        cache_capacity=8, fuse_k=2, control=_control(), obs=obs,
    )
    return perf_counter() - t0


def bench_overhead(reps: int = 3) -> dict:
    _run_once()  # warmup (allocator, imports, caches)
    offs, ons = [], []
    rounds_observed = 0
    for _ in range(reps):  # interleaved so drift hits both sides equally
        offs.append(_run_once(obs=None))
        obs = Observability()
        ons.append(_run_once(obs=obs))
        rounds_observed = int(
            obs.registry.counter("liferaft_rounds_total", track="0").value
        )
    t_off, t_on = min(offs), min(ons)
    ratio = t_off / t_on  # obs-on throughput relative to obs-off
    return {
        "t_off_s": t_off,
        "t_on_s": t_on,
        "throughput_ratio": ratio,
        "rounds_observed": rounds_observed,
        "gate": GATE,
        "passed": ratio >= GATE and rounds_observed > 0,
    }


def export_artifacts(
    snapshot_path: str = "OBS_snapshot.json",
    trace_path: str = "OBS_trace.perfetto.json",
) -> dict:
    """Skewed sharded run with stealing -> consolidated obs artifacts."""
    obs = Observability()
    cost = _cost()
    simulate_sharded(
        _trace(71, n=600, skew=True), _identity_range, cost,
        scheduler_factory=lambda: LifeRaftScheduler(
            cost, 0.5, normalized=True
        ),
        n_shards=4, cache_capacity=8, fuse_k=2,
        steal=StealConfig(low_water_bytes=0.0),
        obs=obs,
    )
    snap = obs.snapshot()
    trace = obs.perfetto()
    pathlib.Path(snapshot_path).write_text(json.dumps(snap, indent=1) + "\n")
    pathlib.Path(trace_path).write_text(json.dumps(trace) + "\n")
    steals = snap["trace"]["steals"]
    tracks = snap["trace"]["tracks"]
    return {
        "snapshot_path": snapshot_path,
        "trace_path": trace_path,
        "rounds": snap["trace"]["rounds"],
        "steals": steals,
        "tracks": tracks,
        "trace_events": len(trace["traceEvents"]),
        "passed": steals >= 1 and tracks == [0, 1, 2, 3],
    }


def run(out_path: str = "BENCH_obs.json", verbose: bool = True) -> dict:
    report = {
        "overhead": bench_overhead(),
        "artifacts": export_artifacts(),
    }
    ov = report["overhead"]
    ar = report["artifacts"]
    pathlib.Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    if verbose:
        print(
            f"  overhead: obs-on {ov['throughput_ratio']:.3f}x of obs-off "
            f"(gate {ov['gate']}x, {ov['rounds_observed']} rounds observed)"
        )
        print(
            f"  artifacts: {ar['rounds']} spans / {ar['steals']} steals "
            f"across tracks {ar['tracks']} -> {ar['snapshot_path']}, "
            f"{ar['trace_path']}"
        )
        print(f"  wrote {out_path}")
    emit(
        "bench_obs",
        ov["throughput_ratio"],
        f"ratio={ov['throughput_ratio']:.3f}x;steals={ar['steals']}",
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_obs.json")
    # Tolerate stray argv (argparse's SystemExit would kill benchmarks.run).
    args, _ = ap.parse_known_args()
    report = run(args.out)
    assert report["overhead"]["passed"], report["overhead"]
    assert report["artifacts"]["passed"], report["artifacts"]


if __name__ == "__main__":
    main()

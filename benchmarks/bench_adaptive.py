"""BENCH_adaptive: the closed-loop control plane vs the best static alpha.

Emits ``BENCH_adaptive.json`` with three measurements:

1. ``closed_loop_vs_static`` — a bursty interactive+batch workload replayed
   under every static alpha in {0, 0.25, 0.5, 0.75, 1} and under the
   ControlLoop (rate-EWMA alpha law, per-round consult through the shared
   DispatchLoop).  The workload alternates two regimes no single alpha
   handles: an interactive-dominant phase where greedy (alpha≈0) is
   response-optimal, and a batch-heavy phase where greedy structurally
   starves cold queries (p95 blows up) and aging is required.  Metrics are
   aggregated over three fixed trace pairs.  Acceptance: the adaptive
   controller improves p95 response over the best feasible static alpha
   (min p95 among statics within 90% of the best static throughput) while
   keeping >= 0.9x the best static throughput.
2. ``normalized_equivalence`` — the incremental lazy-heap scheduler replays
   a trace in lockstep with the naive O(B) oracle under ``normalized=True``
   (the serving engine's default, historically forced onto the naive
   fallback).  Acceptance: 0 mismatches on bucket id and score.
3. ``fuse_k_adaptation`` / ``spill`` — informational: AIMD fuse_k amortizes
   dispatches under queue breadth; the §6 overflow budget spills and
   restores workload queues without losing queries.
4. ``two_tenant`` — the multi-tenant control plane (one ControlVector per
   tenant class, §6 byte budget arbitrated across classes) vs the global
   closed loop on a batch-flood + interactive-singleton workload.
   Acceptance: per-tenant control achieves interactive p95 <= the global
   closed loop's at >= 0.95x aggregate throughput, with byte-accounted
   resident state never exceeding the global budget on any enforcement
   round — spills AND rounds immediately after an unspill grant (modulo
   the oldest-unit no-starvation floors).
5. ``unspill_oscillation`` — the paged oldest-first unspill protocol vs
   the legacy whole-queue unspill on a steady saturating serving load.
   Acceptance: the paged protocol's spill-bit flip count does not regress
   vs the whole-queue baseline and NO paged round that returned spilled
   work ends above the budget (+ floors); the whole-queue baseline's
   overshoot rounds are reported for contrast (it re-exceeds the budget
   whenever a deep spilled adapter is serviced).

Run: ``PYTHONPATH=src python -m benchmarks.bench_adaptive [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core import (
    BucketCache,
    ControlConfig,
    ControlLoop,
    CostModel,
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
    Query,
    TenantControlPlane,
    TenantPolicy,
    WorkloadManager,
    simulate_batched,
)

from .common import emit

COST = CostModel(T_b=1.2, T_m=0.13e-3)
ALPHAS = (0.0, 0.25, 0.5, 0.75, 1.0)
TRACE_SEEDS = (1, 2, 4)  # trace pairs aggregated by the gate


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def bursty_trace(seed, horizon=360.0, stream_rate=2.0, cold_rate=0.7,
                 burst_size=50, burst_every=45.0, hot=10, n_buckets=400):
    """Interactive+batch mix: a zipf hot stream, sparse cold singleton
    queries (the starvation victims under alpha=0), and periodic deep
    batch bursts (where aging distracts the drain)."""
    rng = np.random.default_rng(seed)
    qs, qid = [], 0
    zipf = 1.0 / np.arange(1, hot + 1) ** 1.2
    zipf /= zipf.sum()
    t = 0.0
    while t < horizon:
        t += rng.exponential(1 / stream_rate)
        b = rng.choice(hot, p=zipf)
        ks = np.full(int(rng.integers(60, 120)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
        qid += 1
    t = 0.0
    while t < horizon:
        t += rng.exponential(1 / cold_rate)
        b = rng.integers(hot, n_buckets)
        ks = np.full(int(rng.integers(1, 4)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
        qid += 1
    tb = burst_every / 2
    while tb < horizon:
        for _ in range(burst_size):
            t = tb + rng.uniform(0, 2.0)
            b = rng.choice(hot, p=zipf)
            ks = np.full(int(rng.integers(60, 140)), b, dtype=np.uint64)
            qs.append(Query(qid, t, ks, ks))
            qid += 1
        tb += burst_every
    return qs


def _trace_pair(seed):
    """(interactive-dominant, batch-heavy) — the two regimes whose best
    static alphas differ (greedy vs aged)."""
    return (
        bursty_trace(seed, cold_rate=0.3, stream_rate=2.4),
        bursty_trace(seed + 100, cold_rate=0.7, stream_rate=2.0),
    )


def _control():
    """The benchmark's closed-loop config: rate-EWMA alpha law (bursts spike
    the arrival EWMA -> greedy; lulls relax it -> aged), fuse_k pinned at 1
    so the comparison isolates the alpha law."""
    return ControlLoop(ControlConfig(
        alpha_init=0.5, alpha_step=0.2, halflife_s=4.0,
        rate_knee=5.0, depth_knee=1e12, fuse_k_max=1,
    ))


# ---------------------------------------------------- 1. adaptive vs static
def bench_closed_loop() -> dict:
    traces = [t for s in TRACE_SEEDS for t in _trace_pair(s)]

    def run_static(alpha):
        rs = [
            simulate_batched(
                tr, _identity_range,
                LifeRaftScheduler(COST, alpha, normalized=True),
                COST, cache_capacity=10,
            )
            for tr in traces
        ]
        return rs

    def agg(rs):
        return (
            float(np.mean([r.query_throughput for r in rs])),
            float(np.mean([r.p95_response for r in rs])),
        )

    statics = {}
    for a in ALPHAS:
        qtp, p95 = agg(run_static(a))
        statics[a] = {"query_throughput": qtp, "p95_response": p95}

    rs = [
        simulate_batched(
            tr, _identity_range,
            LifeRaftScheduler(COST, 0.5, normalized=True),
            COST, cache_capacity=10, control=_control(),
        )
        for tr in traces
    ]
    a_qtp, a_p95 = agg(rs)

    max_qtp = max(s["query_throughput"] for s in statics.values())
    feasible = {
        a: s for a, s in statics.items()
        if s["query_throughput"] >= 0.9 * max_qtp
    }
    best_alpha = min(feasible, key=lambda a: feasible[a]["p95_response"])
    best = feasible[best_alpha]
    return {
        "trace_seeds": list(TRACE_SEEDS),
        "n_traces": len(traces),
        "n_queries": sum(len(t) for t in traces),
        "static": {str(a): s for a, s in statics.items()},
        "adaptive": {"query_throughput": a_qtp, "p95_response": a_p95},
        "best_static_alpha": best_alpha,
        "best_static": best,
        "throughput_ratio": a_qtp / max_qtp,
        "p95_improvement_s": best["p95_response"] - a_p95,
        "passes": bool(
            a_qtp >= 0.9 * max_qtp and a_p95 < best["p95_response"]
        ),
    }


# ------------------------------------------- 2. normalized decision equality
def bench_normalized_equivalence() -> dict:
    """Lockstep replay under normalized=True: the incremental heap path
    (no naive fallback anymore) must match the oracle bit for bit."""
    queries = sorted(bursty_trace(7), key=lambda q: q.arrival_time)
    sides = {
        label: dict(
            sched=cls(COST, alpha=0.25, normalized=True),
            wm=WorkloadManager(_identity_range),
            cache=BucketCache(10),
        )
        for label, cls in (("inc", LifeRaftScheduler),
                           ("nai", NaiveLifeRaftScheduler))
    }
    clock, i, decisions, mismatches = 0.0, 0, 0, 0
    wm_i = sides["inc"]["wm"]
    assert not sides["inc"]["sched"]._use_naive(wm_i, sides["inc"]["cache"])
    while i < len(queries) or wm_i.n_pending_queries:
        if not wm_i.nonempty_queues():
            clock = max(clock, queries[i].arrival_time)
        while i < len(queries) and queries[i].arrival_time <= clock:
            for s in sides.values():
                s["wm"].submit(queries[i])
            i += 1
        ds = {
            k: s["sched"].select(s["wm"], s["cache"], clock)
            for k, s in sides.items()
        }
        if ds["inc"] is None and ds["nai"] is None:
            continue
        decisions += 1
        if ds["inc"] is None or ds["nai"] is None:
            mismatches += 1
            break
        if (
            ds["inc"].bucket_id != ds["nai"].bucket_id
            or ds["inc"].score != ds["nai"].score
        ):
            mismatches += 1
        d = ds["nai"]
        step = COST.batch_cost(d.queue_size, d.in_cache)
        clock += step
        for k, s in sides.items():
            s["cache"].access(ds[k].bucket_id)
            s["wm"].complete_bucket(ds[k].bucket_id, clock)
    return {
        "decisions": decisions,
        "mismatches": mismatches,
        "bit_identical": mismatches == 0,
    }


# ------------------------------------------------- 4. per-tenant vs global
TT_COST = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.1, probe_bytes=16.0)
TT_BUDGET = 60_000.0  # global §6 budget, actual probe bytes
TT_SEEDS = (21, 22, 23)


def two_tenant_trace(seed, horizon=10.0):
    """Batch flood (deep queries, 8 hot buckets) + sparse interactive
    singletons on cold buckets, tenant-tagged — the §6 starvation mix."""
    rng = np.random.default_rng(seed)
    qs, qid, t = [], 0, 0.0
    while t < horizon:
        t += rng.exponential(0.03)
        b = rng.integers(0, 8)
        ks = np.full(int(rng.integers(60, 120)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks, meta={"tenant": "batch"}))
        qid += 1
    t = 0.0
    while t < horizon:
        t += rng.exponential(0.4)
        b = rng.integers(8, 160)
        ks = np.full(int(rng.integers(1, 3)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks, meta={"tenant": "interactive"}))
        qid += 1
    return qs


def _tenant_plane():
    """Interactive pins alpha high (arrival order, low latency); batch
    pins it low (data-driven throughput) and takes 2x the budget weight."""
    return TenantControlPlane(
        [
            TenantPolicy("interactive", ControlConfig(
                alpha_init=0.9, alpha_min=0.7, alpha_max=1.0, alpha_step=0.2,
                rate_knee=30.0, depth_knee=5_000.0, fuse_k_max=2,
            )),
            TenantPolicy("batch", ControlConfig(
                alpha_init=0.2, alpha_min=0.0, alpha_max=0.4, alpha_step=0.2,
                rate_knee=10.0, depth_knee=2_000.0, fuse_k_max=6,
            ), weight=2.0),
        ],
        global_budget_bytes=TT_BUDGET,
        halflife_s=3.0,
    )


def _global_control():
    """The single-vector closed loop on the same byte budget (PR 2's
    controller — the baseline per-tenant control must beat on interactive
    p95 without giving up aggregate throughput)."""
    return ControlLoop(ControlConfig(
        alpha_init=0.5, alpha_step=0.2, halflife_s=3.0,
        rate_knee=10.0, depth_knee=2_000.0, fuse_k_max=6,
        spill_budget_bytes=TT_BUDGET,
    ))


def _slice_stat(result, tenant, stat):
    """Per-tenant stat or None — empty slices report ``None`` (n=0), and a
    summary must skip them, never average them in as zero latency."""
    s = result.per_tenant.get(tenant)
    if not s or not s["n"]:
        return None
    return s[stat]


def _mean_defined(values):
    vals = [v for v in values if v is not None]
    return float(np.mean(vals)) if vals else None


def bench_two_tenant() -> dict:
    from repro.core import dispatch as _dispatch

    # Observe post-ROUND residency: apply_spill (the one enforcement choke
    # point — its first argument is the wm, which simulate_batched never
    # exposes) only stashes the reference; sampling happens in on_round,
    # i.e. after EVERY tenant's enforcement ran, so a not-yet-walked
    # tenant's overhang cannot read as a budget violation.  Sampled on
    # every enforcement round — engaged spills AND rounds that paged an
    # unspill grant back in (the §6 overshoot bugfix's acceptance: no
    # round immediately after an unspill may exceed the budget).
    max_resident_after_spill = 0.0
    seen_wm = None
    real_apply_spill = _dispatch.apply_spill

    def stashing_apply_spill(wm, vector, config, **kw):
        nonlocal seen_wm
        seen_wm = wm
        return real_apply_spill(wm, vector, config, **kw)

    def sample_round(outcome):
        nonlocal max_resident_after_spill
        if (outcome.vector.spill or outcome.spill_changed) and seen_wm is not None:
            max_resident_after_spill = max(
                max_resident_after_spill, seen_wm.resident_bytes()
            )

    def run(control, qs, observe=False):
        return simulate_batched(
            qs, _identity_range,
            LifeRaftScheduler(TT_COST, 0.5, normalized=True),
            TT_COST, cache_capacity=8, control=control,
            on_round=sample_round if observe else None,
        )

    rows = []
    _dispatch.apply_spill = stashing_apply_spill
    try:
        for seed in TT_SEEDS:
            qs = two_tenant_trace(seed)
            rg = run(_global_control(), qs)
            rm = run(_tenant_plane(), qs, observe=True)
            rows.append({
                "seed": int(seed),
                "global": {
                    "interactive_p95": _slice_stat(rg, "interactive", "p95_response"),
                    "batch_p95": _slice_stat(rg, "batch", "p95_response"),
                    "query_throughput": rg.query_throughput,
                },
                "per_tenant": {
                    "interactive_p95": _slice_stat(rm, "interactive", "p95_response"),
                    "batch_p95": _slice_stat(rm, "batch", "p95_response"),
                    "query_throughput": rm.query_throughput,
                },
            })
    finally:
        _dispatch.apply_spill = real_apply_spill

    # Empty slices (n=0 -> None) are skipped, not averaged in as zeros.
    g_p95 = _mean_defined([r["global"]["interactive_p95"] for r in rows])
    m_p95 = _mean_defined([r["per_tenant"]["interactive_p95"] for r in rows])
    assert g_p95 is not None and m_p95 is not None, "no interactive completions"
    g_qtp = float(np.mean([r["global"]["query_throughput"] for r in rows]))
    m_qtp = float(np.mean([r["per_tenant"]["query_throughput"] for r in rows]))
    # The §6 floors: each tenant's boundary victim keeps its oldest unit
    # resident — allow one max-size unit per tenant class of slop.
    floor_slop = 2 * 120 * TT_COST.probe_bytes
    within_budget = max_resident_after_spill <= TT_BUDGET + floor_slop
    return {
        "seeds": list(TT_SEEDS),
        "budget_bytes": TT_BUDGET,
        "rows": rows,
        "global_interactive_p95": g_p95,
        "tenant_interactive_p95": m_p95,
        "throughput_ratio": m_qtp / max(g_qtp, 1e-9),
        "max_resident_after_spill": max_resident_after_spill,
        "spill_within_budget": bool(within_budget),
        "passes": bool(
            m_p95 <= g_p95 and m_qtp >= 0.95 * g_qtp and within_budget
        ),
    }


# ------------------------------------------- 5. unspill-oscillation gate
def bench_unspill_oscillation() -> dict:
    """Paged vs whole-queue unspill under a steady saturating serving
    load against a tight §6 byte budget.

    Wholesale unspill pages a serviced adapter's whole spilled suffix
    back in one shot: on this load it re-exceeds the budget on every such
    round (``overshoot_rounds_after_unspill`` > 0) — it only *looks*
    cheap because it holds several times the budget resident.  The paged
    protocol stays within the budget and pays for it in repeated
    sigma-pro-rated T_spill surcharges while the backlog drains (the
    measured ``latency_cost_ratio``).  Gates: (a) the paged protocol's
    spill-bit flip count does not regress vs the whole-queue baseline
    (it must not *introduce* hysteresis oscillation), (b) no paged round
    that returned spilled work ends above the budget + the
    service-batch/oldest-unit floors — the §6 overshoot bugfix — while
    the wholesale baseline demonstrably does, (c) the paged protocol's
    makespan stays within 2.2x the budget-violating baseline (pins
    today's ~1.9x surcharge cost so silent latency regressions fail the
    nightly), and (d) all requests complete either way.
    """
    from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig

    budget = 2_000.0
    req_bytes = 100.0  # prompt_len 10 x kv_bytes_per_token 10
    max_batch = 4
    n_adapters = 4

    def trace():
        rng = np.random.default_rng(17)
        t, reqs = 0.0, []
        for i in range(240):  # steady ~500 req/s, ~24 kB of prompt state
            t += float(rng.exponential(0.002))
            reqs.append(Request(i, int(rng.integers(0, n_adapters)), t, 10, 32))
        return reqs

    def run_mode(wholesale):
        cfg = ServeConfig(
            policy="liferaft", adaptive=True, max_batch=max_batch,
            decode_quantum=16, spill_budget_bytes=budget,
            spill_penalty_s=0.05, kv_bytes_per_token=10.0,
            control_halflife_s=1.0, wholesale_unspill=wholesale,
        )
        eng = LifeRaftEngine(
            [AdapterSpec(a, 8 << 30) for a in range(n_adapters)], cfg
        )
        flips, prev_bit = 0, False
        overshoot_rounds, unspill_rounds = 0, 0
        prev_spilled = 0.0
        # Same floors formula as the pinning regression test
        # (tests/test_partial_spill.py TestWholesaleUnspillOvershoot._bound):
        # one serviced batch of spilled requests + one oldest-unit
        # no-starvation floor per adapter queue.
        bound = budget + (max_batch + n_adapters) * req_bytes

        def on_round(outcome):
            nonlocal flips, prev_bit, overshoot_rounds, unspill_rounds, prev_spilled
            if outcome.vector.spill != prev_bit:
                flips += 1
            prev_bit = outcome.vector.spill
            spilled = sum(
                q.spilled_bytes for q in eng.workload.queues.values()
            )
            if spilled < prev_spilled - 1e-9:
                unspill_rounds += 1
                if eng.workload.resident_bytes() > bound:
                    overshoot_rounds += 1
            prev_spilled = spilled

        eng.loop.on_round = on_round
        summary = eng.run(trace())
        return {
            "flips": flips,
            "unspill_rounds": unspill_rounds,
            "overshoot_rounds_after_unspill": overshoot_rounds,
            "n_completed": summary["n_completed"],
            "p95_response": summary["p95_response"],
            "makespan": summary["makespan"],
        }

    paged = run_mode(wholesale=False)
    wholesale = run_mode(wholesale=True)
    latency_cost = paged["makespan"] / max(wholesale["makespan"], 1e-9)
    return {
        "budget_bytes": budget,
        "paged": paged,
        "wholesale": wholesale,
        "flip_ratio": paged["flips"] / max(wholesale["flips"], 1),
        "latency_cost_ratio": latency_cost,
        "passes": bool(
            paged["flips"] <= wholesale["flips"]
            and paged["unspill_rounds"] > 0
            and paged["overshoot_rounds_after_unspill"] == 0
            and wholesale["overshoot_rounds_after_unspill"] > 0
            and latency_cost <= 2.2
            and paged["n_completed"] == wholesale["n_completed"] == 240
        ),
    }


# ------------------------------------------------ 3. fuse_k + spill (info)
def bench_fuse_and_spill() -> dict:
    rng = np.random.default_rng(11)
    qs, t = [], 0.0
    for qid in range(400):
        t += rng.exponential(0.01)
        b = rng.integers(0, 150)
        ks = np.full(int(rng.integers(2, 12)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    ctl = ControlLoop(ControlConfig(fuse_k_max=8, spill_budget_objects=600))
    r = simulate_batched(
        qs, _identity_range,
        LifeRaftScheduler(CostModel(T_spill=0.4), 0.25, normalized=True),
        CostModel(T_spill=0.4), cache_capacity=10, control=ctl,
    )
    return {
        "n_queries": r.n_queries,
        "batches": r.n_batches,
        "dispatches": r.n_dispatches,
        "amortization": r.n_batches / max(r.n_dispatches, 1),
        "final_fuse_k": ctl.last.fuse_k if ctl.last else 1,
        "all_completed": r.n_queries == len(qs),
    }


def run(out_path: str = "BENCH_adaptive.json", verbose: bool = True) -> dict:
    report = {
        "closed_loop_vs_static": bench_closed_loop(),
        "normalized_equivalence": bench_normalized_equivalence(),
        "fuse_and_spill": bench_fuse_and_spill(),
        "two_tenant": bench_two_tenant(),
        "unspill_oscillation": bench_unspill_oscillation(),
    }
    cl = report["closed_loop_vs_static"]
    eq = report["normalized_equivalence"]
    fs = report["fuse_and_spill"]
    tt = report["two_tenant"]
    uo = report["unspill_oscillation"]
    pathlib.Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    if verbose:
        ad, best = cl["adaptive"], cl["best_static"]
        print(
            f"  closed-loop: p95={ad['p95_response']:.1f}s vs best static "
            f"alpha={cl['best_static_alpha']} p95={best['p95_response']:.1f}s "
            f"(improvement {cl['p95_improvement_s']:+.1f}s) at "
            f"{cl['throughput_ratio']:.2f}x best static throughput"
        )
        print(
            f"  normalized equivalence: {eq['decisions']} decisions, "
            f"{eq['mismatches']} mismatches"
        )
        print(
            f"  fuse/spill: {fs['batches']} batches in {fs['dispatches']} "
            f"dispatches ({fs['amortization']:.1f}x amortized), "
            f"final fuse_k={fs['final_fuse_k']}"
        )
        print(
            f"  two-tenant: interactive p95 {tt['tenant_interactive_p95']:.2f}s"
            f" (per-tenant) vs {tt['global_interactive_p95']:.2f}s (global) at"
            f" {tt['throughput_ratio']:.2f}x throughput; spill within budget:"
            f" {tt['spill_within_budget']}"
        )
        print(
            f"  unspill oscillation: {uo['paged']['flips']} spill-bit flips"
            f" (paged) vs {uo['wholesale']['flips']} (whole-queue);"
            f" overshoot rounds after unspill:"
            f" {uo['paged']['overshoot_rounds_after_unspill']} (paged) vs"
            f" {uo['wholesale']['overshoot_rounds_after_unspill']} (whole-queue)"
        )
        print(f"  wrote {out_path}")
    emit(
        "bench_adaptive",
        0.0,
        f"p95_improvement={cl['p95_improvement_s']:.2f}s;"
        f"throughput_ratio={cl['throughput_ratio']:.3f};"
        f"mismatches={eq['mismatches']};"
        f"tenant_p95={tt['tenant_interactive_p95']:.2f}s;"
        f"tenant_tp_ratio={tt['throughput_ratio']:.3f}",
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_adaptive.json")
    # Tolerate stray argv (argparse's SystemExit would kill benchmarks.run).
    args, _ = ap.parse_known_args()
    report = run(args.out)
    cl = report["closed_loop_vs_static"]
    assert cl["passes"], cl
    assert cl["throughput_ratio"] >= 0.9
    assert cl["p95_improvement_s"] > 0
    assert report["normalized_equivalence"]["bit_identical"]
    assert report["fuse_and_spill"]["all_completed"]
    assert report["fuse_and_spill"]["dispatches"] < report["fuse_and_spill"]["batches"]
    tt = report["two_tenant"]
    assert tt["passes"], tt
    assert tt["tenant_interactive_p95"] <= tt["global_interactive_p95"]
    assert tt["throughput_ratio"] >= 0.95
    assert tt["spill_within_budget"]
    uo = report["unspill_oscillation"]
    assert uo["passes"], uo
    assert uo["paged"]["flips"] <= uo["wholesale"]["flips"]
    assert uo["paged"]["overshoot_rounds_after_unspill"] == 0


if __name__ == "__main__":
    main()

"""Paper Fig. 6: cumulative workload by bucket.  Paper: 2% of buckets
capture 50% of the workload; the long tail is what starves under greedy."""
from __future__ import annotations

import numpy as np

from .common import emit
from .fig5_bucket_reuse import stats


def run(verbose: bool = True) -> dict:
    s, cat, trace = stats()
    load = np.sort(s["load"])[::-1].astype(np.float64)
    csum = np.cumsum(load) / max(load.sum(), 1)
    marks = {}
    for frac in (0.25, 0.5, 0.75, 0.9):
        k = int(np.searchsorted(csum, frac)) + 1
        marks[frac] = k / cat.n_buckets
    if verbose:
        for frac, bucket_frac in marks.items():
            print(f"  {bucket_frac:7.2%} of buckets capture {frac:.0%} of workload")
        print(f"  (paper: 2% of buckets capture 50%)  gini={s['gini_load']:.3f}")
    emit(
        "fig6_workload_cdf", 0.0,
        f"bucket_frac_for_50pct={marks[0.5]:.4f};paper=0.02;gini={s['gini_load']:.3f}",
    )
    return marks


def main() -> None:
    run()


if __name__ == "__main__":
    main()

"""Paper Fig. 2: non-indexed scan vs spatial-index join speed-up as a
function of workload-queue size.

Two views:
  (a) the paper's cost model (T_b=1.2s, T_m=0.13ms, T_probe=4.13ms):
      break-even at |W| ~ 3% of a 10k-object bucket, up to ~20x gap;
  (b) real compute on this machine: the batched cross-match kernel (scan)
      vs per-probe gathered neighborhoods (indexed) over a 10k-object
      bucket — wall-clock microseconds, break-even reported.
"""
from __future__ import annotations

import numpy as np

from repro.core import HybridPlanner
from repro.core.sfc import htm_id, unit_vectors
from repro.kernels.crossmatch import ops as cm_ops

from .common import HYBRID_COST, emit, time_call

BUCKET = 10_000
NEIGHBORHOOD = 64


def model_view(verbose=True):
    planner = HybridPlanner(HYBRID_COST, objects_per_bucket=BUCKET)
    be = HYBRID_COST.break_even_queue()
    rows = []
    for w in (10, 30, 100, 300, 1000, 3000, 10000):
        scan = HYBRID_COST.scan_cost(w, in_cache=False)
        idx = HYBRID_COST.indexed_cost(w)
        rows.append((w, idx / scan, planner.plan(w, False).strategy))
        if verbose:
            print(f"  |W|={w:6d}  index/scan={idx / scan:6.2f}x  plan={rows[-1][2]}")
    if verbose:
        print(f"  analytic break-even |W|*={be:.0f} ({be / BUCKET:.1%} of bucket; paper ~3%)")
    return be, rows


def measured_view(verbose=True):
    rng = np.random.default_rng(0)
    bucket = unit_vectors(BUCKET, seed=1).astype(np.float32)
    order = np.argsort(htm_id(bucket, level=10), kind="stable")
    bucket = bucket[order]
    thr = float(np.cos(0.01))
    results = []
    for w in (8, 64, 256, 1024):
        probes = bucket[rng.integers(0, BUCKET, w)] + 1e-4
        probes /= np.linalg.norm(probes, axis=1, keepdims=True)
        # scan: one batched pass over the whole bucket
        t_scan = time_call(
            lambda: cm_ops.crossmatch(bucket, probes, thr, use_pallas=False)[0]
        )
        # indexed: per-probe gathered neighborhood (random access pattern)
        idx0 = rng.integers(0, BUCKET - NEIGHBORHOOD, w)
        gathered = np.stack([bucket[i : i + NEIGHBORHOOD] for i in idx0])

        def indexed():
            outs = []
            for i in range(w):  # per-probe random probes — the index path
                outs.append(
                    cm_ops.crossmatch(gathered[i], probes[i : i + 1], thr,
                                      use_pallas=False)[0]
                )
            return outs

        t_idx = time_call(indexed, reps=3, warmup=1)
        results.append((w, t_scan, t_idx))
        if verbose:
            print(
                f"  |W|={w:5d}  scan={t_scan:10.0f}us  indexed={t_idx:10.0f}us  "
                f"ratio={t_idx / t_scan:6.2f}x -> {'scan' if t_scan < t_idx else 'indexed'}"
            )
    return results


def run(verbose: bool = True):
    if verbose:
        print(" cost-model view (paper constants):")
    be, _ = model_view(verbose)
    if verbose:
        print(" measured view (CPU, jnp path):")
    meas = measured_view(verbose)
    emit(
        "fig2_hybrid_join",
        meas[-1][1],
        f"break_even_frac={be / BUCKET:.4f};paper=0.03",
    )
    return be, meas


def main() -> None:
    run()


if __name__ == "__main__":
    main()

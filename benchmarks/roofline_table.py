"""Summarize dry-run artifacts into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import json
import pathlib

from .common import emit

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_cells(mesh: str = "16x16", tag: str | None = None) -> list[dict]:
    cells = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("mesh") != mesh:
            continue
        tagged = "__" in f.stem.replace(
            f"{r['arch']}__{r['shape']}__{r['mesh']}", ""
        )
        if tag is None and tagged:
            continue
        if tag is not None and not f.stem.endswith(f"__{tag}"):
            continue
        cells.append(r)
    return cells


def fmt_row(r: dict) -> str:
    dom = r["dominant"][:4]
    return (
        f"  {r['arch']:26s} {r['shape']:12s} "
        f"tc={r['t_compute_s']:9.4f}s tm={r['t_memory_s']:9.4f}s "
        f"tx={r['t_collective_s']:9.4f}s dom={dom:4s} "
        f"useful={r.get('useful_flop_ratio', 0):6.3f} "
        f"mfu_ub={r.get('mfu_upper_bound', 0):6.3f}"
    )


def main() -> None:
    single = load_cells("16x16")
    multi = load_cells("2x16x16")
    if not single:
        print("  (no dry-run artifacts yet — run scripts/run_dryrun_all.sh)")
        emit("roofline_table", 0.0, "cells=0")
        return
    print(f"  single-pod cells: {len(single)}; multi-pod cells: {len(multi)}")
    by_dom = {}
    for r in single:
        by_dom.setdefault(r["dominant"], []).append(r)
        print(fmt_row(r))
    doms = {k: len(v) for k, v in by_dom.items()}
    worst = min(single, key=lambda r: r.get("mfu_upper_bound", 0))
    most_coll = max(single, key=lambda r: r["t_collective_s"] / max(
        r["t_compute_s"] + r["t_memory_s"] + r["t_collective_s"], 1e-12))
    print(f"  dominant-term histogram: {doms}")
    print(f"  worst mfu_upper_bound: {worst['arch']}/{worst['shape']}"
          f" = {worst.get('mfu_upper_bound', 0):.4f}")
    print(f"  most collective-bound: {most_coll['arch']}/{most_coll['shape']}")
    emit(
        "roofline_table", 0.0,
        f"single={len(single)};multi={len(multi)};doms={doms}",
    )


if __name__ == "__main__":
    main()

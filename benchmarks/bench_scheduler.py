"""BENCH_scheduler: the scheduling->execution hot path, before vs after.

Emits ``BENCH_scheduler.json`` with three measurements:

1. ``select_at_1k_buckets`` — per-decision cost of ``select()`` with ~1k
   nonempty bucket queues under submit churn: the naive O(B) rescan vs the
   incremental lazy-heap index (acceptance: >= 5x).
2. ``decision_equivalence`` — both schedulers replay the same 500-query
   SkyQuery-style trace in lockstep; every decision (bucket id AND score)
   must be bit-identical (acceptance: 0 mismatches).
3. ``compile_count`` — ``_crossmatch_jit`` shapes compiled while the
   cross-match engine runs the 500-query trace with power-of-two shape
   bucketing (acceptance: <= log2(max probe batch) + 1).

Run: ``PYTHONPATH=src python -m benchmarks.bench_scheduler [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import numpy as np

from repro.core import (
    BucketCache,
    CostModel,
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
    PAPER_COST_MODEL,
)
from repro.core.workload import Query, WorkloadManager
from repro.crossmatch import CrossMatchEngine, TraceConfig, make_catalog, make_trace
from repro.kernels.crossmatch import ops as cm_ops

from .common import emit


# ---------------------------------------------------------------- 1. select cost
def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _loaded_manager(n_buckets=1000, n_queries=3000, seed=0):
    wm = WorkloadManager(_identity_range)
    rng = np.random.default_rng(seed)
    for qid in range(n_queries):
        ks = rng.integers(0, int(n_buckets * 1.1), 5).astype(np.uint64)
        wm.submit(Query(qid, qid * 1e-3, ks, ks))
    return wm


def bench_select(n_buckets=1000, rounds=200, alpha=0.3) -> dict:
    out = {}
    for label, cls in (("naive", NaiveLifeRaftScheduler), ("incremental", LifeRaftScheduler)):
        wm = _loaded_manager(n_buckets)
        cache = BucketCache(20)
        sched = cls(CostModel(), alpha=alpha)
        rng = np.random.default_rng(1)
        sched.select(wm, cache, 3.0)  # bind / warm
        elapsed = 0.0
        qid = 10_000
        for r in range(rounds):
            now = 3.0 + r * 1e-3
            t0 = time.perf_counter()
            d = sched.select(wm, cache, now)
            elapsed += time.perf_counter() - t0
            # churn between decisions: a submit and a completion
            ks = rng.integers(0, 1100, 5).astype(np.uint64)
            wm.submit(Query(qid, now, ks, ks))
            qid += 1
            if r % 4 == 3:
                cache.access(d.bucket_id)
                wm.complete_bucket(d.bucket_id, now)
        out[f"{label}_us"] = elapsed / rounds * 1e6
        out[f"{label}_nonempty_buckets"] = len(wm.nonempty_queues())
    out["speedup"] = out["naive_us"] / out["incremental_us"]
    return out


# ------------------------------------------------------- 2. decision equivalence
def bench_equivalence(n_queries=500) -> dict:
    cat = make_catalog(n_objects=40_000, objects_per_bucket=128, htm_level=7, seed=3)
    trace = make_trace(
        cat,
        TraceConfig(n_queries=n_queries, arrival_rate=0.5, objects_median=150,
                    seed=17),
    )
    cost = PAPER_COST_MODEL
    sides = {}
    for label, cls in (("inc", LifeRaftScheduler), ("nai", NaiveLifeRaftScheduler)):
        sides[label] = dict(
            sched=cls(cost, alpha=0.25),
            wm=WorkloadManager(cat.partitioner.buckets_for_range,
                               cat.partitioner.bucket_of_keys),
            cache=BucketCache(20),
        )
    queries = sorted(trace, key=lambda q: q.arrival_time)
    clock, i, decisions, mismatches = 0.0, 0, 0, 0
    wm_i = sides["inc"]["wm"]
    while i < len(queries) or wm_i.n_pending_queries:
        if not wm_i.nonempty_queues():
            clock = max(clock, queries[i].arrival_time)
        while i < len(queries) and queries[i].arrival_time <= clock:
            for s in sides.values():
                s["wm"].submit(queries[i])
            i += 1
        ds = {
            k: s["sched"].select(s["wm"], s["cache"], clock)
            for k, s in sides.items()
        }
        if ds["inc"] is None and ds["nai"] is None:
            continue
        decisions += 1
        if ds["inc"] is None or ds["nai"] is None:
            # One-sided idle is itself a divergence; report it, don't crash.
            mismatches += 1
            break
        if (
            ds["inc"].bucket_id != ds["nai"].bucket_id
            or ds["inc"].score != ds["nai"].score
        ):
            mismatches += 1
        d = ds["nai"]
        step = cost.batch_cost(d.queue_size, d.in_cache)
        clock += step
        for k, s in sides.items():
            s["cache"].access(ds[k].bucket_id)
            s["wm"].complete_bucket(ds[k].bucket_id, clock)
    return {
        "trace_queries": n_queries,
        "decisions": decisions,
        "mismatches": mismatches,
        "bit_identical": mismatches == 0,
    }


# ---------------------------------------------------------- 3. compile counting
def bench_compiles(n_queries=500) -> dict:
    cat = make_catalog(n_objects=20_000, objects_per_bucket=128, htm_level=7, seed=5)
    trace = make_trace(
        cat,
        TraceConfig(n_queries=n_queries, arrival_rate=1.0, objects_median=120,
                    seed=23),
    )
    before = cm_ops.jit_cache_size()
    eng = CrossMatchEngine(cat, match_radius_rad=2e-3)
    eng.run(trace)
    shapes = cm_ops.jit_cache_size() - before
    max_probes = max(eng.max_probe_batch, 2)
    bound = int(math.log2(1 << (max_probes - 1).bit_length())) + 1
    return {
        "trace_queries": n_queries,
        "batches": eng.batches,
        "max_probe_batch": max_probes,
        "shapes_compiled": shapes,
        "bound_log2_max_probes_plus_1": bound,
        "within_bound": 0 <= shapes <= bound,
    }


# ------------------------------------------------------------- 4. fused dispatch
def bench_fused(n_queries=120) -> dict:
    cat = make_catalog(n_objects=20_000, objects_per_bucket=128, htm_level=7, seed=5)
    trace = make_trace(
        cat,
        TraceConfig(n_queries=n_queries, arrival_rate=1.0, objects_median=120,
                    seed=29),
    )
    out = {}
    for k in (1, 4):
        eng = CrossMatchEngine(cat, match_radius_rad=2e-3, fuse_k=k)
        t0 = time.perf_counter()
        eng.run(trace)
        out[f"fuse_k={k}"] = {
            "batches": eng.batches,
            "dispatches": eng.dispatches,
            "wall_s": round(time.perf_counter() - t0, 3),
        }
    return out


def run(out_path: str = "BENCH_scheduler.json", verbose: bool = True) -> dict:
    report = {
        "select_at_1k_buckets": bench_select(),
        "decision_equivalence": bench_equivalence(),
        "compile_count": bench_compiles(),
        "fused_dispatch": bench_fused(),
    }
    sel = report["select_at_1k_buckets"]
    eq = report["decision_equivalence"]
    cc = report["compile_count"]
    pathlib.Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    if verbose:
        print(
            f"  select@1k: naive={sel['naive_us']:.1f}us "
            f"incremental={sel['incremental_us']:.1f}us "
            f"speedup={sel['speedup']:.1f}x"
        )
        print(
            f"  equivalence: {eq['decisions']} decisions, "
            f"{eq['mismatches']} mismatches"
        )
        print(
            f"  compiles: {cc['shapes_compiled']} shapes "
            f"(bound {cc['bound_log2_max_probes_plus_1']}, "
            f"max batch {cc['max_probe_batch']})"
        )
        print(f"  wrote {out_path}")
    emit(
        "bench_scheduler",
        sel["incremental_us"],
        f"speedup={sel['speedup']:.1f}x;mismatches={eq['mismatches']};"
        f"shapes={cc['shapes_compiled']}/{cc['bound_log2_max_probes_plus_1']}",
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scheduler.json")
    # Tolerate stray argv (argparse's SystemExit would kill benchmarks.run).
    args, _ = ap.parse_known_args()
    report = run(args.out)
    assert report["select_at_1k_buckets"]["speedup"] >= 5.0
    assert report["decision_equivalence"]["bit_identical"]
    assert report["compile_count"]["within_bound"]


if __name__ == "__main__":
    main()

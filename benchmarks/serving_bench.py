"""Beyond-paper: LifeRaft continuous batching for multi-tenant LLM serving.

Buckets = LoRA-adapter weight groups (8 GB tenant state), cache = 4 HBM
slots, trace = Zipf tenant popularity with Poisson arrivals.  Compares
NoShare (per-request FCFS), RR, LifeRaft greedy / aged — same four systems
as the paper's Fig. 7, on the serving side."""
from __future__ import annotations

import numpy as np

from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig

from .common import emit


def make_requests(n=600, n_adapters=16, rate=150.0, zipf=1.4, seed=5):
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_adapters + 1) ** zipf
    w /= w.sum()
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        out.append(
            Request(
                request_id=i,
                adapter_id=int(rng.choice(n_adapters, p=w)),
                arrival_time=t,
                prompt_len=int(rng.integers(16, 256)),
                max_new_tokens=32,
            )
        )
    return out


def run(verbose: bool = True) -> dict:
    adapters = [AdapterSpec(i, 8 << 30) for i in range(16)]
    rows = {}
    for policy, alpha in [
        ("noshare", 0.0), ("rr", 0.0),
        ("liferaft", 0.0), ("liferaft", 0.25), ("liferaft", 1.0),
    ]:
        eng = LifeRaftEngine(
            adapters, ServeConfig(policy=policy, alpha=alpha, adapter_slots=4)
        )
        s = eng.run(make_requests())
        key = f"{policy}(a={alpha})" if policy == "liferaft" else policy
        rows[key] = s
        if verbose:
            print(
                f"  {key:16s} tok/s={s['token_throughput']:9.1f} "
                f"resp={s['mean_response']:7.3f}s p95={s['p95_response']:7.3f}s "
                f"hit={s['cache_hit_rate']:5.3f} batches={s['batches']} "
                f"indexed={s['indexed_batches']}"
            )
    speedup = rows["liferaft(a=0.0)"]["token_throughput"] / max(
        rows["noshare"]["token_throughput"], 1e-9
    )
    emit("serving_bench", 0.0, f"liferaft/noshare_tokens={speedup:.2f}x")
    return rows


def main() -> None:
    run()


if __name__ == "__main__":
    main()

"""BENCH_shard: multi-shard execution tier vs the single dispatch loop.

Emits ``BENCH_shard.json`` with three gated measurements:

1. ``shard_scaling`` — a saturating trace through ``simulate_sharded`` at
   S=1 and S=4 with EQUAL aggregate cache bytes (each shard gets 1/S of
   the slots).  Acceptance: >= 3.0x simulated throughput at S=4.
2. ``steal_conservation`` — a skewed trace (one hot SFC range) at S=4
   with work stealing on: every submitted query must complete exactly
   once — no completion lost to a migration, none double-counted by the
   cross-shard join — and the run must actually migrate buckets
   (acceptance: 0 lost / 0 duplicated, steals > 0).
3. ``s1_bit_identity`` — ``simulate_sharded(S=1)`` vs the
   ``simulate_batched`` oracle replaying the same trace: the decision
   logs (bucket, score, residency, queue size, cost, vector, spill
   transitions) must be bit-identical (acceptance: 0 mismatches).

Run: ``PYTHONPATH=src python -m benchmarks.bench_shard [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core import (
    CostModel,
    LifeRaftScheduler,
    StealConfig,
    simulate_batched,
    simulate_sharded,
)
from repro.core.workload import Query

from .common import emit

SCALING_GATE = 3.0


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _trace(seed, n=400, buckets=64, gap=0.004, depth=(20, 120), skew=False):
    """Saturating trace: arrivals far denser than service, so makespan is
    compute-bound and shard parallelism is visible.  ``skew`` biases
    bucket popularity quadratically toward the low SFC range — the
    imbalance the steal gate needs."""
    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(n):
        t += float(rng.exponential(gap))
        b = int(rng.integers(0, buckets))
        if skew:
            b = b * b // buckets
        ks = np.full(int(rng.integers(*depth)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    return qs


# --------------------------------------------------------- 1. shard scaling
def bench_scaling(seed=13) -> dict:
    cost = CostModel(T_b=0.08, T_m=2e-4)
    qs = _trace(seed)
    out = {}
    for S in (1, 4):
        r = simulate_sharded(
            qs, _identity_range, cost,
            scheduler_factory=lambda: LifeRaftScheduler(cost, alpha=0.25),
            n_shards=S, cache_capacity=16,
        )
        out[f"S{S}"] = {
            "policy": r.policy,
            "makespan": r.makespan,
            "query_throughput": r.query_throughput,
            "object_throughput": r.object_throughput,
            "cache_hit_rate": r.cache_hit_rate,
        }
    gain = out["S4"]["object_throughput"] / out["S1"]["object_throughput"]
    return {
        "trace_queries": len(qs),
        "aggregate_cache_slots": 16,
        **out,
        "throughput_gain": gain,
        "gate": SCALING_GATE,
        "passed": gain >= SCALING_GATE,
    }


# ----------------------------------------------------- 2. steal conservation
def bench_steal_conservation(seed=29) -> dict:
    cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)
    qs = _trace(seed, n=240, gap=0.01, depth=(5, 60), skew=True)
    steals = []
    completions: list[int] = []
    r = simulate_sharded(
        qs, _identity_range, cost,
        scheduler_factory=lambda: LifeRaftScheduler(cost, alpha=0.25),
        n_shards=4, cache_capacity=16,
        steal=StealConfig(low_water_bytes=0.0),
        on_steal=steals.append,
        on_round=lambda sid, o: completions.append(sid),
    )
    submitted = {q.query_id for q in qs}
    # simulate_sharded's response map holds exactly the completed queries;
    # a dict can't double-count, so duplicates show up as a shortfall in
    # n_queries vs the submitted set, and losses the same way.
    lost = len(submitted) - r.n_queries
    return {
        "trace_queries": len(qs),
        "n_completed": r.n_queries,
        "lost": lost,
        "steals": len(steals),
        "stolen_units": sum(ev.n_units for ev in steals),
        "stolen_bytes": sum(ev.nbytes for ev in steals),
        "reclaimed_stage_s": sum(ev.reclaimed_stage_s for ev in steals),
        "makespan": r.makespan,
        "passed": lost == 0 and len(steals) > 0,
    }


# -------------------------------------------------------- 3. S=1 bit identity
def bench_s1_identity(seed=37, n=200) -> dict:
    """The composability proof the tentpole rests on: one shard, same
    trace, same cost model — the sharded coordinator's decision log must
    be bit-identical to the single-loop oracle's."""
    cost = CostModel(T_b=0.08, T_m=2e-4)
    qs = _trace(seed, n=n, gap=0.02, depth=(5, 80))

    def entry(outcome):
        return (
            tuple(
                (d.bucket_id, d.score, d.in_cache, d.queue_size)
                for d in outcome.decisions
            ),
            outcome.cost,
            (outcome.vector.alpha, outcome.vector.fuse_k, outcome.vector.spill),
            tuple(outcome.spill_changed),
        )

    oracle: list = []
    simulate_batched(
        qs, _identity_range, LifeRaftScheduler(cost, alpha=0.25), cost,
        cache_capacity=8, fuse_k=2,
        on_round=lambda o: oracle.append(entry(o)),
    )
    sharded: list = []
    simulate_sharded(
        qs, _identity_range, cost,
        scheduler_factory=lambda: LifeRaftScheduler(cost, alpha=0.25),
        n_shards=1, cache_capacity=8, fuse_k=2,
        on_round=lambda sid, o: sharded.append(entry(o)),
    )
    mismatches = sum(1 for e, g in zip(oracle, sharded) if e != g)
    mismatches += abs(len(oracle) - len(sharded))
    return {
        "trace_queries": n,
        "rounds": len(oracle),
        "mismatches": mismatches,
        "bit_identical": mismatches == 0,
    }


def run(out_path: str = "BENCH_shard.json", verbose: bool = True) -> dict:
    report = {
        "shard_scaling": bench_scaling(),
        "steal_conservation": bench_steal_conservation(),
        "s1_bit_identity": bench_s1_identity(),
    }
    sc = report["shard_scaling"]
    st = report["steal_conservation"]
    bi = report["s1_bit_identity"]
    pathlib.Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    if verbose:
        print(
            f"  scaling: {sc['throughput_gain']:.2f}x at S=4 vs S=1 "
            f"(gate {sc['gate']}x, equal aggregate cache)"
        )
        print(
            f"  stealing: {st['steals']} migrations, "
            f"{st['stolen_units']} units moved, {st['lost']} lost"
        )
        print(
            f"  S=1 identity: {bi['rounds']} rounds, "
            f"{bi['mismatches']} mismatches"
        )
        print(f"  wrote {out_path}")
    emit(
        "bench_shard",
        sc["throughput_gain"],
        f"gain={sc['throughput_gain']:.2f}x;steals={st['steals']};"
        f"mismatches={bi['mismatches']}",
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_shard.json")
    # Tolerate stray argv (argparse's SystemExit would kill benchmarks.run).
    args, _ = ap.parse_known_args()
    report = run(args.out)
    assert report["shard_scaling"]["passed"], report["shard_scaling"]
    assert report["steal_conservation"]["passed"], report["steal_conservation"]
    assert report["s1_bit_identity"]["bit_identical"], report["s1_bit_identity"]


if __name__ == "__main__":
    main()

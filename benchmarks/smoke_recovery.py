"""Kill -9 recovery smoke for the durable service tier.

The headline durability gate, as a runnable check:

1. run the serving daemon's driver uninterrupted in-process → golden
   decision log;
2. spawn a child process running the SAME driver over a write-ahead
   journal, throttled on the wall clock so the flood takes a few seconds;
3. ``SIGKILL`` the child mid-flood (no atexit, no flushing grace);
4. recover a fresh daemon over the killed journal and re-run the driver;
5. assert every submission the child acked completes, and the recovered
   decision log is **bit-identical** to the uninterrupted golden.

On failure the journal directory is left in place (CI uploads it as an
artifact); on success it is removed.

Usage::

    PYTHONPATH=src python -m benchmarks.smoke_recovery            # full smoke
    PYTHONPATH=src python -m benchmarks.smoke_recovery --n 60     # quicker
"""
from __future__ import annotations

import argparse
import os
import pathlib
import shutil
import signal
import subprocess
import sys
import tempfile
import time

_REPO = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------- scenario
def _adapters():
    from repro.serving import AdapterSpec

    return [
        AdapterSpec(
            a,
            nbytes=(a + 1) * 1_000_000,
            tenant="interactive" if a % 2 else "batch",
        )
        for a in range(8)
    ]


def _trace(n: int):
    from repro.serving import Request

    return [
        Request(
            request_id=i,
            adapter_id=(i * 5) % 8,
            arrival_time=0.01 * i,
            prompt_len=32 + (i % 7) * 16,
            max_new_tokens=48,
        )
        for i in range(n)
    ]


def build_daemon(journal_dir):
    from repro.serving import (
        LifeRaftEngine,
        ServeConfig,
        ServiceDaemon,
        ServingHost,
    )

    cfg = ServeConfig(adapter_slots=5, fuse_k=2, adaptive=True)
    return ServiceDaemon(
        ServingHost(LifeRaftEngine(_adapters(), cfg)), journal_dir
    )


def drive(daemon, requests, throttle_s: float = 0.0) -> None:
    """The daemon driver: decode up to each arrival, then durably submit.
    ``throttle_s`` slows the *wall* clock only — the virtual clock, and
    therefore every decision, is unaffected."""
    for r in requests:
        daemon.pump(until=r.arrival_time)
        daemon.submit(r)
        if throttle_s:
            time.sleep(throttle_s)
    daemon.pump()


# ---------------------------------------------------------------- child
def run_child(journal_dir, n: int, throttle_s: float) -> int:
    daemon = build_daemon(journal_dir)
    drive(daemon, _trace(n), throttle_s)
    daemon.close()
    return 0


# ---------------------------------------------------------------- parent
def run_parent(journal_dir, n: int, throttle_s: float,
               keep: bool = False) -> int:
    from repro.core import diff_entries

    journal_dir = pathlib.Path(journal_dir).resolve()
    if journal_dir.exists():
        shutil.rmtree(journal_dir)

    # 1. uninterrupted golden, in-process
    golden_dir = tempfile.mkdtemp(prefix="smoke-recovery-golden-")
    golden = build_daemon(golden_dir)
    drive(golden, _trace(n))
    golden.close()
    shutil.rmtree(golden_dir)
    print(
        f"golden: {len(golden.entries)} rounds, "
        f"{len(golden.completed())} completed"
    )

    # 2. throttled child over the real journal
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    child = subprocess.Popen(
        [
            sys.executable, "-m", "benchmarks.smoke_recovery", "--child",
            "--dir", str(journal_dir), "--n", str(n),
            "--throttle", str(throttle_s),
        ],
        cwd=str(_REPO),
        env=env,
    )

    # 3. SIGKILL once the journal shows a healthy mid-flood prefix
    def journal_bytes() -> int:
        if not journal_dir.exists():
            return 0
        return sum(p.stat().st_size for p in journal_dir.glob("seg-*.jsonl"))

    deadline = time.time() + 120.0
    target = 2_000  # a handful of acked submissions + rounds
    while (
        time.time() < deadline
        and child.poll() is None
        and journal_bytes() < target
    ):
        time.sleep(0.01)
    if child.poll() is None:
        os.kill(child.pid, signal.SIGKILL)
        child.wait()
        print(f"killed child mid-flood at {journal_bytes()} journal bytes")
    else:
        print("child exited before the kill; recovery still exercised")

    # 4. recover + finish the trace with the same driver
    recovered = build_daemon(journal_dir)
    acked_in_journal = set(recovered.acked)
    print(
        f"recovered: {len(recovered.entries)} rounds replayed, "
        f"{len(acked_in_journal)} acked submissions"
    )
    drive(recovered, _trace(n))
    recovered.close()

    # 5. the gate
    failures = []
    diff = diff_entries(golden.entries, recovered.entries)
    if diff:
        failures.append(
            "decision log diverged from the uninterrupted run:\n"
            + "\n".join(diff)
        )
    completed = recovered.completed()
    missing = sorted(
        k for k in acked_in_journal
        if int(k.rsplit("-", 1)[1]) not in completed
    )
    if missing:
        failures.append(f"acked but never completed: {missing[:10]}")
    if failures:
        print("FAIL: kill -9 recovery smoke", file=sys.stderr)
        for f in failures:
            print(f, file=sys.stderr)
        print(
            f"journal left at {journal_dir} for inspection", file=sys.stderr
        )
        return 1
    print(
        f"OK: {len(acked_in_journal)} acked pre-kill, "
        f"{len(completed)} completed post-recovery, decisions bit-identical"
    )
    if not keep:
        shutil.rmtree(journal_dir)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true",
                    help="internal: run the to-be-killed driver")
    ap.add_argument("--dir", default="smoke_recovery_journal",
                    help="journal directory (left behind on failure)")
    ap.add_argument("--n", type=int, default=150, help="trace length")
    ap.add_argument("--throttle", type=float, default=0.02,
                    help="child wall-clock delay per submission (s)")
    ap.add_argument("--keep", action="store_true",
                    help="keep the journal directory on success too")
    args = ap.parse_args(argv)
    if args.child:
        return run_child(args.dir, args.n, args.throttle)
    return run_parent(args.dir, args.n, args.throttle, keep=args.keep)


if __name__ == "__main__":
    sys.exit(main())

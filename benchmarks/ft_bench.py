"""Fault-tolerance benchmark: goodput under failures and stragglers at
simulated 256-worker scale — checkpoint/restart + backup-task mitigation."""
from __future__ import annotations

from repro.dist import simulate_training_with_failures

from .common import emit


def run(verbose: bool = True) -> dict:
    rows = {}
    base = dict(n_steps=1000, n_workers=256, step_time=1.0,
                checkpoint_every=50, seed=3)
    for name, kw in [
        ("clean", dict(failure_rate=0.0, straggler_rate=0.0)),
        ("failures", dict(failure_rate=2e-7, straggler_rate=0.0)),
        ("stragglers_nobackup", dict(failure_rate=0.0, straggler_rate=0.05,
                                     straggler_slowdown=6.0, backup_tasks=False)),
        ("stragglers_backup", dict(failure_rate=0.0, straggler_rate=0.05,
                                   straggler_slowdown=6.0, backup_tasks=True)),
        ("both", dict(failure_rate=2e-7, straggler_rate=0.05,
                      straggler_slowdown=6.0, backup_tasks=True)),
    ]:
        r = simulate_training_with_failures(**base, **kw)
        goodput = r.steps_done / r.wall_time
        rows[name] = (r, goodput)
        if verbose:
            print(
                f"  {name:22s} wall={r.wall_time:8.0f}s goodput={goodput:6.3f} steps/s "
                f"failures={r.n_failures} lost={r.lost_steps} "
                f"stragglers={r.n_straggler_steps} backups={r.n_backup_dispatches}"
            )
    mit = rows["stragglers_backup"][1] / rows["stragglers_nobackup"][1]
    emit("ft_bench", 0.0, f"backup_task_goodput_gain={mit:.2f}x")
    return rows


def main() -> None:
    run()


if __name__ == "__main__":
    main()

"""Paper Fig. 7: query throughput + response time by scheduling algorithm.

Claims validated (paper §5.2):
  * LifeRaft greedy (alpha=0) >= ~2x NoShare query throughput (Fig. 7a)
  * RR ~ LifeRaft(alpha=1) throughput (neither models contention)
  * NoShare has the WORST mean response time (Fig. 7b)
  * greedy response ~ 2x the pure age-based scheduler (last-mile effect)
  * cache hit-rate gap: ~40% (alpha=0) vs ~7% (alpha=1) (paper §6)
"""
from __future__ import annotations

from repro.core import run_policy

from .common import CACHE_CAPACITY, COST, emit, workload


def run(verbose: bool = True) -> dict:
    cat, trace = workload()
    bor = cat.partitioner.buckets_for_range
    rows = {}
    plans = [("noshare", 0.0), ("rr", 0.0)] + [
        ("liferaft", a) for a in (0.0, 0.25, 0.5, 0.75, 1.0)
    ]
    for pol, a in plans:
        r = run_policy(pol, trace, bor, COST, alpha=a, cache_capacity=CACHE_CAPACITY,
                       bucket_of_keys=cat.partitioner.bucket_of_keys)
        rows[r.policy] = r
        if verbose:
            print(
                f"  {r.policy:18s} qtp={r.query_throughput:7.4f}/s "
                f"resp={r.mean_response:9.1f}s p95={r.p95_response:9.1f}s "
                f"std={r.std_response:8.1f} hit={r.cache_hit_rate:5.3f} "
                f"batches={r.n_batches}"
            )
    g, ns = rows["liferaft(a=0)"], rows["noshare"]
    ordered, rr = rows["liferaft(a=1)"], rows["rr"]
    derived = (
        f"greedy/noshare_throughput={g.query_throughput / ns.query_throughput:.2f}x;"
        f"rr_vs_a1={rr.query_throughput / ordered.query_throughput:.2f};"
        f"noshare_worst_resp={ns.mean_response >= max(r.mean_response for r in rows.values()) - 1e-9};"
        f"greedy_resp/a1_resp={g.mean_response / max(ordered.mean_response, 1e-9):.2f};"
        f"hit_a0={g.cache_hit_rate:.2f};hit_a1={ordered.cache_hit_rate:.2f}"
    )
    emit("fig7_schedulers", 0.0, derived)
    return rows


def main() -> None:
    run()


if __name__ == "__main__":
    main()

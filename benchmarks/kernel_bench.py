"""Kernel micro-benchmarks: wall us/call for the jnp reference paths on CPU
(relative comparisons) + analytic TPU-v5e time from flop/byte counts.

interpret-mode Pallas timings are NOT wall-clock meaningful (python
executes the kernel body); correctness is covered in tests/test_kernels.py.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.crossmatch import ops as cm_ops
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.paged_attention.ops import dense_to_pages, paged_attention
from repro.launch.roofline import HW

from .common import emit, time_call


def crossmatch_bench(verbose=True):
    rng = np.random.default_rng(0)
    N, M = 10_000, 1_024
    b = rng.normal(size=(N, 3)).astype(np.float32)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    p = rng.normal(size=(M, 3)).astype(np.float32)
    p /= np.linalg.norm(p, axis=1, keepdims=True)
    thr = float(np.cos(0.01))
    us = time_call(lambda: cm_ops.crossmatch(b, p, thr, use_pallas=False)[0])
    flops = 2.0 * N * M * 3
    tpu_us = flops / HW.peak_flops * 1e6
    hbm_us = (N * 8 + M * 8) * 4 / HW.hbm_bw * 1e6  # padded coords bf16-ish
    if verbose:
        print(f"  crossmatch 10k x 1k: cpu={us:.0f}us  v5e compute~{tpu_us:.2f}us "
              f"hbm~{hbm_us:.2f}us (memory-bound: band-sparse tiles are the win)")
    emit("kernel_crossmatch", us, f"v5e_est_us={max(tpu_us, hbm_us):.2f}")


def grouped_matmul_bench(verbose=True):
    rng = np.random.default_rng(1)
    import jax.numpy as jnp

    sizes = jnp.array([512, 1024, 512, 2048])
    T, d, f = 4096, 1024, 1024
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, d, f)) * 0.02, jnp.float32)
    us = time_call(lambda: grouped_matmul(x, sizes, w, use_pallas=False))
    flops = 2.0 * T * d * f
    tpu_us = flops / HW.peak_flops * 1e6
    hbm_us = (T * d + 4 * d * f + T * f) * 2 / HW.hbm_bw * 1e6
    if verbose:
        print(f"  grouped_matmul 4kx1kx1k/4g: cpu={us:.0f}us  v5e compute~{tpu_us:.1f}us "
              f"hbm~{hbm_us:.1f}us")
    emit("kernel_grouped_matmul", us, f"v5e_est_us={max(tpu_us, hbm_us):.2f}")


def paged_attention_bench(verbose=True):
    rng = np.random.default_rng(2)
    import jax.numpy as jnp

    B, H, KV, D, page, P = 16, 16, 8, 128, 64, 32
    S = page * P
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    kp, vp, pt = dense_to_pages(k, v, page)
    lens = jnp.full((B,), S, jnp.int32)
    us = time_call(lambda: paged_attention(q, kp, vp, pt, lens, use_pallas=False))
    bytes_moved = 2 * B * S * KV * D * 2  # K+V pages in bf16
    hbm_us = bytes_moved / HW.hbm_bw * 1e6
    flops = 4.0 * B * H * S * D
    tpu_us = flops / HW.peak_flops * 1e6
    if verbose:
        print(f"  paged_attention B16 S2048: cpu={us:.0f}us  v5e hbm~{hbm_us:.1f}us "
              f"compute~{tpu_us:.2f}us (bandwidth-bound as expected for decode)")
    emit("kernel_paged_attention", us, f"v5e_est_us={max(tpu_us, hbm_us):.2f}")


def run(verbose: bool = True):
    crossmatch_bench(verbose)
    grouped_matmul_bench(verbose)
    paged_attention_bench(verbose)


def main() -> None:
    run()


if __name__ == "__main__":
    main()

"""Benchmark harness — one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark) between
human-readable sections.  Roofline tables come from ``launch/dryrun.py``
artifacts and are summarized by ``roofline_table.py``.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from . import (  # noqa: E402
        bench_adaptive,
        bench_obs,
        bench_prefetch,
        bench_scheduler,
        bench_shard,
        bench_sharedplan,
        fig2_hybrid_join,
        fig5_bucket_reuse,
        fig6_workload_cdf,
        fig7_schedulers,
        fig8_tradeoff,
        serving_bench,
        kernel_bench,
        ft_bench,
        roofline_table,
    )

    sections = [
        ("Fig.2 hybrid join (scan vs index break-even)", fig2_hybrid_join.main),
        ("Fig.5 bucket reuse (top-10 coverage)", fig5_bucket_reuse.main),
        ("Fig.6 cumulative workload CDF", fig6_workload_cdf.main),
        ("Fig.7 schedulers (throughput / response / cache)", fig7_schedulers.main),
        ("Fig.8 saturation trade-off + adaptive alpha", fig8_tradeoff.main),
        ("Scheduler hot path: incremental vs naive + compile counts", bench_scheduler.main),
        ("Adaptive control plane: closed loop vs best static alpha", bench_adaptive.main),
        ("Prefetch: scan-horizon staging vs reactive LRU", bench_prefetch.main),
        ("Shared plans: masked multi-query kernel vs per-predicate", bench_sharedplan.main),
        ("Sharding: multi-shard tier + work stealing vs one loop", bench_shard.main),
        ("Observability: obs-on/off overhead + snapshot/Perfetto artifacts", bench_obs.main),
        ("Serving: multi-tenant LifeRaft engine", serving_bench.main),
        ("Kernels: micro-benchmarks", kernel_bench.main),
        ("Fault tolerance: goodput under failures", ft_bench.main),
        ("Roofline: dry-run artifact summary", roofline_table.main),
    ]
    failures = 0
    for title, fn in sections:
        print(f"\n=== {title} ===")
        try:
            fn()
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"  BENCH-ERROR {title}: {type(e).__name__}: {e}")
    print(f"\n[benchmarks] done in {time.time() - t0:.0f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

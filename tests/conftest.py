"""Test-session setup: make ``src`` importable without an editable install
and fall back to the bundled hypothesis stub when the real package (a dev
requirement, see requirements-dev.txt) is not installed."""
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SRC = str(_ROOT / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

sys.path.insert(0, str(_ROOT / "tests"))
import _hypothesis_stub  # noqa: E402

_hypothesis_stub.install()

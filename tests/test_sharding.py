"""Sharding rules: divisibility fallback, used-axis exclusion, ZeRO-1
augmentation, and logical->spec derivation for model params."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.logical import DECODE_RULES, DEFAULT_RULES, ShardingRules
from repro.training.train_step import tree_shardings


def _mesh():
    # single device, but axis SIZES are what the rules consult -> use a
    # fake multi-axis mesh over 1 device via reshape
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


class _FakeMesh:
    """Shape-only stand-in (rules only read mesh.shape)."""

    def __init__(self, shape: dict):
        self.shape = shape


def _rules(shape=None, table=None):
    r = ShardingRules.__new__(ShardingRules)
    r.mesh = _FakeMesh(shape or {"pod": 2, "data": 16, "model": 16})
    r.rules = dict(DEFAULT_RULES if table is None else table)
    return r


class TestSpecFor:
    def test_batch_takes_pod_and_data(self):
        spec = _rules().spec_for(("batch", "seq"), (256, 4096))
        assert spec == P(("pod", "data"), None)

    def test_divisibility_fallback_drops_axis(self):
        # 8 kv heads on a 16-way model axis -> replicated
        spec = _rules().spec_for(("kv_heads",), (8,))
        assert spec == P(None)

    def test_divisibility_fallback_prefix(self):
        # batch 16 can't take pod*data=32, falls back to pod=2 prefix
        spec = _rules().spec_for(("batch",), (16,))
        assert spec == P("pod")

    def test_used_axis_not_reassigned(self):
        # experts take model; expert_ff then must NOT also take model
        spec = _rules().spec_for(("experts", "embed", "expert_ff"), (64, 1024, 2048))
        assert spec == P("model", None, None)

    def test_expert_ff_picks_up_when_experts_cant(self):
        # mixtral: 8 experts < 16 -> expert_ff gets the model axis
        spec = _rules().spec_for(("experts", "embed", "expert_ff"), (8, 1024, 2048))
        assert spec == P(None, None, "model")

    def test_decode_rules_shard_cache_seq(self):
        r = _rules(table=DECODE_RULES)
        spec = r.spec_for(
            ("layers", "batch", "cache_seq", "cache_kv_heads", "head_dim"),
            (32, 128, 32768, 8, 128),
        )
        assert spec[2] == "model"  # seq sharded
        assert spec[3] is None  # kv heads replicated (8 % 16 != 0)

    def test_vocab_padded_shards(self):
        spec = _rules().spec_for(("vocab", "embed"), (256256, 1024))
        assert spec == P("model", None)

    def test_unknown_logical_name_replicates(self):
        spec = _rules().spec_for(("nonexistent", None), (7, 13))
        assert spec == P(None, None)


class TestTreeShardings:
    def test_zero1_augments_dim0(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules(mesh, dict(DEFAULT_RULES))
        axes = {"m": ("embed", "ff")}
        abstract = {"m": jax.ShapeDtypeStruct((64, 32), "float32")}
        sh = tree_shardings(rules, axes, abstract, zero1=True)
        assert sh["m"].spec[0] == "data"

    def test_zero1_skips_when_data_already_used(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules(mesh, dict(DEFAULT_RULES))
        axes = {"m": ("batch", "ff")}  # batch already uses data
        abstract = {"m": jax.ShapeDtypeStruct((64, 32), "float32")}
        sh = tree_shardings(rules, axes, abstract, zero1=True)
        spec0 = sh["m"].spec[0]
        assert spec0 in (("pod", "data"), "data", ("data",))  # not doubled

    def test_structure_preserved(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules(mesh, dict(DEFAULT_RULES))
        axes = {"a": {"b": ("embed",)}, "c": ()}
        abstract = {
            "a": {"b": jax.ShapeDtypeStruct((8,), "float32")},
            "c": jax.ShapeDtypeStruct((), "int32"),
        }
        sh = tree_shardings(rules, axes, abstract)
        assert set(sh) == {"a", "c"}


class TestParamAxesCoverage:
    """Every param leaf of every arch gets a well-formed axes tuple."""

    @pytest.mark.parametrize("arch", [
        "codeqwen1.5-7b", "mixtral-8x22b", "falcon-mamba-7b",
        "jamba-v0.1-52b", "seamless-m4t-large-v2", "paligemma-3b",
    ])
    def test_axes_match_abstract_shapes(self, arch):
        from repro.configs import get_config
        from repro.models import registry as R

        cfg = get_config(arch)
        axes = R.param_axes(cfg)
        abstract = R.init_params(cfg, mode="abstract")
        flat_a = jax.tree_util.tree_leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple)
        )
        flat_s = jax.tree_util.tree_leaves(abstract)
        assert len(flat_a) == len(flat_s)
        for ax, st in zip(flat_a, flat_s):
            assert len(ax) == len(st.shape), (arch, ax, st.shape)

"""Multi-shard execution tier: routing, stealing, joins, and the S=1
proof obligation.

The tentpole claim is composability: ``simulate_sharded`` with one shard
must replay the single-loop simulator *bit-identically* (same executor
arithmetic, same round sequence), and with S shards plus stealing it must
complete exactly the same query set — never losing or double-counting a
completion across a migration.  The hypothesis property here drives that
join invariant over random traces, shard counts, and steal schedules; the
unit tests pin the migration bookkeeping and the per-shard starvation
bound; the golden assertions keep the recorded steal scenario honest.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import replay
from repro.core import (
    ControlConfig,
    ControlLoop,
    CostModel,
    LifeRaftScheduler,
    Query,
    ShardControlPlane,
    ShardMap,
    StealConfig,
    WorkloadManager,
    simulate_batched,
    simulate_sharded,
    waterfill,
)


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _trace(seed, n=80, buckets=32, gap=0.03, depth_hi=24, skew=False):
    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(n):
        t += float(rng.exponential(gap))
        b = int(rng.integers(0, buckets))
        if skew:
            b = b * b // buckets
        ks = np.full(int(rng.integers(1, depth_hi)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    return qs


# ------------------------------------------------------------------ ShardMap
class TestShardMap:
    def test_byte_balanced_cuts_cover_every_bucket(self):
        bb = {b: float(1 + (b % 5)) for b in range(40)}
        sm = ShardMap.from_bucket_bytes(bb, 4)
        owned = {s: [] for s in sm.shards()}
        for b in bb:
            owned[sm.shard_of(b)].append(b)
        assert sorted(sum(owned.values(), [])) == sorted(bb)
        # SFC ranges: each shard owns a contiguous id run
        for ids in owned.values():
            assert ids == list(range(min(ids), max(ids) + 1))
        # the greedy target keeps the heaviest shard within one bucket
        # of the mean load
        loads = [sum(bb[b] for b in ids) for ids in owned.values()]
        assert max(loads) <= sum(bb.values()) / 4 + max(bb.values())

    def test_reassign_overrides_and_clears(self):
        sm = ShardMap.uniform(12, 3)
        home = sm.shard_of(5)
        other = (home + 1) % 3
        sm.reassign(5, other)
        assert sm.shard_of(5) == other
        sm.reassign(5, home)  # back home: override dropped, not stacked
        assert sm.shard_of(5) == home
        assert 5 not in sm.overrides

    def test_more_shards_than_buckets_still_partitions(self):
        sm = ShardMap.from_bucket_bytes({0: 1.0, 1: 1.0}, 4)
        assert {sm.shard_of(0), sm.shard_of(1)} <= set(sm.shards())


# ----------------------------------------------------------------- waterfill
class TestWaterfill:
    def test_grants_sum_to_budget_and_cap_at_demand(self):
        demand = {0: 100.0, 1: 400.0, 2: 50.0}
        g = waterfill(demand, {}, 300.0)
        assert sum(g.values()) == pytest.approx(300.0)
        for s, d in demand.items():
            assert g[s] <= d + 1e-9

    def test_weights_tilt_the_fill(self):
        demand = {0: 500.0, 1: 500.0}
        g = waterfill(demand, {0: 3.0, 1: 1.0}, 400.0)
        assert g[0] == pytest.approx(300.0)
        assert g[1] == pytest.approx(100.0)

    def test_slack_from_satisfied_redistributes(self):
        demand = {0: 10.0, 1: 1000.0}
        g = waterfill(demand, {}, 500.0)
        assert g[0] == pytest.approx(10.0)
        assert g[1] == pytest.approx(490.0)


# ---------------------------------------------------- the S=1 proof obligation
class TestSingleShardBitIdentity:
    """simulate_sharded(S=1) must equal simulate_batched round for round —
    replayed against the committed goldens, not just against a fresh
    oracle run."""

    def _sharded_entries(self, golden_name):
        rec = replay.ShardTraceRecorder()
        if golden_name == "sim_raw_fused":
            cost = CostModel(T_b=0.8, T_m=2e-4)
            simulate_sharded(
                replay.sim_trace(11), _identity_range, cost,
                scheduler_factory=lambda: LifeRaftScheduler(cost, alpha=0.25),
                n_shards=1, cache_capacity=8, fuse_k=3,
                on_round=rec.on_round,
            )
        elif golden_name == "sim_norm_ctl":
            cost = CostModel(T_b=0.8, T_m=2e-4)
            simulate_sharded(
                replay.sim_trace(23, n=180, buckets=90, gap=0.02),
                _identity_range, cost,
                scheduler_factory=lambda: LifeRaftScheduler(
                    cost, alpha=0.5, normalized=True
                ),
                n_shards=1, cache_capacity=8,
                control_factory=lambda: ControlLoop(ControlConfig(
                    alpha_init=0.5, alpha_step=0.2, halflife_s=3.0,
                    rate_knee=6.0, depth_knee=500.0, fuse_k_max=4,
                )),
                on_round=rec.on_round,
            )
        else:
            raise ValueError(golden_name)
        entries = rec.entries
        for e in entries:
            e.pop("shard", None)  # the golden predates the shard axis
        return entries

    @pytest.mark.parametrize("name", ["sim_raw_fused", "sim_norm_ctl"])
    def test_single_shard_replays_golden_bit_identically(self, name):
        expect = replay.load_trace(replay.GOLDEN_DIR / f"{name}.json")
        got = self._sharded_entries(name)
        divergence = replay.diff_traces(expect, got)
        assert not divergence, "\n".join(divergence)


# ------------------------------------------------------- completion invariant
class TestCompletionJoin:
    @given(st.integers(0, 10_000), st.integers(1, 4), st.integers(0, 1))
    @settings(max_examples=20, deadline=None)
    def test_sharded_completions_equal_single_shard(self, seed, S, stealing):
        """The join invariant: same queries, any shard count, any steal
        schedule -> the completed-query set equals the single-loop run's,
        with every query completed exactly once."""
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)
        qs = _trace(seed, skew=bool(stealing))
        base = simulate_batched(
            qs, _identity_range,
            LifeRaftScheduler(cost, alpha=0.3), cost, cache_capacity=8,
        )
        done: dict[int, int] = {}
        r = simulate_sharded(
            qs, _identity_range, cost,
            scheduler_factory=lambda: LifeRaftScheduler(cost, alpha=0.3),
            n_shards=S, cache_capacity=8,
            steal=StealConfig(low_water_bytes=0.0) if stealing else None,
            on_steal=lambda ev: None,
        )
        assert r.n_queries == base.n_queries == len(qs)

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_steal_never_loses_or_duplicates(self, seed):
        """Every query completes exactly once even when the steal schedule
        migrates its buckets mid-flight (tracked via on_round completions
        through the coordinator's own response map)."""
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)
        qs = _trace(seed, n=60, skew=True)
        steals = []
        r = simulate_sharded(
            qs, _identity_range, cost,
            scheduler_factory=lambda: LifeRaftScheduler(cost, alpha=0.3),
            n_shards=3, cache_capacity=6,
            steal=StealConfig(low_water_bytes=0.0),
            on_steal=steals.append,
        )
        assert r.n_queries == len(qs)
        assert r.steals == len(steals)


# ----------------------------------------------------------- migration units
class TestMigration:
    def _wm(self, cost):
        return WorkloadManager(
            _identity_range, probe_bytes=cost.probe_bytes,
            min_unit_bytes=cost.min_unit_bytes,
        )

    def test_units_conserved_across_a_migration(self):
        cost = CostModel(probe_bytes=8.0)
        src, dst = self._wm(cost), self._wm(cost)
        ks = np.array([3, 3, 7], dtype=np.uint64)
        q = Query(1, 0.5, ks, ks)
        src.submit(q)
        before = {b: qq.size for b, qq in src.queues.items() if qq}
        units = src.migrate_out(3)
        # bucket 3 left the source: not pending, not completed
        assert 3 not in {b for b, qq in src.queues.items() if qq}
        assert src.outstanding[1] == {7}
        assert 1 not in src.completed
        assert sum(u.size for u in units) == before[3]
        nbytes = sum(u.nbytes for u in units)
        dst.migrate_in(units, {1: q})
        assert dst.queue(3).size == before[3]
        assert dst.queue(3).nbytes == pytest.approx(nbytes)
        assert dst.outstanding[1] == {3}
        # arrival times survive the move (ages stay honest on the thief)
        assert all(u.arrival_time == 0.5 for u in dst.queue(3).units)

    def test_migrated_probe_indices_stay_valid(self):
        """Object indices index the ORIGINAL query arrays; migration must
        not rebase them (the thief gathers probes from the same payload)."""
        cost = CostModel(probe_bytes=8.0)
        src, dst = self._wm(cost), self._wm(cost)
        ks = np.array([9, 2, 9, 2, 9], dtype=np.uint64)
        q = Query(4, 0.0, ks, ks)
        src.submit(q)
        units = src.migrate_out(9)
        dst.migrate_in(units, {4: q})
        idx = np.concatenate([u.object_idx for u in dst.queue(9).units])
        assert sorted(idx.tolist()) == [0, 2, 4]

    def test_migrate_out_empty_bucket_is_noop(self):
        cost = CostModel()
        src = self._wm(cost)
        assert src.migrate_out(123) == []


# ------------------------------------------------------ per-shard starvation
class TestPerShardStarvation:
    """The §6 bound survives sharding: each shard runs its own tenant
    plane over its slice of the flood, so no interactive query ages past
    the same age_scale-derived horizon that holds at S=1."""

    ALPHA_MIN = 0.7
    ROUND_SLACK_S = 0.7

    def test_bound_holds_on_every_shard(self):
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.1, probe_bytes=16.0)
        bound = cost.age_scale_ms / 1e3 / self.ALPHA_MIN + self.ROUND_SLACK_S
        qs = replay.two_tenant_trace(
            41, horizon=10.0, flood_gap=0.03, depth_lo=60, depth_hi=120
        )
        r = simulate_sharded(
            qs, _identity_range, cost,
            scheduler_factory=lambda: LifeRaftScheduler(
                cost, 0.5, normalized=True
            ),
            n_shards=2, cache_capacity=8,
            control_factory=lambda: replay.two_tenant_plane(60_000.0),
        )
        stats = r.per_tenant["interactive"]
        assert stats["n"] > 0
        assert stats["max_response"] <= bound, (stats, bound)


# -------------------------------------------------------------- golden teeth
class TestStealGolden:
    def test_steal_golden_actually_exercises_a_migration(self):
        """A steal golden with no steal entries guards nothing."""
        rounds = replay.load_trace(replay.GOLDEN_DIR / "sim_shard_steal.json")
        steals = [e for e in rounds if "steal" in e]
        assert steals, "sim_shard_steal.json recorded zero migrations"
        for b, victim, thief, n_units in (e["steal"] for e in steals):
            assert victim != thief
            assert n_units > 0

    def test_shard_golden_interleaves_all_shards(self):
        rounds = replay.load_trace(replay.GOLDEN_DIR / "sim_shard4.json")
        assert {e["shard"] for e in rounds if "shard" in e} == {0, 1, 2, 3}


# -------------------------------------------------------- global byte arbiter
class TestShardControlPlane:
    def test_grants_waterfill_across_shards(self):
        from repro.core.control import Telemetry

        plane = ShardControlPlane(3, spill_budget_bytes=600.0)
        tels = {
            s: Telemetry(
                now=1.0, arrival_rate=0.0, pending_objects=0,
                resident_objects=0, n_queues=1, oldest_age_ms=0.0,
                cache_hit_rate=0.0, occupancy=0.0,
                pending_bytes=pb, resident_bytes=pb,
            )
            for s, pb in {0: 100.0, 1: 1000.0, 2: 100.0}.items()
        }
        grants = plane.update(tels)
        assert sum(g.spill_bytes for g in grants.values()) == pytest.approx(
            600.0
        )
        assert grants[0].spill_bytes == pytest.approx(100.0)
        assert grants[2].spill_bytes == pytest.approx(100.0)
        assert grants[1].spill_bytes == pytest.approx(400.0)

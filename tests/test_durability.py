"""Durable service tier: write-ahead journal, crash recovery, admission
control — plus the shard/control-tier bugfix regressions that ride along.

Covers:
  * ``Journal`` mechanics: segment rotation, restart-opens-new-segment,
    torn-tail tolerance, mid-journal corruption detection
  * ``ServiceDaemon`` recovery: golden-vs-recovered decision bit-identity
    for the single serving engine, the sharded serving engine (including
    steal overrides), and the cross-match engine; idempotent
    resubmission; RecoveryError on journal/engine disagreement
  * per-tenant admission control: deterministic 429s, journaled and
    replayed bit-identically
  * the truncation property (satellite 5): replayed state == live state
    at every captured truncation point of a recorded run
  * satellite bugfix regressions: adapter-slot remainder conservation,
    waterfill zero-demand slack, dryrun perf_counter, cross-match drain
    thread fault propagation
  * the kill -9 gate, via ``benchmarks/smoke_recovery`` in a subprocess
"""
import os
import pathlib
import shutil
import subprocess
import sys
import tempfile

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdmissionController,
    AdmissionQuota,
    AdmissionRejected,
    Journal,
    JournalCorrupt,
    StealConfig,
    diff_entries,
    split_slots,
    waterfill,
)
from repro.crossmatch import (
    CrossMatchEngine,
    ShardedCrossMatch,
    TraceConfig,
    make_catalog,
    make_trace,
)
from repro.serving import (
    AdapterSpec,
    CrossMatchHost,
    LifeRaftEngine,
    RecoveryError,
    Request,
    ServeConfig,
    ServiceDaemon,
    ServingHost,
    ShardedServingEngine,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


# ----------------------------------------------------------- scenario helpers
def _adapters(n=6):
    return [
        AdapterSpec(
            a,
            nbytes=(a + 1) * 1_000_000,
            tenant="interactive" if a % 2 else "batch",
        )
        for a in range(n)
    ]


def _trace(n=40, n_adapters=6):
    return [
        Request(
            request_id=i,
            adapter_id=(i * 5) % n_adapters,
            arrival_time=0.01 * i,
            prompt_len=32 + (i % 7) * 16,
            max_new_tokens=32,
        )
        for i in range(n)
    ]


_CFG = ServeConfig(adapter_slots=3, fuse_k=2, adaptive=True)


def _serving_daemon(journal_dir, cfg=_CFG, **daemon_kw):
    return ServiceDaemon(
        ServingHost(LifeRaftEngine(_adapters(), cfg)), journal_dir, **daemon_kw
    )


def _drive(daemon, items):
    for it in items:
        daemon.pump(until=it.arrival_time)
        daemon.submit(it)
    daemon.pump()


_MEMO = {}


def _memo(key, builder):
    """Module-lifetime cache for expensive recorded runs; plain dict
    rather than fixtures so ``@given`` tests (whose drawn arguments are
    passed positionally by the hypothesis stub) can share them too."""
    if key not in _MEMO:
        _MEMO[key] = builder()
    return _MEMO[key]


def _catalog():
    return _memo(
        "catalog",
        lambda: make_catalog(n_objects=3000, objects_per_bucket=200, seed=5),
    )


@pytest.fixture(scope="module")
def small_catalog():
    return _catalog()


def _xmatch_trace(catalog, n=12):
    return make_trace(
        catalog,
        TraceConfig(n_queries=n, seed=9, objects_median=60, arrival_rate=2.0),
    )


# ================================================================== journal
class TestJournal:
    def test_rotation_and_replay_order(self, tmp_path):
        j = Journal(tmp_path / "j", segment_bytes=256)
        recs = [{"type": "entry", "entry": {"i": i, "pad": "x" * 40}}
                for i in range(50)]
        for r in recs:
            j.append(r)
        j.close()
        assert len(j.segments()) > 1  # rotation actually happened
        assert Journal(tmp_path / "j").replay() == recs

    def test_restart_opens_new_segment(self, tmp_path):
        j1 = Journal(tmp_path / "j")
        j1.append({"type": "entry", "entry": {"i": 0}})
        j1.close()
        j2 = Journal(tmp_path / "j")
        j2.append({"type": "entry", "entry": {"i": 1}})
        j2.close()
        assert len(j2.segments()) == 2
        assert [r["entry"]["i"] for r in j2.replay()] == [0, 1]

    def test_torn_tail_dropped(self, tmp_path):
        j = Journal(tmp_path / "j")
        j.append({"type": "submit", "key": "a", "item": {}})
        j.append({"type": "submit", "key": "b", "item": {}})
        j.close()
        seg = j.segments()[-1]
        with open(seg, "a", encoding="utf-8") as fh:
            fh.write('{"type":"submit","key":"c","it')  # torn mid-write
        recs = Journal(tmp_path / "j").replay()
        assert [r["key"] for r in recs] == ["a", "b"]

    def test_mid_journal_corruption_raises(self, tmp_path):
        j = Journal(tmp_path / "j")
        for k in ("a", "b", "c"):
            j.append({"type": "submit", "key": k, "item": {}})
        j.close()
        seg = j.segments()[0]
        lines = seg.read_text().splitlines()
        lines[1] = lines[1][: len(lines[1]) // 2]  # damage a middle line
        seg.write_text("\n".join(lines) + "\n")
        with pytest.raises(JournalCorrupt):
            Journal(tmp_path / "j").replay()

    def test_codec_shared_with_golden_harness(self):
        # The tentpole's schema-unification claim: the golden-trace
        # recorder and the journal literally share one codec.
        sys.path.insert(0, str(REPO / "tests"))
        try:
            import replay as golden_harness
        finally:
            sys.path.pop(0)
        from repro.core import journal

        assert golden_harness.encode_outcome is journal.encode_outcome
        assert golden_harness.diff_traces is journal.diff_entries


# ================================================================ admission
class TestAdmission:
    def test_queue_depth_quota(self):
        ctl = AdmissionController({"batch": AdmissionQuota(max_queue_depth=3)})
        ctl.check("batch", 2, 0.0)  # 2 + 1 <= 3
        with pytest.raises(AdmissionRejected) as ei:
            ctl.check("batch", 3, 0.0)
        assert ei.value.reason == "queue_depth"
        assert ei.value.status == 429
        assert ei.value.observed == 3.0 and ei.value.limit == 3.0
        ctl.check("interactive", 1000, 0.0)  # unlisted tenant: unlimited

    def test_pending_bytes_quota_and_default(self):
        ctl = AdmissionController(
            default=AdmissionQuota(max_pending_bytes=100.0)
        )
        ctl.check("anyone", 0, 60.0, add_bytes=40.0)
        with pytest.raises(AdmissionRejected) as ei:
            ctl.check("anyone", 0, 60.0, add_bytes=41.0)
        assert ei.value.reason == "pending_bytes"

    def test_daemon_rejects_journaled_and_replayed(self, tmp_path):
        adm = AdmissionController(
            {"batch": AdmissionQuota(max_queue_depth=2)}
        )
        d = _serving_daemon(tmp_path / "j", admission=adm)
        rejected = []
        for r in _trace(10):  # no pumping: queues only grow
            try:
                d.submit(r)
            except AdmissionRejected:
                rejected.append(r.request_id)
        assert rejected  # the batch tenant hit its quota
        # cached rejection re-raised on resubmit, identical fields
        dup = [r for r in _trace(10) if r.request_id == rejected[0]][0]
        with pytest.raises(AdmissionRejected):
            d.submit(dup)
        d.close()
        # replay reproduces every disposition without re-checking quota
        d2 = _serving_daemon(tmp_path / "j", admission=adm)
        assert sorted(
            int(k.rsplit("-", 1)[1]) for k in d2.rejected
        ) == sorted(rejected)
        for rid in rejected:
            assert d2.disposition(f"req-{rid}") == "rejected"
            with pytest.raises(AdmissionRejected):
                d2.submit([r for r in _trace(10) if r.request_id == rid][0])
        d2.close()

    def test_retry_after_drain_admits(self, tmp_path):
        adm = AdmissionController(
            {"batch": AdmissionQuota(max_queue_depth=2)}
        )
        d = _serving_daemon(tmp_path / "j", admission=adm)
        reqs = [r for r in _trace(12) if r.adapter_id % 2 == 0]  # batch only
        got_reject = None
        for r in reqs:
            try:
                d.submit(r)
            except AdmissionRejected:
                got_reject = r
                break
        assert got_reject is not None
        d.pump()  # drain: quota headroom restored
        fresh = [
            r for r in _trace(12) if r.request_id == got_reject.request_id
        ][0]
        assert d.submit(fresh, retry=True)["status"] == "acked"
        d.close()
        # the later submit record supersedes the journaled 429 on replay
        d2 = _serving_daemon(tmp_path / "j", admission=adm)
        assert d2.disposition(f"req-{got_reject.request_id}") == "acked"
        d2.close()


# ======================================================== daemon recovery
class TestDaemonRecovery:
    def _golden_crash_recover(self, make_daemon, items, crash_after, tmp):
        """Golden run; same driver crashed after ``crash_after`` submits
        (abandoned without close — the in-process stand-in for kill -9);
        recover and finish; return (golden, recovered)."""
        golden = make_daemon(tmp / "golden")
        _drive(golden, items())
        golden.close()
        crashed = make_daemon(tmp / "crashed")
        for it in items()[:crash_after]:
            crashed.pump(until=it.arrival_time)
            crashed.submit(it)
        del crashed  # no close: tail past the last fsync may tear
        recovered = make_daemon(tmp / "crashed")
        _drive(recovered, items())
        recovered.close()
        return golden, recovered

    def test_single_engine_bit_identical(self, tmp_path):
        golden, rec = self._golden_crash_recover(
            _serving_daemon, _trace, 20, tmp_path
        )
        assert diff_entries(golden.entries, rec.entries) == []
        assert rec.completed() == golden.completed()
        assert len(rec.completed()) == len(_trace())

    def test_sharded_engine_bit_identical(self, tmp_path):
        def make(d):
            eng = ShardedServingEngine(
                _adapters(), _CFG, n_shards=3,
                steal=StealConfig(low_water_bytes=50.0),
            )
            return ServiceDaemon(ServingHost(eng), d)

        golden, rec = self._golden_crash_recover(make, _trace, 25, tmp_path)
        assert diff_entries(golden.entries, rec.entries) == []
        assert rec.completed() == golden.completed()
        # recovered shard state (incl. any steal overrides) matches a
        # never-crashed run exactly
        assert rec.state_fingerprint() == golden.state_fingerprint()

    def test_crossmatch_engine_bit_identical(self, tmp_path, small_catalog):
        def make(d):
            eng = CrossMatchEngine(small_catalog, cache_capacity=4, fuse_k=2)
            return ServiceDaemon(CrossMatchHost(eng), d)

        items = lambda: _xmatch_trace(small_catalog)  # noqa: E731
        golden, rec = self._golden_crash_recover(make, items, 7, tmp_path)
        assert diff_entries(golden.entries, rec.entries) == []
        assert rec.completed() == golden.completed()
        assert len(rec.completed()) == 12

    def test_idempotent_resubmission(self, tmp_path):
        d = _serving_daemon(tmp_path / "j")
        r = _trace(1)[0]
        assert d.submit(r)["status"] == "acked"
        assert d.submit(_trace(1)[0])["status"] == "duplicate"
        before = d.journal.appended
        d.submit(_trace(1)[0])
        assert d.journal.appended == before  # duplicates are not journaled
        d.close()

    def test_ack_is_write_ahead(self, tmp_path):
        d = _serving_daemon(tmp_path / "j")
        d.submit(_trace(1)[0])
        # the record is already durable on disk, pre-pump, pre-close
        recs = Journal(tmp_path / "j").replay()
        assert [r["type"] for r in recs] == ["submit"]
        assert recs[0]["key"] == "req-0"
        d.close()

    def test_recovery_refuses_divergent_engine(self, tmp_path):
        d = _serving_daemon(tmp_path / "j")
        _drive(d, _trace(10))
        d.close()
        # 'recover' under a different config: decisions cannot match
        other = ServeConfig(adapter_slots=3, fuse_k=2, alpha=0.9)
        with pytest.raises(RecoveryError):
            _serving_daemon(tmp_path / "j", cfg=other)

    def test_recovery_tolerates_torn_tail(self, tmp_path):
        d = _serving_daemon(tmp_path / "j")
        for r in _trace(8):
            d.pump(until=r.arrival_time)
            d.submit(r)
        d.journal._fh.write('{"type":"entry","ent')  # crash mid-append
        d.journal._fh.flush()
        del d
        rec = _serving_daemon(tmp_path / "j")
        _drive(rec, _trace(8))
        rec.close()
        golden = _serving_daemon(tmp_path / "g")
        _drive(golden, _trace(8))
        golden.close()
        assert diff_entries(golden.entries, rec.entries) == []


# ================================================= truncation property (#5)
def _record_run(make_daemon, items):
    """Drive a daemon one operation at a time, capturing the engine state
    fingerprint at every journal record count reached."""
    dirpath = tempfile.mkdtemp(prefix="rec-")
    try:
        d = make_daemon(dirpath)
        points = {d.journal.appended: d.state_fingerprint()}

        def settle(until):
            while d.host.has_work() and (
                until is None or d.host.clock() < until
            ):
                if d.host.step() is None:
                    break
                points[d.journal.appended] = d.state_fingerprint()

        for it in items:
            settle(it.arrival_time)
            d.submit(it)
            points[d.journal.appended] = d.state_fingerprint()
        settle(None)
        d.close()
        return d.journal.replay(), points
    finally:
        shutil.rmtree(dirpath)


def _check_truncation(make_daemon, records, points, t):
    """Copy the first ``t`` journal records into a fresh directory, recover
    a daemon there, and assert its state equals the live run's state at
    that point."""
    tmp = tempfile.mkdtemp(prefix="truncation-")
    try:
        trunc = Journal(tmp)
        for rec in records[:t]:
            trunc.append(rec)
        trunc.close()
        d = make_daemon(tmp)
        fp = d.state_fingerprint()
        d.close()
        assert fp == points[t], f"state diverged at truncation point {t}"
    finally:
        shutil.rmtree(tmp)


def _recorded_serving():
    return _memo(
        "rec-serving", lambda: _record_run(_serving_daemon, _trace(24))
    )


def _make_sharded_daemon(d):
    eng = ShardedServingEngine(
        _adapters(), _CFG, n_shards=2,
        steal=StealConfig(low_water_bytes=1e4, min_victim_queues=1),
    )
    return ServiceDaemon(ServingHost(eng), d)


def _recorded_sharded():
    def build():
        # skew arrivals onto shard 1's adapters so shard 0 runs dry
        # and steals — the recorded run must exercise steal overrides
        reqs = [
            Request(request_id=i, adapter_id=4 + (i % 2) if i > 2 else 0,
                    arrival_time=0.01 * i, prompt_len=64, max_new_tokens=32)
            for i in range(20)
        ]
        records, points = _record_run(_make_sharded_daemon, reqs)
        assert any(
            "steal" in r["entry"] for r in records if r["type"] == "entry"
        ), "scenario must exercise steal overrides"
        return records, points

    return _memo("rec-sharded", build)


def _make_xmatch_daemon(d):
    eng = CrossMatchEngine(_catalog(), cache_capacity=4, fuse_k=2)
    return ServiceDaemon(CrossMatchHost(eng), d)


def _recorded_xmatch():
    return _memo(
        "rec-xmatch",
        lambda: _record_run(_make_xmatch_daemon, _xmatch_trace(_catalog())),
    )


class TestTruncationProperty:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_serving_state_matches_at_any_truncation(self, draw):
        records, points = _recorded_serving()
        counts = sorted(points)
        _check_truncation(
            _serving_daemon, records, points, counts[draw % len(counts)]
        )

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_sharded_state_matches_at_any_truncation(self, draw):
        records, points = _recorded_sharded()
        counts = sorted(points)
        _check_truncation(
            _make_sharded_daemon, records, points, counts[draw % len(counts)]
        )

    @settings(max_examples=5, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_crossmatch_state_matches_at_any_truncation(self, draw):
        records, points = _recorded_xmatch()
        counts = sorted(points)
        _check_truncation(
            _make_xmatch_daemon, records, points, counts[draw % len(counts)]
        )


# ==================================================== satellite regressions
class TestSlotSplit:
    """Satellite 1: ``slots // S`` dropped the remainder."""

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=1, max_value=64),
           st.integers(min_value=1, max_value=8))
    def test_split_conserves_and_balances(self, total, n):
        parts = split_slots(total, n)
        assert len(parts) == n
        assert all(p >= 1 for p in parts)
        if total >= n:
            assert sum(parts) == total  # conservation — the bug
            assert max(parts) - min(parts) <= 1
        else:
            assert parts == [1] * n  # floor-at-1 inflation only

    @pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
    def test_sharded_serving_conserves_aggregate_slots(self, n_shards):
        cfg = ServeConfig(adapter_slots=6)
        eng = ShardedServingEngine(_adapters(), cfg, n_shards=n_shards)
        assert (
            sum(e.cache.capacity for e in eng.engines) == cfg.adapter_slots
        )
        # remainder goes to the lowest shard ids
        caps = [e.cache.capacity for e in eng.engines]
        assert caps == sorted(caps, reverse=True)

    def test_sharded_crossmatch_conserves_cache_slots(self, small_catalog):
        sx = ShardedCrossMatch(small_catalog, n_shards=3, cache_capacity=7)
        assert sum(e.cache.capacity for e in sx.engines) == 7


class TestWaterfill:
    """Satellite 2: final slack was spread over zero-demand parties."""

    def test_zero_demand_party_gets_nothing(self):
        grants = waterfill({"a": 10.0, "b": 0.0, "c": 5.0}, {}, 30.0)
        assert grants["b"] == 0.0
        assert sum(grants.values()) == pytest.approx(30.0)
        # slack beyond total demand lands on the demanders
        assert grants["a"] > 10.0 and grants["c"] > 5.0

    @settings(max_examples=30, deadline=None)
    @given(
        st.lists(st.floats(min_value=0.0, max_value=100.0),
                 min_size=1, max_size=6),
        st.floats(min_value=0.0, max_value=500.0),
    )
    def test_conservation_and_no_free_grants(self, demands, budget):
        demand = {f"t{i}": d for i, d in enumerate(demands)}
        grants = waterfill(demand, {}, budget)
        assert sum(grants.values()) == pytest.approx(budget)
        if any(d > 0.0 for d in demands):
            for t, d in demand.items():
                if d == 0.0:
                    assert grants[t] == 0.0


def test_dryrun_times_with_perf_counter():
    """Satellite 3: lowering/compile timings must use the monotonic
    clock, matching trainer.py."""
    import inspect

    from repro.launch import dryrun

    src = inspect.getsource(dryrun.run_cell)
    assert "time.time()" not in src
    assert "time.perf_counter()" in src


class TestDrainFault:
    """Satellite 4: a drain thread dying must surface at join, with the
    originating shard id, instead of hanging or passing silently."""

    def test_store_fault_propagates_with_shard_id(self, small_catalog):
        sx = ShardedCrossMatch(small_catalog, n_shards=2, cache_capacity=4)
        boom = RuntimeError("injected store fault")
        real_read = small_catalog.store.read
        calls = []

        def failing_read(bucket_id):
            calls.append(bucket_id)
            if len(calls) >= 2:
                raise boom
            return real_read(bucket_id)

        small_catalog.store.read = failing_read
        try:
            with pytest.raises(RuntimeError, match=r"shard \d+ drain thread died"):
                sx.run(_xmatch_trace(small_catalog, n=8))
        finally:
            small_catalog.store.read = real_read
        assert sx._drain_errors
        sid, exc = sx._drain_errors[0]
        assert exc is boom
        assert sx._abort.is_set()

    def test_error_chains_original_exception(self, small_catalog):
        sx = ShardedCrossMatch(small_catalog, n_shards=2, cache_capacity=4)
        real_read = small_catalog.store.read
        small_catalog.store.read = lambda b: (_ for _ in ()).throw(
            ValueError("disk on fire")
        )
        try:
            with pytest.raises(RuntimeError) as ei:
                sx.run(_xmatch_trace(small_catalog, n=8))
        finally:
            small_catalog.store.read = real_read
        assert isinstance(ei.value.__cause__, ValueError)


# ================================================= the kill -9 gate (CI smoke)
def test_kill9_recovery_gate(tmp_path):
    """Headline gate: SIGKILL a journaling daemon mid-flood, recover, and
    require every acked query to complete with decisions bit-identical to
    an uninterrupted run.  Runs the CI smoke in-subprocess with a short
    trace."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [
            sys.executable, "-m", "benchmarks.smoke_recovery",
            "--dir", str(tmp_path / "journal"), "--n", "60",
            "--throttle", "0.02",
        ],
        cwd=str(REPO),
        env=env,
        capture_output=True,
        text=True,
        timeout=280,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr

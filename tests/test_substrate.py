"""Substrate tests: data pipeline, checkpointing, optimizers, compression,
fault tolerance, serving engine, KV page pool."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import DataConfig, TokenPipeline
from repro.dist import (
    StragglerPolicy,
    dequantize_blockwise,
    error_feedback_compress,
    quantize_blockwise,
    simulate_training_with_failures,
    topk_compress,
)
from repro.dist.ft import HeartbeatMonitor
from repro.serving import AdapterSpec, LifeRaftEngine, PagePool, Request, ServeConfig
from repro.training.optimizer import cosine_schedule, make_optimizer


# ------------------------------------------------------------------ data
class TestPipeline:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        p1 = TokenPipeline(cfg)
        batches = [p1.next_batch() for _ in range(3)]
        p2 = TokenPipeline.restore(cfg, {"step": 2, "seed": 3})
        np.testing.assert_array_equal(p2.next_batch()["tokens"], batches[2]["tokens"])

    def test_shards_disjoint_and_cover(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
        full = TokenPipeline(cfg, dp_rank=0, dp_size=1).next_batch()["tokens"]
        shards = [
            TokenPipeline(cfg, dp_rank=r, dp_size=4).next_batch()["tokens"]
            for r in range(4)
        ]
        np.testing.assert_array_equal(np.concatenate(shards), full)

    def test_labels_shifted(self):
        cfg = DataConfig(vocab_size=50, seq_len=12, global_batch=2)
        b = TokenPipeline(cfg).next_batch()
        assert b["tokens"].shape == b["labels"].shape == (2, 12)


# ------------------------------------------------------------------ checkpoint
class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {
            "a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
        }

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(tmp_path, 7, tree)
        restored, step = restore_checkpoint(tmp_path, None, tree)
        assert step == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            tree, restored,
        )

    def test_latest_step_and_atomicity(self, tmp_path):
        save_checkpoint(tmp_path, 1, self._tree(1))
        save_checkpoint(tmp_path, 5, self._tree(2))
        assert latest_step(tmp_path) == 5
        # a stale .tmp dir must not be picked up
        (tmp_path / "step_00000009.tmp").mkdir()
        assert latest_step(tmp_path) == 5

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(tmp_path)
        tree = self._tree(3)
        ck.save(2, tree)
        ck.wait()
        restored, step = restore_checkpoint(tmp_path, None, tree)
        assert step == 2

    def test_structure_mismatch_raises(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"a": jnp.zeros((2, 2))})
        with pytest.raises(AssertionError):
            restore_checkpoint(tmp_path, 1, {"a": jnp.zeros((3, 3))})


# ------------------------------------------------------------------ optimizer
class TestOptimizers:
    def _quadratic(self, opt, steps=60):
        target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)
        params = {"w": jnp.zeros((8, 8), jnp.float32)}
        state = opt.init(params)
        loss = lambda p: jnp.mean((p["w"] - target) ** 2)
        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state, _ = opt.update(g, state, params)
        return float(loss(params))

    @pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor"])
    def test_optimizers_descend(self, name):
        opt = make_optimizer(name, lr=0.05, weight_decay=0.0)
        final = self._quadratic(opt)
        assert final < 0.3, (name, final)

    def test_8bit_state_is_small(self):
        opt = make_optimizer("adamw8bit")
        params = {"w": jnp.zeros((1024, 64), jnp.bfloat16)}
        state = opt.init(params)
        mu = state["mu"]["w"]
        int8_bytes = mu["m_q"].size + mu["v_q"].size
        scale_bytes = (mu["m_s"].size + mu["v_s"].size) * 4
        f32_bytes = 2 * 1024 * 64 * 4
        assert int8_bytes + scale_bytes < 0.3 * f32_bytes

    def test_state_axes_structure_matches(self):
        opt = make_optimizer("adamw")
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        axes = {"w": ("embed", "ff"), "b": ("ff",)}
        sa = opt.state_axes(axes)
        state = opt.init(params)
        jax.tree_util.tree_map(
            lambda *_: None, sa, state, is_leaf=lambda x: isinstance(x, tuple)
        )  # structure compatibility check (raises on mismatch)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------------ compression
class TestCompression:
    @given(st.integers(1, 5000))
    @settings(max_examples=25, deadline=None)
    def test_quantize_roundtrip_bounded(self, n):
        x = jnp.asarray(np.random.default_rng(n).normal(size=n), jnp.float32)
        q, s = quantize_blockwise(x)
        y = dequantize_blockwise(q, s, x.shape)
        blk_max = np.abs(np.asarray(x)).max()
        assert float(jnp.abs(x - y).max()) <= blk_max / 127.0 + 1e-6

    def test_error_feedback_converges(self):
        """Sum of dequantized payloads + final residual == sum of grads."""
        rng = np.random.default_rng(0)
        total = np.zeros(100, np.float32)
        recovered = np.zeros(100, np.float32)
        res = None
        for i in range(20):
            g = jnp.asarray(rng.normal(size=100), jnp.float32)
            total += np.asarray(g)
            (q, s), res = error_feedback_compress(g, res)
            recovered += np.asarray(dequantize_blockwise(q, s, g.shape))
        np.testing.assert_allclose(recovered + np.asarray(res), total, atol=1e-3)

    def test_topk_keeps_largest(self):
        g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05], jnp.float32)
        kept, res = topk_compress(g, 0.4, None)
        assert float(kept[1]) == -5.0 and float(kept[3]) == 3.0
        assert float(kept[0]) == 0.0
        np.testing.assert_allclose(np.asarray(kept + res), np.asarray(g), atol=1e-7)


# ------------------------------------------------------------------ fault tolerance
class TestFaultTolerance:
    def test_heartbeat_detects_failure(self):
        hb = HeartbeatMonitor([0, 1, 2], timeout=10.0)
        for t in (0.0, 5.0):
            hb.beat(0, t)
            hb.beat(1, t)
        hb.beat(2, 0.0)
        assert hb.check(12.0) == [2]
        assert set(hb.alive) == {0, 1}

    def test_straggler_policy(self):
        p = StragglerPolicy(factor=2.0)
        for _ in range(10):
            assert not p.observe(1.0)
        assert p.observe(5.0)
        assert p.backup_cutoff() == pytest.approx(2.0, rel=0.1)

    def test_backup_tasks_reduce_walltime(self):
        kw = dict(n_steps=400, straggler_rate=0.1, straggler_slowdown=8.0,
                  failure_rate=0.0, seed=1)
        with_b = simulate_training_with_failures(backup_tasks=True, **kw)
        without = simulate_training_with_failures(backup_tasks=False, **kw)
        assert with_b.wall_time < without.wall_time
        assert with_b.n_backup_dispatches > 0

    def test_failures_roll_back_to_checkpoint(self):
        r = simulate_training_with_failures(
            n_steps=300, failure_rate=3e-6, checkpoint_every=20, seed=2
        )
        assert r.steps_done == 300
        if r.n_failures:
            assert r.lost_steps <= r.n_failures * 20


# ------------------------------------------------------------------ page pool
class TestPagePool:
    def test_allocate_and_release(self):
        pool = PagePool(n_pages=8, page_size=4, n_kv=2, head_dim=8)
        pool.create(0)
        for _ in range(9):  # 3 pages worth
            pool.append_token_slot(0)
        assert pool.free_pages == 5
        pool.release(0)
        assert pool.free_pages == 8

    def test_prefix_sharing_refcount(self):
        pool = PagePool(n_pages=8, page_size=4, n_kv=2, head_dim=8)
        pool.create(0)
        for _ in range(8):
            pool.append_token_slot(0)
        used = 8 - pool.free_pages
        pool.create(1, prefix_of=0)  # shares both pages
        assert 8 - pool.free_pages == used
        pool.release(0)
        assert 8 - pool.free_pages == used  # still referenced by seq 1
        pool.release(1)
        assert pool.free_pages == 8

    def test_copy_on_write_on_shared_tail(self):
        pool = PagePool(n_pages=8, page_size=4, n_kv=2, head_dim=8)
        pool.create(0)
        for _ in range(6):  # page 2 half-full
            pool.append_token_slot(0)
        pool.create(1, prefix_of=0)
        p0_pages = list(pool._seqs[0].pages)
        pool.append_token_slot(1)  # must CoW the shared tail page
        assert pool._seqs[1].pages[-1] != p0_pages[-1]

    def test_exhaustion(self):
        pool = PagePool(n_pages=1, page_size=2, n_kv=1, head_dim=4)
        pool.create(0)
        pool.append_token_slot(0)
        pool.append_token_slot(0)
        with pytest.raises(MemoryError):
            pool.append_token_slot(0)

    def test_page_table(self):
        pool = PagePool(n_pages=8, page_size=4, n_kv=2, head_dim=8)
        pool.create(0)
        for _ in range(5):
            pool.append_token_slot(0)
        pt, lens = pool.page_table([0], pad_to=4)
        assert pt.shape == (1, 4)
        assert int(lens[0]) == 5


# ------------------------------------------------------------------ serving engine
def _trace(n=120, n_adapters=8, rate=200.0, zipf=1.5, seed=0):
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_adapters + 1) ** zipf
    w /= w.sum()
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        out.append(
            Request(
                request_id=i,
                adapter_id=int(rng.choice(n_adapters, p=w)),
                arrival_time=t,
                prompt_len=int(rng.integers(8, 64)),
                max_new_tokens=16,
            )
        )
    return out


def _adapters(n=8, nbytes=8 << 30):
    return [AdapterSpec(i, nbytes) for i in range(n)]


class TestServingEngine:
    def test_all_requests_complete(self):
        for policy in ("liferaft", "rr", "noshare"):
            eng = LifeRaftEngine(_adapters(), ServeConfig(policy=policy))
            s = eng.run(_trace())
            assert s["n_completed"] == 120, policy

    def test_liferaft_beats_noshare_throughput(self):
        lr = LifeRaftEngine(_adapters(), ServeConfig(policy="liferaft", alpha=0.0))
        ns = LifeRaftEngine(_adapters(), ServeConfig(policy="noshare"))
        s1, s2 = lr.run(_trace(seed=1)), ns.run(_trace(seed=1))
        assert s1["token_throughput"] > 1.3 * s2["token_throughput"]

    def test_batching_amortizes_adapter_loads(self):
        eng = LifeRaftEngine(_adapters(), ServeConfig(policy="liferaft", alpha=0.0))
        s = eng.run(_trace(seed=2))
        assert s["cache_hit_rate"] > 0.2
        assert s["batches"] < 120 * 2  # far fewer scheduling rounds than tokens/quantum naive

    def test_aging_prevents_starvation(self):
        """alpha=1 must bound p95 response vs pure greedy under skew."""
        t = _trace(n=200, zipf=2.5, rate=400.0, seed=3)
        greedy = LifeRaftEngine(_adapters(), ServeConfig(policy="liferaft", alpha=0.0)).run(t)
        aged = LifeRaftEngine(_adapters(), ServeConfig(policy="liferaft", alpha=1.0)).run(t)
        assert aged["p95_response"] <= greedy["p95_response"] * 1.5
        assert greedy["token_throughput"] >= aged["token_throughput"] * 0.95

    def test_fused_dispatch_completes_all(self):
        """fuse_k>1 services the top-k adapters per dispatch; every request
        still completes and throughput does not degrade."""
        t = _trace(seed=4)
        base = LifeRaftEngine(_adapters(), ServeConfig(policy="liferaft", alpha=0.0))
        fused = LifeRaftEngine(
            _adapters(), ServeConfig(policy="liferaft", alpha=0.0, fuse_k=3)
        )
        s1, s2 = base.run(_trace(seed=4)), fused.run(t)
        assert s2["n_completed"] == 120
        assert s2["token_throughput"] >= 0.8 * s1["token_throughput"]

    def test_real_decode_hook_called(self):
        calls = []
        eng = LifeRaftEngine(
            _adapters(2),
            ServeConfig(policy="liferaft"),
            decode_batch_fn=lambda a, b, q: calls.append((a, len(b), q)),
        )
        eng.run(_trace(n=10, n_adapters=2))
        assert calls and all(q == 16 for _, _, q in calls)

"""Scan-horizon prefetch subsystem tests (core/scanplan.py, core/prefetch.py)
plus the demand-aware BucketCache and the priced spill victim walk.

Property invariants locked down here:
  * the committed horizon is always a *prefix-consistent reorder* of the
    scheduler's heap order — a permutation of ``peek_topk(H)``: nothing
    invented, nothing from the top-H dropped, only the staging order
    within the horizon is layout-driven (elevator sweep);
  * ``peek_topk`` is non-mutating and bit-identical between the
    incremental scheduler and the naive oracle, so both commit the same
    horizon;
  * invalidation never starves the oldest pending bucket: after
    ``starvation_deferrals`` commits that leave it behind, it is forced
    to the horizon front;
  * a horizon-protected bucket is never evicted while protected, and
    with a demand probe installed, zero-demand residents are preferred
    victims;
  * ``CacheStats`` splits demand hits from prefetch fills (hit rate
    stays a demand statistic);
  * cache edge cases are explicit now: over-pinned inserts raise
    ``CacheOverflowError`` instead of silently exceeding capacity, and
    invalidating a pinned bucket is a hard error;
  * with ``price_spill_victims``, the spill victim walk evicts the
    lowest T_spill wait-cost-per-byte queue first while the oldest queue
    still walks last (and is never fully spilled); the default walk is
    bit-for-bit the legacy youngest-first order.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BucketCache,
    CacheOverflowError,
    ControlConfig,
    ControlVector,
    CostModel,
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
    PrefetchConfig,
    PrefetchPipeline,
    ScanPlanConfig,
    ScanPlanner,
    apply_spill,
    build_pipeline,
    run_policy,
    unspill_price,
)
from repro.core.workload import Query, WorkloadManager

import replay


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _mk_query(qid, t, buckets):
    ks = np.asarray(buckets, dtype=np.uint64)
    return Query(qid, t, ks, ks)


def _workload_from_seed(seed, n_queries=30, n_buckets=12):
    rng = np.random.default_rng(seed)
    wm = WorkloadManager(_identity_range, probe_bytes=4.0)
    t = 0.0
    for qid in range(n_queries):
        t += float(rng.exponential(0.1))
        n = int(rng.integers(1, 5))
        wm.submit(_mk_query(qid, t, rng.integers(0, n_buckets, n)))
    return wm, t


# ------------------------------------------------------------- ScanPlanner
class TestScanPlanner:
    @given(st.integers(0, 10_000), st.integers(1, 10))
    @settings(max_examples=40, deadline=None)
    def test_horizon_is_prefix_consistent_reorder_of_heap_order(self, seed, h):
        """The committed horizon is a permutation of the scheduler's own
        top-H peek — the planner reorders, it never edits the set."""
        wm, now = _workload_from_seed(seed)
        cache = BucketCache(4)
        sched = LifeRaftScheduler(CostModel(T_b=0.1, T_m=1e-3), alpha=0.3)
        planner = ScanPlanner(sched, ScanPlanConfig(horizon=h))
        plan = planner.plan(wm, cache, now)
        top = [d.bucket_id for d in sched.peek_topk(wm, cache, now, h)]
        assert sorted(plan) == sorted(top)

    @given(st.integers(0, 10_000), st.integers(1, 8))
    @settings(max_examples=25, deadline=None)
    def test_peek_topk_is_non_mutating_and_matches_oracle(self, seed, k):
        wm, now = _workload_from_seed(seed)
        cache = BucketCache(4)
        cost = CostModel(T_b=0.1, T_m=1e-3)
        inc = LifeRaftScheduler(cost, alpha=0.4, normalized=True)
        nai = NaiveLifeRaftScheduler(cost, alpha=0.4, normalized=True)
        got = [(d.bucket_id, d.score) for d in inc.peek_topk(wm, cache, now, k)]
        want = [(d.bucket_id, d.score) for d in nai.peek_topk(wm, cache, now, k)]
        assert got == want
        # Peeking left the incremental index untouched: the next select
        # still bit-matches the oracle.
        d_inc = inc.select(wm, cache, now + 0.5)
        d_nai = nai.select(wm, cache, now + 0.5)
        assert (d_inc.bucket_id, d_inc.score) == (d_nai.bucket_id, d_nai.score)

    def test_elevator_sweep_continues_from_head(self):
        """Candidates at/after the head sweep ascending first; the
        stragglers behind come on the way back, descending."""
        wm, now = _workload_from_seed(3, n_queries=40, n_buckets=20)
        cache = BucketCache(4)
        sched = LifeRaftScheduler(CostModel(T_b=0.1, T_m=1e-3), alpha=0.0)
        planner = ScanPlanner(
            sched, ScanPlanConfig(horizon=8, starvation_deferrals=10**9)
        )
        planner.note_serviced([9])  # head at layout position 9
        plan = planner.plan(wm, cache, now)
        ahead = [b for b in plan if b >= 9]
        behind = [b for b in plan if b < 9]
        assert plan == ahead + behind
        assert ahead == sorted(ahead)
        assert behind == sorted(behind, reverse=True)

    def test_invalidation_never_starves_the_oldest_pending_bucket(self):
        """Adversarial reshuffling: new deep arrivals keep re-sorting the
        committed horizon so the oldest pending bucket (a shallow greedy
        loser) always lands at the back of the sweep.  After
        ``starvation_deferrals`` commits the guard must force it front."""
        wm = WorkloadManager(_identity_range, probe_bytes=4.0)
        wm.submit(_mk_query(0, 0.0, [5]))  # the oldest pending bucket
        for qid in range(1, 4):
            wm.submit(_mk_query(qid, 0.1 * qid, [10 + qid] * 6))
        cache = BucketCache(4)
        sched = LifeRaftScheduler(CostModel(T_b=0.5, T_m=1e-3), alpha=0.0)
        planner = ScanPlanner(
            sched, ScanPlanConfig(horizon=4, starvation_deferrals=3)
        )
        qid, fronted = 4, None
        for commit in range(8):
            # reshuffle each commit: another deep unit perturbs the scores
            wm.submit(_mk_query(qid, 1.0 + 0.1 * commit, [11 + commit % 3] * 6))
            qid += 1
            plan = planner.plan(wm, cache, 2.0 + 0.1 * commit)
            assert 5 in plan  # horizon covers all four buckets
            if plan[0] == 5:
                fronted = commit
                break
        assert fronted is not None, "oldest pending bucket never fronted"
        assert fronted <= planner.cfg.starvation_deferrals + 1

    def test_planner_without_peek_commits_nothing(self):
        class NoPeek:
            pass

        wm, now = _workload_from_seed(1)
        planner = ScanPlanner(NoPeek(), ScanPlanConfig(horizon=4))
        assert planner.plan(wm, BucketCache(4), now) == []

    def test_deferrals_survive_horizon_oscillation(self):
        """A still-pending bucket bouncing in and out of the top-H (each
        reshuffle drops the promise) keeps accumulating deferrals — a
        drop from the committed horizon must not wipe the count — and is
        fronted the next time it qualifies."""
        from repro.core import SchedulerDecision

        wm = WorkloadManager(_identity_range, probe_bytes=4.0)
        wm.submit(_mk_query(0, 0.0, [5]))  # oldest pending, rank-boundary
        for qid, b in enumerate([10, 11, 12], start=1):
            wm.submit(_mk_query(qid, 0.1 * qid, [b] * 4))

        class Scripted:
            next: list[int] = []

            def peek_topk(self, wm, cache, now, k):
                return [
                    SchedulerDecision(b, 0.0, False, 1) for b in self.next
                ]

        sched = Scripted()
        planner = ScanPlanner(
            sched, ScanPlanConfig(horizon=3, starvation_deferrals=3)
        )
        cache = BucketCache(4)
        fronted = None
        for i, cands in enumerate(
            [[10, 5, 11], [10, 11, 12], [10, 5, 11], [10, 11, 12], [10, 5, 11]]
        ):
            sched.next = cands
            plan = planner.plan(wm, cache, float(i))
            if plan and plan[0] == 5:
                fronted = i
                break
        assert fronted is not None, "oscillating oldest bucket never fronted"

    def test_build_pipeline_rejects_peekless_scheduler(self):
        """prefetch configured on a scheduler that cannot be peeked (round
        robin) is a misconfiguration, not a silent no-op."""
        from repro.core import RoundRobinScheduler

        with pytest.raises(ValueError, match="peek_topk"):
            build_pipeline(
                True, RoundRobinScheduler(CostModel()), BucketCache(4), 1.0
            )


# ------------------------------------------------- demand-aware BucketCache
class TestDemandAwareCache:
    @given(
        st.lists(st.integers(0, 15), min_size=1, max_size=200),
        st.integers(3, 8),
    )
    @settings(max_examples=40, deadline=None)
    def test_protected_bucket_never_evicted_while_protected(self, accesses, cap):
        c = BucketCache(cap)
        protected = {0, 1}  # within the capacity - 1 protection cap
        c.protect(protected)
        for b in accesses:
            evicted = c.access(b)
            assert not (set(evicted) & protected)
        assert len(c) <= cap

    def test_zero_demand_residents_are_preferred_victims(self):
        c = BucketCache(2)
        demand = {1: 5, 2: 0}
        c.set_demand_probe(lambda b: demand.get(b, 0))
        c.access(1)
        c.access(2)  # LRU order: 1 (oldest), 2
        evicted = c.access(3)
        # plain LRU would evict 1; demand-aware eviction picks idle 2
        assert evicted == [2]
        assert c.contains(1)

    def test_demand_fallback_is_lru_when_everyone_has_demand(self):
        c = BucketCache(2)
        c.set_demand_probe(lambda b: 1)
        c.access(1)
        c.access(2)
        assert c.access(3) == [1]

    def test_stats_split_demand_hits_from_prefetch_fills(self):
        c = BucketCache(4)
        assert c.insert_prefetched(7) == []
        assert c.stats.prefetch_fills == 1
        assert c.stats.accesses == 0  # a fill is not an access
        c.access(7)  # first demand touch of the prefetched entry
        assert c.stats.hits == 1 and c.stats.prefetch_hits == 1
        assert c.stats.demand_hits == 0
        c.access(7)  # second touch: ordinary locality
        assert c.stats.hits == 2 and c.stats.prefetch_hits == 1
        assert c.stats.demand_hits == 1

    def test_unused_prefetch_eviction_is_counted_as_waste(self):
        c = BucketCache(1)
        c.insert_prefetched(1)
        c.access(2)  # evicts the untouched prefetched fill
        assert c.stats.prefetch_unused == 1

    def test_prefetch_fill_refused_when_no_victim(self):
        c = BucketCache(2)
        c.access(1)
        c.access(2)
        c.pin(1)
        c.protect([2])
        assert c.insert_prefetched(3) is None  # refused, not raised
        assert not c.contains(3)
        assert len(c) == 2

    def test_protection_capped_below_capacity(self):
        c = BucketCache(3)
        c.protect([1, 2, 3, 4])
        assert len(c.protected()) == 2  # capacity - 1
        for b in (1, 2, 3):
            c.access(b)
        assert c.access(4)  # a victim always exists for demand inserts


# ------------------------------------------------------ cache edge cases
class TestCacheEdgeCases:
    def test_overpinned_insert_raises_instead_of_overflowing(self):
        c = BucketCache(2)
        c.access(1)
        c.access(2)
        c.pin(1)
        c.pin(2)
        c.pin(3)  # pinned before residency: nothing evictable on insert
        with pytest.raises(CacheOverflowError):
            c.access(3)
        assert len(c) <= c.capacity  # never silently exceeds capacity

    def test_pinned_insert_evicts_newcomer_not_overflow(self):
        """Pinning everything *resident* is still survivable: the insert
        itself is the only victim candidate (historical behavior)."""
        c = BucketCache(1)
        c.access(1)
        c.pin(1)
        c.access(2)  # 2 is evictable; 1 stays
        assert c.contains(1) and len(c) == 1

    def test_invalidate_pinned_is_a_hard_error(self):
        c = BucketCache(2)
        c.access(1)
        c.pin(1)
        with pytest.raises(ValueError):
            c.invalidate([1])
        assert c.contains(1)
        c.unpin(1)
        c.invalidate([1])
        assert not c.contains(1)


# ------------------------------------------------------- PrefetchPipeline
class TestPrefetchPipeline:
    def _trace(self, seed, n=120, buckets=30, depth=(50, 300)):
        rng = np.random.default_rng(seed)
        qs, t = [], 0.0
        for qid in range(n):
            t += float(rng.exponential(0.05))
            b = int(rng.integers(0, buckets))
            ks = np.full(int(rng.integers(*depth)), b, dtype=np.uint64)
            qs.append(Query(qid, t, ks, ks))
        return qs

    def test_prefetch_overlaps_io_with_compute(self):
        """On a T_b-dominated workload whose compute is comparable to the
        bucket read, staging ahead must beat the reactive LRU (the I/O
        moves off the critical path)."""
        cost = CostModel(T_b=0.08, T_m=2e-4)
        qs = self._trace(11)
        off = run_policy("liferaft", qs, _identity_range, cost, alpha=0.25,
                         cache_capacity=8)
        on = run_policy("liferaft", qs, _identity_range, cost, alpha=0.25,
                        cache_capacity=8, prefetch=True)
        assert on.makespan < off.makespan
        assert on.n_queries == off.n_queries  # same completions, faster

    def test_stall_is_residual_not_full_read(self):
        """A demanded in-flight stage pays eta - now, never a full T_b on
        top of the staging already under way."""
        cache = BucketCache(4)
        sched = LifeRaftScheduler(CostModel(T_b=1.0, T_m=1e-3), alpha=0.0)
        planner = ScanPlanner(sched, ScanPlanConfig(horizon=2))
        pipe = PrefetchPipeline(cache, planner, 1.0, depth=2)
        wm = WorkloadManager(_identity_range)
        wm.submit(_mk_query(0, 0.0, [1, 2]))

        class _D:
            def __init__(self, b):
                self.bucket_id = b

        # round at t=0 services bucket 1, stages bucket 2 (eta=1.0)
        stall0 = pipe.stage(wm, 0.0, [_D(1)])
        assert stall0 == 0.0 and pipe.inflight == 1
        # bucket 2 demanded at t=0.6: residual stall 0.4, and it lands
        stall1 = pipe.stage(wm, 0.6, [_D(2)])
        assert stall1 == pytest.approx(0.4)
        assert cache.contains(2)
        assert cache.stats.prefetch_fills == 1

    def test_incremental_vs_oracle_identical_with_prefetch_on(self):
        cost = CostModel(T_b=0.08, T_m=2e-4)
        qs = self._trace(23, n=100)
        traces = {}
        for policy in ("liferaft", "liferaft-naive"):
            rec = replay.TraceRecorder()
            run_policy(policy, qs, _identity_range, cost, alpha=0.25,
                       cache_capacity=8, normalized=True, fuse_k=2,
                       prefetch=True, on_round=rec)
            traces[policy] = rec.entries
        divergence = replay.diff_traces(
            traces["liferaft-naive"], traces["liferaft"]
        )
        assert not divergence, "\n".join(divergence)

    def test_serving_engine_prefetch_path(self):
        from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig

        rng = np.random.default_rng(5)
        adapters = [AdapterSpec(i, 8 << 30) for i in range(8)]
        reqs, t = [], 0.0
        for i in range(120):
            t += float(rng.exponential(1.0 / 150.0))
            reqs.append(Request(i, int(rng.integers(0, 8)), t,
                                int(rng.integers(8, 64)), 16))
        base = LifeRaftEngine(
            adapters, ServeConfig(policy="liferaft", alpha=0.25, fuse_k=2)
        )
        base.run([Request(r.request_id, r.adapter_id, r.arrival_time,
                          r.prompt_len, r.max_new_tokens) for r in reqs])
        eng = LifeRaftEngine(
            adapters,
            ServeConfig(policy="liferaft", alpha=0.25, fuse_k=2, prefetch=True),
        )
        out = eng.run(reqs)
        assert out["n_completed"] == len(reqs)
        assert eng.cache.stats.prefetch_fills > 0
        assert eng.loop.prefetch.staged > 0
        assert eng.clock <= base.clock  # staged adapter loads never lose

    def test_crossmatch_threaded_staging_preserves_results(self):
        """The cross-match engine stages real bucket payloads on a thread
        pool while cost accounting stays on the virtual channel: match
        results must be identical to the reactive run, the staged
        payloads must be the real store reads, and the virtual clock must
        not regress."""
        from repro.crossmatch import (
            CrossMatchEngine, TraceConfig, make_catalog, make_trace,
        )

        catalog = make_catalog(
            n_objects=2_000, objects_per_bucket=100, htm_level=6, seed=17
        )
        trace = make_trace(catalog, TraceConfig(
            n_queries=16, arrival_rate=2.0, objects_median=40, seed=19,
        ))

        def run(pf):
            eng = CrossMatchEngine(
                catalog, match_radius_rad=4e-3, fuse_k=2, cache_capacity=6,
                prefetch=pf,
            )
            return eng, eng.run(trace)

        e_off, r_off = run(False)
        e_on, r_on = run(PrefetchConfig(horizon=4, depth=3))
        try:
            assert e_on.loop.prefetch.fills > 0
            assert e_on.sim_clock <= e_off.sim_clock
            assert set(r_off) == set(r_on)
            for qid in r_off:
                assert len(r_off[qid]) == len(r_on[qid])
                for ma, mb in zip(r_off[qid], r_on[qid]):
                    np.testing.assert_array_equal(ma.probe_idx, mb.probe_idx)
                    np.testing.assert_array_equal(ma.match_obj, mb.match_obj)
                    np.testing.assert_allclose(ma.best_dot, mb.best_dot)
        finally:
            e_on.loop.prefetch.close()

    def test_adaptive_horizon_law_engages(self):
        """With prefetch_horizon_max set, the ControlLoop sizes H and the
        vector carries a nonzero horizon."""
        from repro.core import ControlLoop

        cost = CostModel(T_b=0.08, T_m=2e-4)
        qs = self._trace(31, n=80)
        ctl = ControlLoop(ControlConfig(
            alpha_init=0.3, alpha_step=0.2, prefetch_horizon_init=2,
            prefetch_horizon_max=8,
        ))
        rec = replay.TraceRecorder()
        r = run_policy("liferaft", qs, _identity_range, cost,
                       cache_capacity=8, normalized=True, control=ctl,
                       prefetch=True, on_round=rec)
        assert r.n_queries == len(qs)
        assert ctl.last.horizon >= 1

    def test_build_pipeline_off_is_none(self):
        sched = LifeRaftScheduler(CostModel(), alpha=0.0)
        assert build_pipeline(False, sched, BucketCache(4), 1.0) is None
        pipe = build_pipeline(
            PrefetchConfig(horizon=6, depth=3), sched, BucketCache(4), 1.0
        )
        assert pipe.depth == 3 and pipe.planner.cfg.horizon == 6


# ------------------------------------------------ priced spill victim walk
class TestPricedSpillVictims:
    def _wm(self):
        """Three queues, same arrival shape, very different byte weights:
        bucket 1 oldest/heavy, 2 mid, 3 youngest/light."""
        wm = WorkloadManager(_identity_range, probe_bytes=1.0)
        qid = 0
        sizes = {1: 40, 2: 10, 3: 2}
        for i, b in enumerate([1, 2, 3]):
            for j in range(5):
                ks = np.full(sizes[b], b, dtype=np.uint64)
                wm.submit(Query(qid, float(i) + 0.1 * j, ks, ks))
                qid += 1
        return wm

    def test_unpriced_walk_is_youngest_first_unchanged(self):
        # price_spill_victims=False opts back into the legacy walk
        # (pre-PR-6 default; see the golden waiver in docs/adaptive.md).
        wm = self._wm()
        cfg = ControlConfig(
            spill_budget_bytes=215.0, price_spill_victims=False
        )
        changed = apply_spill(
            wm, ControlVector(0.5, 1, True), cfg,
            cost=CostModel(T_spill=0.5),
        )
        # legacy order: youngest (3) first, then 2
        assert changed == [3, 2]

    def test_priced_walk_evicts_lowest_relief_per_byte_first(self):
        wm = self._wm()
        cfg = ControlConfig(spill_budget_bytes=215.0, price_spill_victims=True)
        cost = CostModel(T_spill=0.5)
        qs = {q.bucket_id: q for q in wm.nonempty_queues()}
        # bucket 2 (50 B) has lower T_spill/nbytes than bucket 3 (10 B):
        # evicting it buys the deficit at the least future wait per byte.
        assert unspill_price(qs[2], cost) < unspill_price(qs[3], cost)
        changed = apply_spill(wm, ControlVector(0.5, 1, True), cfg, cost=cost)
        assert changed[0] == 2
        assert 1 not in changed or wm.queues[1].resident_size > 0

    def test_priced_walk_unpriced_degenerates_to_youngest_first(self):
        for cost in (None, CostModel(T_spill=0.0)):
            wm = self._wm()
            cfg = ControlConfig(
                spill_budget_bytes=215.0, price_spill_victims=True
            )
            changed = apply_spill(
                wm, ControlVector(0.5, 1, True), cfg, cost=cost
            )
            assert changed == [3, 2], cost

    def test_priced_walk_never_fully_spills_oldest_queue(self):
        wm = self._wm()
        cfg = ControlConfig(spill_budget_bytes=0.0, price_spill_victims=True)
        apply_spill(
            wm, ControlVector(0.5, 1, True), cfg, cost=CostModel(T_spill=0.5)
        )
        q1 = wm.queues[1]
        assert q1.resident_size > 0
        assert wm.resident_bytes() == q1.resident_bytes

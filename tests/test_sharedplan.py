"""Shared query plans: the query axis fused into one masked device call.

Three layers under test:

* kernel — ``crossmatch_shared`` (traced per-probe thresholds) must be
  bit-identical to the per-query ``crossmatch`` loop on both the jnp
  reference path and the Pallas tile-skip path, across padded/sentinel
  edge shapes (property-based);
* compile bounding — K distinct predicates in one shared call must cost
  at most one ``jit_cache_size`` entry per pow2 shape pair, not K;
* control + engine — the AIMD ``share_width`` law, and the cross-match
  engine's ``execute_shared`` producing results bit-equal to the
  per-predicate off path while issuing strictly fewer device dispatches.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.control import ControlConfig, ControlLoop, Telemetry
from repro.kernels.crossmatch import ops as cm_ops


def _unit_rows(rng, n):
    v = rng.normal(size=(n, 3))
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _make_case(rng, n_buckets, n_queries, rows_hi, n_empty):
    """Concatenated multi-bucket layout + per-query probe batches.

    ``n_empty`` trailing buckets get payload rows but no probes (the
    zero-query-bucket edge); queries draw heterogeneous thresholds.
    """
    sizes = [int(rng.integers(1, 30)) for _ in range(n_buckets + n_empty)]
    payloads = [_unit_rows(rng, s) for s in sizes]
    row_off = np.cumsum([0] + sizes[:-1])
    bucket_cat = np.concatenate(payloads)
    bseg = np.concatenate(
        [np.full(s, i, np.int64) for i, s in enumerate(sizes)]
    )
    queries = []
    for _ in range(n_queries):
        b = int(rng.integers(0, n_buckets))
        m = int(rng.integers(1, rows_hi + 1))
        # Probes near the bucket's own rows so thresholds actually bite.
        base = payloads[b][rng.integers(0, sizes[b], m)]
        probes = base + rng.normal(scale=2e-3, size=(m, 3))
        probes /= np.linalg.norm(probes, axis=1, keepdims=True)
        thr = float(rng.choice([0.95, 0.999, 0.999998]))
        queries.append((b, probes, thr))
    return bucket_cat, bseg, row_off, payloads, queries


def _bits(a):
    return np.ascontiguousarray(np.asarray(a, np.float32)).view(np.int32)


class TestSharedKernel:
    @given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 20),
           st.integers(0, 2))
    @settings(max_examples=8, deadline=None)
    def test_shared_equals_per_query_loop(
        self, n_buckets, n_queries, rows_hi, n_empty
    ):
        """One shared masked call == the per-query crossmatch loop, bit
        for bit, on both kernel paths and across edge shapes."""
        seed = 100_000 * n_buckets + 10_000 * n_queries + 13 * rows_hi + n_empty
        rng = np.random.default_rng(seed)
        bucket_cat, bseg, row_off, payloads, queries = _make_case(
            rng, n_buckets, n_queries, rows_hi, n_empty
        )
        probes_cat = np.concatenate([p for _, p, _ in queries])
        pseg = np.concatenate(
            [np.full(len(p), b, np.int64) for b, p, _ in queries]
        )
        thr_row = np.concatenate(
            [np.full(len(p), t, np.float32) for _, p, t in queries]
        )
        for use_pallas in (False, True):
            kw = dict(use_pallas=use_pallas, bm=8, bn=8, interpret=True)
            s_idx, s_dot, s_cnt = map(np.asarray, cm_ops.crossmatch_shared(
                bucket_cat, probes_cat, bseg, pseg, thr_row, **kw
            ))
            at = 0
            for b, probes, thr in queries:
                idx, dot, cnt = cm_ops.crossmatch(
                    payloads[b], probes, thr, **kw
                )
                sl = slice(at, at + len(probes))
                np.testing.assert_array_equal(
                    s_idx[sl] - row_off[b], np.asarray(idx)
                )
                np.testing.assert_array_equal(_bits(s_dot[sl]), _bits(dot))
                np.testing.assert_array_equal(s_cnt[sl], np.asarray(cnt))
                at += len(probes)

    def test_single_query_single_probe(self):
        """Minimal shapes: one query, one probe row, one bucket row."""
        bucket = np.array([[1.0, 0.0, 0.0]])
        probes = np.array([[1.0, 0.0, 0.0]])
        idx, dot, cnt = cm_ops.crossmatch_shared(
            bucket, probes, np.zeros(1), np.zeros(1), np.array([0.99])
        )
        assert int(idx[0]) == 0 and int(cnt[0]) == 1
        assert float(dot[0]) == pytest.approx(1.0)

    def test_ref_vs_pallas_bit_identical(self):
        rng = np.random.default_rng(7)
        bucket_cat, bseg, row_off, payloads, queries = _make_case(
            rng, 3, 4, 12, 1
        )
        probes_cat = np.concatenate([p for _, p, _ in queries])
        pseg = np.concatenate(
            [np.full(len(p), b, np.int64) for b, p, _ in queries]
        )
        thr_row = np.concatenate(
            [np.full(len(p), t, np.float32) for _, p, t in queries]
        )
        r = cm_ops.crossmatch_shared(
            bucket_cat, probes_cat, bseg, pseg, thr_row, use_pallas=False
        )
        p = cm_ops.crossmatch_shared(
            bucket_cat, probes_cat, bseg, pseg, thr_row,
            use_pallas=True, bm=8, bn=8, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))
        np.testing.assert_array_equal(_bits(r[1]), _bits(p[1]))
        np.testing.assert_array_equal(np.asarray(r[2]), np.asarray(p[2]))

    def test_shared_compiles_once_for_k_predicates(self):
        """K distinct thresholds at one pow2 shape pair: exactly one new
        compile-cache entry (the per-query static path would add K)."""
        rng = np.random.default_rng(11)
        bucket = _unit_rows(rng, 33)  # pads to 64: a fresh shape pair
        base = cm_ops.jit_cache_size()
        for k in range(6):  # 6 distinct predicates, same shapes
            probes = _unit_rows(rng, 9)  # pads to 16
            thr = np.full(9, 0.9 + 0.01 * k, np.float32)
            cm_ops.crossmatch_shared(
                bucket, probes, np.zeros(33), np.zeros(9), thr
            )
        assert cm_ops.jit_cache_size() == base + 1


class TestShareWidthLaw:
    def _tel(self, occ):
        return Telemetry(0.0, 1.0, 10, 10, 1, 0.0, 0.5, 0.5,
                        shared_occupancy=occ)

    def test_disabled_without_ceiling(self):
        loop = ControlLoop(ControlConfig(share_width_init=4))
        assert loop.update(self._tel(1.0)).share_width == 0

    def test_aimd_widen_narrow_clamp(self):
        cfg = ControlConfig(share_width_init=4, share_width_max=6,
                            share_occ_low=0.5, share_occ_high=0.95)
        loop = ControlLoop(cfg)
        assert loop.update(self._tel(1.0)).share_width == 5  # saturated: widen
        assert loop.update(self._tel(1.0)).share_width == 6
        assert loop.update(self._tel(1.0)).share_width == 6  # ceiling
        assert loop.update(self._tel(0.7)).share_width == 6  # in-band: hold
        assert loop.update(self._tel(0.1)).share_width == 5  # padding: narrow
        for _ in range(8):
            loop.update(self._tel(0.0))
        assert loop.update(self._tel(0.0)).share_width == 1  # floor


class TestEngineSharedPlan:
    def _setup(self, **eng_kw):
        from repro.crossmatch import (
            CrossMatchEngine, TraceConfig, make_catalog, make_trace,
        )

        catalog = make_catalog(
            n_objects=2_000, objects_per_bucket=100, htm_level=6, seed=17
        )
        trace = make_trace(
            catalog,
            TraceConfig(n_queries=14, arrival_rate=2.0, objects_median=40,
                        seed=19),
        )
        rng = np.random.default_rng(5)
        for q in trace:
            q.meta["radius"] = float(rng.choice([2e-3, 4e-3, 8e-3]))
            q.meta["mag_cut"] = float(rng.choice([23.0, 24.0, 25.0]))
        eng = CrossMatchEngine(
            catalog, match_radius_rad=4e-3, fuse_k=3, **eng_kw
        )
        return eng, trace

    @staticmethod
    def _assert_same_results(a, b):
        assert set(a) == set(b)
        for qid in a:
            ra = sorted(a[qid], key=lambda r: r.probe_idx.min() if len(r.probe_idx) else -1)
            rb = sorted(b[qid], key=lambda r: r.probe_idx.min() if len(r.probe_idx) else -1)
            assert len(ra) == len(rb)
            for x, y in zip(ra, rb):
                np.testing.assert_array_equal(x.probe_idx, y.probe_idx)
                np.testing.assert_array_equal(x.match_obj, y.match_obj)
                np.testing.assert_array_equal(_bits(x.best_dot), _bits(y.best_dot))
                np.testing.assert_array_equal(x.n_candidates, y.n_candidates)

    def test_shared_bit_equal_and_fewer_dispatches(self):
        eng_off, trace = self._setup(shared_plan=False)
        res_off = eng_off.run(trace)
        eng_on, trace2 = self._setup(shared_plan=True, share_width=8)
        res_on = eng_on.run(trace2)
        self._assert_same_results(res_off, res_on)
        off = eng_off.summary()["device_dispatches"]
        on = eng_on.summary()["device_dispatches"]
        assert on < off  # the whole point of the shared plan
        assert 0.0 < eng_on.summary()["shared_batch_occupancy"] <= 1.0

    def test_width_one_chunking_still_bit_equal(self):
        """width < live queries: the executor chunks, results unchanged."""
        eng_off, trace = self._setup(shared_plan=False)
        res_off = eng_off.run(trace)
        eng_on, trace2 = self._setup(shared_plan=True, share_width=1)
        res_on = eng_on.run(trace2)
        self._assert_same_results(res_off, res_on)

    def test_width_exceeding_queries(self):
        """share_width far beyond the live query count: one chunk, low
        occupancy, same results."""
        eng_off, trace = self._setup(shared_plan=False)
        res_off = eng_off.run(trace)
        eng_on, trace2 = self._setup(shared_plan=True, share_width=64)
        res_on = eng_on.run(trace2)
        self._assert_same_results(res_off, res_on)
        assert eng_on.summary()["shared_batch_occupancy"] < 0.5

    def test_zero_query_bucket(self):
        """execute_shared on a bucket with no pending work: no crash, no
        device dispatch."""
        eng, _ = self._setup(shared_plan=True)
        before = eng.loop.device_dispatches
        eng.execute_shared([0])
        assert eng.loop.device_dispatches == before

"""Property tests: the incremental LifeRaft scheduler is decision-identical
to the naive O(B)-rescan oracle under randomized workloads — submits,
completions, cache churn, alpha sweeps, and deliberate ties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BucketCache,
    CostModel,
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
)
from repro.core.workload import Query, WorkloadManager


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


TENANTS = ("default", "interactive", "batch")


def _mk_query(qid, t, buckets, tenant="default"):
    ks = np.asarray(buckets, dtype=np.uint64)
    return Query(qid, t, ks, ks, meta={"tenant": tenant})


class _Mirror:
    """Two identical (workload, cache) pairs driven in lockstep, one
    selected by the incremental scheduler and one by the oracle."""

    def __init__(self, alpha, cache_cap=6, normalized=False, cost=None):
        cm = cost or CostModel()
        self.inc = LifeRaftScheduler(cm, alpha=alpha, normalized=normalized)
        self.nai = NaiveLifeRaftScheduler(cm, alpha=alpha, normalized=normalized)
        self.wm_i = WorkloadManager(_identity_range)
        self.wm_n = WorkloadManager(_identity_range)
        self.cache_i = BucketCache(cache_cap)
        self.cache_n = BucketCache(cache_cap)

    def submit(self, qid, t, buckets, tenant="default"):
        self.wm_i.submit(_mk_query(qid, t, buckets, tenant))
        self.wm_n.submit(_mk_query(qid, t, buckets, tenant))

    def set_alpha(self, a):
        self.inc.alpha = a
        self.nai.alpha = a

    def set_tenant_alphas(self, alphas):
        """Per-tenant Eq. 2 blends on both sides (each side's tenant_of
        reads its own workload, but the workloads are mirrored)."""
        self.inc.set_tenant_alphas(alphas, self.wm_i.tenant_of_bucket)
        self.nai.set_tenant_alphas(alphas, self.wm_n.tenant_of_bucket)

    def touch_cache(self, b):
        self.cache_i.access(b)
        self.cache_n.access(b)

    def spill(self, b, frac=1.0):
        self.wm_i.spill_bucket(b, frac)
        self.wm_n.spill_bucket(b, frac)

    def unspill(self, b, budget=None):
        """Wholesale (budget=None) or paged (budget_bytes) unspill on both
        sides — partial unspill must update sigma/resident through the
        change notification exactly like a spill does."""
        self.wm_i.unspill_bucket(b, budget_bytes=budget)
        self.wm_n.unspill_bucket(b, budget_bytes=budget)

    def compare_select(self, now):
        di = self.inc.select(self.wm_i, self.cache_i, now)
        dn = self.nai.select(self.wm_n, self.cache_n, now)
        if dn is None:
            assert di is None
            return None
        assert di.bucket_id == dn.bucket_id, (now, di, dn)
        assert di.score == dn.score  # bit-identical, not approx
        assert di.in_cache == dn.in_cache
        assert di.queue_size == dn.queue_size
        return dn

    def complete(self, b, now):
        self.wm_i.complete_bucket(b, now)
        self.wm_n.complete_bucket(b, now)


class TestIncrementalEquivalence:
    @given(st.integers(0, 10_000), st.floats(0.0, 1.0), st.integers(0, 1))
    @settings(max_examples=25, deadline=None)
    def test_randomized_trace_decisions_identical(self, seed, alpha, norm):
        """Covers both scoring modes: raw scales and the monotone rebased
        ``normalized=True`` form, plus §6 spill/unspill churn — whole-queue
        AND partial (byte-fraction sigma) spills (T_spill > 0 so spilling
        actually moves scores)."""
        rng = np.random.default_rng(seed)
        m = _Mirror(
            alpha, cache_cap=4, normalized=bool(norm),
            cost=CostModel(T_spill=0.8),
        )
        clock = 0.0
        qid = 0
        for _ in range(60):
            op = rng.random()
            if op < 0.40:
                # Submit; duplicated bucket ids + shared arrival times
                # manufacture exact ties in both U_t and age.
                n = int(rng.integers(1, 6))
                buckets = rng.integers(0, 12, n)
                m.submit(qid, clock, buckets)
                qid += 1
            elif op < 0.75:
                d = m.compare_select(clock)
                if d is not None:
                    m.touch_cache(d.bucket_id)
                    clock += 0.01 + 1e-4 * d.queue_size
                    m.complete(d.bucket_id, clock)
            elif op < 0.85:
                m.touch_cache(int(rng.integers(0, 12)))
            elif op < 0.95:
                b = int(rng.integers(0, 12))
                r = rng.random()
                if r < 0.3:
                    m.spill(b)  # whole queue (legacy sigma = 1)
                elif r < 0.55:
                    m.spill(b, float(rng.uniform(0.1, 0.9)))  # partial
                elif r < 0.8:
                    # Paged unspill: a byte grant pages back only part of
                    # the suffix — sigma moves without reaching 0.
                    m.unspill(b, float(rng.uniform(0.5, 8.0)))
                else:
                    m.unspill(b)
            else:
                clock += float(rng.exponential(0.5))
            m.compare_select(clock)
        # Drain fully — tie-breaks dominate at the tail.
        while m.compare_select(clock) is not None:
            d = m.compare_select(clock)
            clock += 0.01
            m.complete(d.bucket_id, clock)

    @given(st.integers(0, 10_000), st.integers(0, 1))
    @settings(max_examples=20, deadline=None)
    def test_per_tenant_alphas_decisions_identical(self, seed, norm):
        """The multi-tenant scheduler invariant: per-bucket tenant alphas
        (hot-swapped every few ops, like the plane does every round) with
        partial-spill churn in the mix — the incremental heap path must
        stay decision-bit-identical to the oracle."""
        rng = np.random.default_rng(seed)
        m = _Mirror(0.5, cache_cap=4, normalized=bool(norm),
                    cost=CostModel(T_spill=0.8))
        m.set_tenant_alphas(
            {"interactive": 0.9, "batch": 0.1}  # 'default' falls back to 0.5
        )
        clock = 0.0
        qid = 0
        for _ in range(50):
            op = rng.random()
            if op < 0.40:
                tenant = TENANTS[int(rng.integers(0, 3))]
                m.submit(qid, clock, rng.integers(0, 10, int(rng.integers(1, 5))),
                         tenant)
                qid += 1
            elif op < 0.70:
                d = m.compare_select(clock)
                if d is not None:
                    m.touch_cache(d.bucket_id)
                    clock += 0.01 + 1e-4 * d.queue_size
                    m.complete(d.bucket_id, clock)
            elif op < 0.80:
                # Hot-swap the per-tenant alphas (plane retunes per round).
                m.set_tenant_alphas({
                    "interactive": float(rng.uniform(0.5, 1.0)),
                    "batch": float(rng.uniform(0.0, 0.5)),
                })
            elif op < 0.92:
                b = int(rng.integers(0, 10))
                r = rng.random()
                if r < 0.5:
                    m.spill(b, float(rng.uniform(0.2, 1.0)))
                elif r < 0.75:
                    m.unspill(b, float(rng.uniform(0.5, 6.0)))  # paged
                else:
                    m.unspill(b)
            else:
                clock += float(rng.exponential(0.4))
            m.compare_select(clock)
        while m.compare_select(clock) is not None:
            d = m.compare_select(clock)
            clock += 0.01
            m.complete(d.bucket_id, clock)

    @given(st.integers(0, 5_000))
    @settings(max_examples=15, deadline=None)
    def test_alpha_sweep_mid_trace(self, seed):
        rng = np.random.default_rng(seed)
        m = _Mirror(0.0)
        clock = 0.0
        for qid in range(30):
            clock += float(rng.exponential(0.2))
            m.submit(qid, clock, rng.integers(0, 8, rng.integers(1, 4)))
            if qid % 5 == 4:
                m.set_alpha(float(rng.uniform(0.0, 1.0)))
            d = m.compare_select(clock)
            if d is not None and rng.random() < 0.5:
                clock += 0.05
                m.complete(d.bucket_id, clock)
                m.compare_select(clock)

    @given(st.integers(0, 5_000), st.floats(0.0, 1.0), st.integers(1, 6),
           st.integers(0, 1))
    @settings(max_examples=15, deadline=None)
    def test_topk_matches_naive_ordering(self, seed, alpha, k, norm):
        rng = np.random.default_rng(seed)
        m = _Mirror(alpha, normalized=bool(norm))
        clock = 0.0
        for qid in range(25):
            clock += float(rng.exponential(0.1))
            m.submit(qid, clock, rng.integers(0, 10, rng.integers(1, 5)))
        di = m.inc.select_topk(m.wm_i, m.cache_i, clock, k)
        dn = m.nai.select_topk(m.wm_n, m.cache_n, clock, k)
        assert [d.bucket_id for d in di] == [d.bucket_id for d in dn]
        assert [d.score for d in di] == [d.score for d in dn]
        # select_topk must not corrupt subsequent single selects
        m.compare_select(clock)

    def test_exact_ties_break_on_bucket_id(self):
        m = _Mirror(0.5)
        # Identical sizes, identical arrival times -> exact score ties.
        m.submit(0, 1.0, [3, 3, 7, 7])
        m.submit(1, 1.0, [5, 5, 9, 9])
        d = m.compare_select(2.0)
        assert d.bucket_id == 3  # smallest id wins a tie

    def test_normalized_runs_incremental_path(self):
        """normalized=True no longer forces the O(B) naive fallback: the
        lazy-heap index is populated and agrees with the oracle."""
        cm = CostModel()
        inc = LifeRaftScheduler(cm, alpha=0.5, normalized=True)
        nai = NaiveLifeRaftScheduler(cm, alpha=0.5, normalized=True)
        wm = WorkloadManager(_identity_range)
        cache = BucketCache(4)
        wm.submit(_mk_query(0, 0.0, [1, 1, 2]))
        wm.submit(_mk_query(1, 0.5, [2, 4]))
        assert not inc._use_naive(wm, cache)
        di = inc.select(wm, cache, 1.0)
        dn = nai.select(wm, cache, 1.0)
        assert di.bucket_id == dn.bucket_id and di.score == dn.score
        assert inc._entries and inc.heap_size()  # the incremental index engaged

    def test_rebuild_recovers_from_external_mutation(self):
        cm = CostModel()
        inc = LifeRaftScheduler(cm, alpha=0.0)
        wm = WorkloadManager(_identity_range)
        cache = BucketCache(4)
        wm.submit(_mk_query(0, 0.0, [1, 1]))
        wm.submit(_mk_query(1, 0.0, [2]))
        assert inc.select(wm, cache, 1.0).bucket_id == 1
        # Surgery behind the manager's back: bucket 2 becomes huge.
        wm.queues[2].units[0].object_idx = np.arange(500)
        wm.queues[2]._size = 500
        inc.mark_dirty(2)
        d = inc.select(wm, cache, 1.0)
        assert d.bucket_id == 2 and d.queue_size == 500
        inc.rebuild()
        assert inc.select(wm, cache, 1.0).bucket_id == 2


class TestAlphaHotSwap:
    """Hot-swapping ``scheduler.alpha`` mid-run triggers the ``_alpha_dirty``
    bulk re-key; its decisions must be identical to throwing the index away
    and rebuilding from scratch — including right after ``select_topk``
    suspensions, whose winners sit in ``_dirty`` awaiting restore."""

    @given(st.integers(0, 5_000), st.integers(1, 5), st.integers(0, 1))
    @settings(max_examples=15, deadline=None)
    def test_bulk_rekey_matches_fresh_rebuild(self, seed, k, norm):
        rng = np.random.default_rng(seed)
        cm = CostModel()
        wm = WorkloadManager(_identity_range)
        cache = BucketCache(4)
        live = LifeRaftScheduler(cm, alpha=0.2, normalized=bool(norm))
        nai = NaiveLifeRaftScheduler(cm, alpha=0.2, normalized=bool(norm))
        clock = 0.0
        for qid in range(30):
            clock += float(rng.exponential(0.1))
            wm.submit(_mk_query(qid, clock, rng.integers(0, 10, rng.integers(1, 4))))
        live.select(wm, cache, clock)  # bind + seed the index
        for round_no in range(6):
            # Suspend the top-k, then immediately hot-swap alpha: the bulk
            # re-key must not resurrect the suspended winners with stale keys.
            live.select_topk(wm, cache, clock, k)
            new_alpha = float(rng.uniform(0.0, 1.0))
            live.alpha = new_alpha
            nai.alpha = new_alpha
            fresh = LifeRaftScheduler(cm, alpha=new_alpha, normalized=bool(norm))
            dl = live.select(wm, cache, clock)
            df = fresh.select(wm, cache, clock)
            dn = nai.select(wm, cache, clock)
            assert dl.bucket_id == df.bucket_id == dn.bucket_id
            assert dl.score == df.score == dn.score
            fresh.rebuild()  # unsubscribe before it goes out of scope
            # churn before the next round
            clock += 0.05
            wm.complete_bucket(dl.bucket_id, clock)
            cache.access(dl.bucket_id)
            wm.submit(
                _mk_query(100 + round_no, clock, rng.integers(0, 10, 2))
            )

    def test_rekey_after_topk_suspension_restores_winners(self):
        cm = CostModel()
        wm = WorkloadManager(_identity_range)
        cache = BucketCache(4)
        inc = LifeRaftScheduler(cm, alpha=0.0)
        nai = NaiveLifeRaftScheduler(cm, alpha=0.0)
        for qid, b in enumerate([3, 3, 5, 7]):
            wm.submit(_mk_query(qid, 0.1 * qid, [b, b]))
        top = inc.select_topk(wm, cache, 1.0, k=2)
        assert len(top) == 2
        inc.alpha = 1.0  # re-key while the two winners are suspended
        nai.alpha = 1.0
        di, dn = inc.select(wm, cache, 2.0), nai.select(wm, cache, 2.0)
        assert di.bucket_id == dn.bucket_id and di.score == dn.score


class TestHeapCompaction:
    def test_heap_bounded_under_topk_churn(self):
        """Stale heap entries (completion garbage, residency re-keys,
        select_topk suspensions) must not leak: across a build-up phase
        (wide bucket fan-out + cache churn) and a full top-k drain, the
        lazy heap stays within the compaction bound and compaction
        actually fires."""
        cm = CostModel()
        wm = WorkloadManager(_identity_range)
        cache = BucketCache(6)
        inc = LifeRaftScheduler(cm, alpha=0.3)
        compactions = 0
        orig_compact = inc._compact

        def counting_compact():
            nonlocal compactions
            compactions += 1
            orig_compact()

        inc._compact = counting_compact
        rng = np.random.default_rng(7)
        clock, qid, k = 0.0, 0, 3

        def assert_bounded():
            # Invariant: the heap holds at most the compaction bound over
            # live entries (+k winners suspended awaiting the dirty-restore
            # on the next flush).
            bound = 4 * max(len(inc._entries) + k, 8)
            assert inc.heap_size() <= bound, (inc.heap_size(), bound)

        # Build-up: hundreds of buckets; every cache access flips some
        # bucket's residency and re-keys it, leaving version garbage.
        for r in range(300):
            clock += 0.02
            wm.submit(_mk_query(qid, clock, rng.integers(0, 300, 4)))
            qid += 1
            d = inc.select(wm, cache, clock)
            cache.access(int(rng.integers(0, 300)))
            if r % 5 == 0:
                wm.complete_bucket(d.bucket_id, clock)
            assert_bounded()
        # Drain: entries shrink every round while garbage lingers — the
        # regime where an unbounded heap would leak.
        while True:
            decisions = inc.select_topk(wm, cache, clock, k)
            if not decisions:
                break
            clock += 0.01
            for d in decisions:
                cache.access(d.bucket_id)
                wm.complete_bucket(d.bucket_id, clock)
            assert_bounded()
        assert compactions > 0, "compaction never triggered under churn"
        assert inc.heap_size() == 0 and len(inc._entries) == 0


class TestSelectScaling:
    def test_incremental_faster_than_naive_at_many_buckets(self):
        """Smoke-scale version of BENCH_scheduler's >=5x criterion."""
        import time

        cm = CostModel()
        wm = WorkloadManager(_identity_range)
        cache = BucketCache(8)
        rng = np.random.default_rng(0)
        for qid in range(1500):
            ks = rng.integers(0, 600, 4)
            wm.submit(_mk_query(qid, qid * 1e-3, ks))

        def timed(sched, n=150):
            sched.select(wm, cache, 2.0)  # bind/warm
            t0 = time.perf_counter()
            for r in range(n):
                sched.select(wm, cache, 2.0 + r * 1e-3)
            return (time.perf_counter() - t0) / n

        t_inc = timed(LifeRaftScheduler(cm, alpha=0.3))
        t_nai = timed(NaiveLifeRaftScheduler(cm, alpha=0.3))
        # Steady-state selects (no queue churn) are pure heap peeks for the
        # incremental index; demand a conservative 3x here (bench asserts 5x).
        assert t_nai > 3.0 * t_inc, (t_nai, t_inc)

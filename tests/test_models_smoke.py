"""Per-architecture smoke tests: reduced config, one forward/train step +
one decode step on CPU; assert output shapes and no NaNs (brief req. (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, list_archs, smoke_config
from repro.models import registry as R
from repro.models.common import pad_vocab

B, S = 2, 32


def _batch(cfg, rng):
    V = cfg.vocab_size
    if cfg.family == "encdec":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "dec_tokens": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
        }
    if cfg.family == "vlm":
        P = cfg.n_prefix
        return {
            "patches": jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, V, (B, S - P)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, V, (B, S - P)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32),
    }


@pytest.fixture(scope="module", params=list_archs())
def arch_setup(request):
    cfg = smoke_config(request.param)
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestForward:
    def test_loss_finite(self, arch_setup):
        cfg, params = arch_setup
        rng = np.random.default_rng(0)
        loss = R.loss_fn(cfg)(params, _batch(cfg, rng))
        assert loss.shape == ()
        assert jnp.isfinite(loss), f"{cfg.name}: loss={loss}"
        # Random init + masked padded vocab: loss ~ ln(V)
        assert float(loss) < 3 * np.log(cfg.vocab_size)

    def test_logits_shape(self, arch_setup):
        cfg, params = arch_setup
        rng = np.random.default_rng(1)
        batch = _batch(cfg, rng)
        batch.pop("labels", None)
        logits = R.forward_fn(cfg)(params, batch)
        V = pad_vocab(cfg.vocab_size)
        assert logits.shape == (B, S, V), (cfg.name, logits.shape)
        assert not jnp.isnan(logits).any()

    def test_grads_finite(self, arch_setup):
        cfg, params = arch_setup
        rng = np.random.default_rng(2)
        g = jax.grad(lambda p: R.loss_fn(cfg)(p, _batch(cfg, rng)))(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert leaves
        for leaf in leaves:
            assert jnp.isfinite(leaf).all(), cfg.name


class TestDecode:
    def test_decode_step(self, arch_setup):
        cfg, params = arch_setup
        max_seq = 64
        cache = R.make_cache(cfg, B, max_seq, enc_len=S)
        step = R.decode_fn(cfg, max_seq)
        token = jnp.zeros((B, 1), jnp.int32)
        logits, cache = step(params, token, cache)
        V = pad_vocab(cfg.vocab_size)
        assert logits.shape == (B, 1, V), cfg.name
        assert not jnp.isnan(logits).any(), cfg.name
        assert int(cache["pos"]) == 1
        logits2, cache = step(params, token, cache)
        assert int(cache["pos"]) == 2
        assert not jnp.isnan(logits2).any(), cfg.name

    def test_decode_matches_prefill_tail(self, arch_setup):
        """Greedy decode logits == full-forward logits at the same position
        (cache correctness), for token-only families."""
        cfg, params = arch_setup
        if cfg.family in ("encdec", "vlm"):
            pytest.skip("prefix/cross caches compared in dedicated tests")
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)
        full_logits = R.forward_fn(cfg)(params, {"tokens": toks})
        cache = R.make_cache(cfg, B, 16)
        step = R.decode_fn(cfg, 16)
        logits = None
        for t in range(8):
            logits, cache = step(params, toks[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(full_logits[:, -1]),
            rtol=2e-2,
            atol=2e-2,
        )

"""End-to-end cross-match engine tests: correctness of the full Fig. 3
pipeline (scheduler -> cache -> kernel join -> per-query routing)."""
import numpy as np
import pytest

from repro.core import CostModel, LifeRaftScheduler, RoundRobinScheduler
from repro.crossmatch import CrossMatchEngine, TraceConfig, make_catalog, make_trace
from repro.core.workload import Query
from repro.core.sfc import htm_id


@pytest.fixture(scope="module")
def catalog():
    return make_catalog(n_objects=8_000, objects_per_bucket=200, htm_level=7, seed=5)


def _probe_query(catalog, qid, idx, radius=3e-3, level_offset=2):
    """A query probing exact catalog positions (guaranteed matches)."""
    pos = catalog.positions[idx]
    ids = htm_id(pos, level=catalog.level)
    shift = np.uint64(2 * level_offset)
    anc = ids >> shift
    return Query(
        query_id=qid,
        arrival_time=float(qid),
        keys_lo=anc << shift,
        keys_hi=((anc + np.uint64(1)) << shift) - np.uint64(1),
        payload={"positions": pos},
    )


class TestEngineCorrectness:
    def test_self_probes_all_match(self, catalog):
        eng = CrossMatchEngine(catalog, match_radius_rad=1e-3)
        q = _probe_query(catalog, 0, np.arange(0, 512))
        eng.submit(q)
        while eng.step() is not None:
            pass
        got = np.concatenate([r.probe_idx for r in eng.results[0]])
        assert len(np.unique(got)) == 512  # every probe found its source
        rows = np.concatenate([r.match_obj for r in eng.results[0]])
        assert set(rows.tolist()) <= set(range(catalog.n_objects))

    def test_matches_are_true_neighbors(self, catalog):
        eng = CrossMatchEngine(catalog, match_radius_rad=5e-3)
        rng = np.random.default_rng(0)
        idx = rng.integers(0, catalog.n_objects, 128)
        eng.submit(_probe_query(catalog, 0, idx))
        while eng.step() is not None:
            pass
        for r in eng.results[0]:
            probe = catalog.positions[idx[r.probe_idx]]
            matched = catalog.positions[r.match_obj]
            dots = np.sum(probe * matched, axis=1)
            assert (dots >= np.cos(5e-3) - 1e-5).all()

    def test_pallas_and_jnp_paths_agree(self, catalog):
        rng = np.random.default_rng(1)
        idx = rng.integers(0, catalog.n_objects, 64)
        out = {}
        for use_pallas in (False, True):
            eng = CrossMatchEngine(
                catalog, match_radius_rad=2e-3, use_pallas=use_pallas
            )
            eng.submit(_probe_query(catalog, 0, idx))
            while eng.step() is not None:
                pass
            got = {
                (int(p), int(m))
                for r in eng.results[0]
                for p, m in zip(r.probe_idx, r.match_obj)
            }
            out[use_pallas] = got
        assert out[False] == out[True]

    def test_scheduler_choice_does_not_change_results(self, catalog):
        trace = make_trace(
            catalog, TraceConfig(n_queries=12, arrival_rate=2.0,
                                 objects_median=60, seed=9),
        )
        outs = []
        for sched in (
            LifeRaftScheduler(CostModel(), alpha=0.0),
            LifeRaftScheduler(CostModel(), alpha=1.0),
            RoundRobinScheduler(CostModel()),
        ):
            eng = CrossMatchEngine(catalog, scheduler=sched, match_radius_rad=4e-3)
            res = eng.run(trace)
            outs.append({
                qid: {(int(p), int(m)) for r in groups
                      for p, m in zip(r.probe_idx, r.match_obj)}
                for qid, groups in res.items()
            })
        assert outs[0] == outs[1] == outs[2]  # scheduling is result-invariant

    def test_fused_multibucket_matches_single(self, catalog):
        """fuse_k>1 (one segmented device call for the top-k buckets) must
        produce exactly the matches of the per-bucket path."""
        trace = make_trace(
            catalog, TraceConfig(n_queries=16, arrival_rate=2.0,
                                 objects_median=60, seed=13),
        )
        outs = {}
        for k in (1, 4):
            eng = CrossMatchEngine(catalog, match_radius_rad=4e-3, fuse_k=k)
            res = eng.run(trace)
            outs[k] = {
                qid: {(int(p), int(m)) for r in groups
                      for p, m in zip(r.probe_idx, r.match_obj)}
                for qid, groups in res.items()
            }
            if k > 1:  # dispatch amortization actually happened
                assert eng.dispatches < eng.batches
        assert outs[1] == outs[4]

    def test_fused_pallas_matches_jnp(self, catalog):
        rng = np.random.default_rng(2)
        idx = rng.integers(0, catalog.n_objects, 96)
        out = {}
        for use_pallas in (False, True):
            eng = CrossMatchEngine(
                catalog, match_radius_rad=2e-3, use_pallas=use_pallas, fuse_k=3
            )
            eng.submit(_probe_query(catalog, 0, idx))
            while eng.step() is not None:
                pass
            out[use_pallas] = {
                (int(p), int(m))
                for r in eng.results[0]
                for p, m in zip(r.probe_idx, r.match_obj)
            }
        assert out[False] == out[True]

    def test_indexed_plan_records_cache_hit(self, catalog):
        """Regression: the indexed-plan path read resident payloads via
        cache.get without recording a hit, skewing stats.hit_rate."""
        from repro.core import HybridCostModel, HybridPlanner

        planner = HybridPlanner(
            HybridCostModel(), objects_per_bucket=200, threshold_frac=0.02
        )
        eng = CrossMatchEngine(
            catalog, match_radius_rad=1e-3, hybrid=planner, cache_capacity=50
        )
        idx = np.arange(0, 400)
        # Pass 1: big queues -> scan plans establish residency.
        eng.submit(_probe_query(catalog, 0, idx))
        while eng.step() is not None:
            pass
        assert eng.cache.stats.misses > 0
        hits_before = eng.cache.stats.hits
        # Pass 2: tiny queues on the same buckets -> indexed plans on
        # resident payloads must now count as hits.
        eng.submit(_probe_query(catalog, 1, idx[:8]))
        while eng.step() is not None:
            pass
        assert eng.cache.stats.hits > hits_before

    def test_batching_shares_bucket_reads(self, catalog):
        """Two queries on the same region -> one bucket pass serves both."""
        eng = CrossMatchEngine(catalog, match_radius_rad=2e-3)
        idx = np.arange(100, 160)
        eng.submit(_probe_query(catalog, 0, idx))
        eng.submit(_probe_query(catalog, 1, idx))
        buckets_serviced = 0
        while eng.step() is not None:
            buckets_serviced += 1
        per_query = len({int(b) for q in (0, 1) for b in
                         [r.match_obj[0] for r in eng.results[q]]})
        assert buckets_serviced < 2 * max(per_query, 1) + 4
        assert eng.results[0] and eng.results[1]

"""Fault-tolerance integration: kill-and-resume training reproduces the
uninterrupted run exactly (checkpoint + deterministic data pipeline)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.training.trainer import Trainer, TrainerConfig


def _cfg():
    base = smoke_config("codeqwen1.5-7b")
    return dataclasses.replace(base, n_layers=1, d_model=32, d_ff=64,
                               n_heads=2, n_kv_heads=2, head_dim=16,
                               vocab_size=128)


def _tcfg(steps, ckpt_dir):
    return TrainerConfig(steps=steps, checkpoint_every=5, log_every=1000,
                         checkpoint_dir=str(ckpt_dir), lr=1e-3,
                         global_batch=2, seq_len=16)


class TestRestart:
    def test_resume_matches_uninterrupted(self, tmp_path):
        # uninterrupted 10-step run
        t_full = Trainer(_cfg(), _tcfg(10, tmp_path / "full"), log_fn=lambda s: None)
        hist_full = t_full.run()

        # run to step 5 (checkpoint lands), then a NEW trainer resumes
        t_a = Trainer(_cfg(), _tcfg(5, tmp_path / "resume"), log_fn=lambda s: None)
        t_a.run()
        t_b = Trainer(_cfg(), _tcfg(10, tmp_path / "resume"), log_fn=lambda s: None)
        assert t_b.start_step == 5  # picked up the checkpoint
        hist_b = t_b.run()

        full_tail = {h["step"]: h["loss"] for h in hist_full if h["step"] > 5}
        resumed = {h["step"]: h["loss"] for h in hist_b}
        assert set(resumed) == set(full_tail)
        for step in full_tail:
            np.testing.assert_allclose(resumed[step], full_tail[step],
                                       rtol=1e-4, atol=1e-5)

    def test_straggler_flag_recorded(self, tmp_path):
        t = Trainer(_cfg(), _tcfg(3, tmp_path / "s"), log_fn=lambda s: None)
        hist = t.run()
        assert all("straggler" in h for h in hist)

"""Seeded tracing-safety violations in jit-reachable code."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flag",))
def bad_branch(x, flag):
    if x > 0:
        return x
    return -x


@jax.jit
def bad_concretize(x):
    return float(x)


@jax.jit
def bad_pad(x):
    n = 37
    return jnp.pad(x, ((0, n), (0, 0)))

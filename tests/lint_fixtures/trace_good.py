"""Tracing-safe jit code: static/shape branching and helper-routed pads."""
import functools

import jax
import jax.numpy as jnp

_MIN = 8


def _pow2_ceil(n, floor=_MIN):
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


def _pad_rows(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnames=("use_ref", "bn"))
def good_core(x, y, use_ref, bn):
    if use_ref:
        return x + y
    xp = _pad_rows(x, bn)
    if x.shape[0] > 4:
        return xp * 2.0
    return xp + y[: xp.shape[0]]


@jax.jit
def good_pad(x):
    n = x.shape[0]
    return jnp.pad(x, ((0, _pow2_ceil(n) - n), (0, 0)))

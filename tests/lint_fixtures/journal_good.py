"""Mini journal module whose emit/diff field sets agree."""
TRACE_SCHEMA_VERSION = 1


def encode_outcome(outcome):
    return {
        "decisions": list(outcome.decisions),
        "cost": float(outcome.cost),
    }


def diff_entries(expect, got):
    out = []
    for i, (e, g) in enumerate(zip(expect, got)):
        for field in ("decisions", "cost"):
            if e.get(field) != g.get(field):
                out.append((i, field))
    return out

"""Reasoned waivers suppress obs-tap-pure; reasonless ones do not."""


def stamping_tap(outcome):
    outcome.obs_seen = True  # lint: allow[obs-tap-pure] harness scratch flag; never journaled or diffed
    return outcome.cost


def greedy_tap(outcome):
    outcome.decisions.clear()  # lint: allow[obs-tap-pure]


def install(loop):
    loop.add_round_tap(stamping_tap)
    loop.add_round_tap(greedy_tap)

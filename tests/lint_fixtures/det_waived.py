"""Waiver behavior: reasoned waiver suppresses, reasonless does not."""


def decide(buckets, notify):
    touched = {b.bucket_id for b in buckets}
    for b in touched:  # lint: allow[det-set-order] int bucket ids; CPython int order is insertion-deterministic
        notify(b)
    ids = {b.bucket_id for b in buckets}
    for b in ids:  # lint: allow[det-set-order]
        notify(b)

"""Determinism-clean decision-path idioms: should produce no findings."""
import time

import numpy as np


def time_pure(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def seeded(seed):
    rng = np.random.default_rng(seed)
    return rng.normal()


def ordered(queries, process):
    tenants = sorted({q.tenant for q in queries})
    for t in tenants:
        process(t)
    pending = {q.qid for q in queries}
    n = len(pending)
    return [q for q in queries if q.qid in pending], n

"""Lock idioms matching the documented hierarchy: no findings expected."""
import os
import threading


class GoodShards:
    def __init__(self, n):
        self._locks = [threading.Lock() for _ in range(n)]
        self._steal_lock = threading.Lock()

    def steal(self, thief, victim, migrate):
        with self._steal_lock:
            lo, hi = sorted((thief, victim))
            with self._locks[lo], self._locks[hi]:
                migrate()

    def constant_pair(self, migrate):
        with self._locks[0], self._locks[1]:
            migrate()

    def guarded(self, sid, work):
        self._locks[sid].acquire()
        try:
            work()
        finally:
            self._locks[sid].release()

    def io_outside(self, sid, fh, publish):
        os.fsync(fh)
        with self._locks[sid]:
            publish()

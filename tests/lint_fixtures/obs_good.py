"""Clean tap idioms: read-only observers, copies, unregistered mutators."""


class Recorder:
    def __init__(self):
        self.entries = []

    def __call__(self, outcome):
        self.entries.append(
            (outcome.cost, tuple(d.bucket_id for d in outcome.decisions))
        )

    def on_steal(self, ev):
        self.entries.append(("steal", ev.bucket_id, ev.n_units))


def copy_tap(outcome, sink=None):
    mine = list(outcome.decisions)
    mine.sort()
    if sink is not None:
        sink.append(mine)


def not_a_tap(outcome):
    outcome.decisions.clear()


def install(loop, coord):
    rec = Recorder()
    loop.add_round_tap(rec)
    loop.add_round_tap(copy_tap)
    coord.on_steal = rec.on_steal
    coord.on_round = None

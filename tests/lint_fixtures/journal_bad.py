"""Mini journal module with an emitted-but-never-diffed field."""
TRACE_SCHEMA_VERSION = 1


def encode_outcome(outcome):
    entry = {
        "decisions": list(outcome.decisions),
        "cost": float(outcome.cost),
    }
    entry["debug_note"] = "x"
    return entry


def diff_entries(expect, got):
    out = []
    for i, (e, g) in enumerate(zip(expect, got)):
        for field in ("decisions", "cost"):
            if e.get(field) != g.get(field):
                out.append((i, field))
    return out

"""Seeded determinism violations (analyzed by tests, never imported)."""
import datetime
import time

import numpy as np


def decide_when(units):
    deadline = time.time() + 5.0
    stamp = datetime.datetime.now()
    return deadline, stamp


def decide_jitter():
    rng = np.random.default_rng()
    del rng
    return np.random.rand()


def decide_order(queries, report):
    tenants = {q.tenant for q in queries}
    for t in tenants:
        report(t)
    report(tenants)

"""Seeded lock-hierarchy violations (docs/sharding.md, lock-order rules)."""
import os
import threading


class BadShards:
    def __init__(self, n):
        self._locks = [threading.Lock() for _ in range(n)]
        self._steal_lock = threading.Lock()

    def inverted_steal(self, sid, migrate):
        with self._locks[sid]:
            with self._steal_lock:
                migrate()

    def unproven_pair(self, a, b, migrate):
        with self._locks[a], self._locks[b]:
            migrate()

    def bare(self, sid, work):
        self._locks[sid].acquire()
        work()
        self._locks[sid].release()

    def io_under_lock(self, sid, fh):
        with self._locks[sid]:
            os.fsync(fh)

"""Seeded obs-tap purity violations (analyzed by tests, never imported)."""


def bad_attr_tap(outcome):
    outcome.cost = 0.0


def bad_mutator_tap(outcome):
    outcome.decisions.append(None)


def bad_alias_tap(outcome):
    ds = outcome.decisions
    ds.clear()


def bad_aug_tap(ev):
    ev.n_units += 1


def bad_item_tap(outcome):
    outcome.decisions[0] = None


def install(loop, coord, make_coord):
    loop.add_round_tap(bad_attr_tap)
    loop.add_round_tap(bad_mutator_tap)
    coord.on_round = bad_alias_tap
    coord.on_steal = bad_aug_tap
    make_coord(on_round=bad_item_tap)
    loop.add_round_tap(lambda o: o.decisions.pop())

"""liferaft-lint (tools/analysis): per-rule fixtures, waivers, baseline,
journal schema drift regression, and an end-to-end zero-findings run.

Fixtures live in tests/lint_fixtures/ — that directory is excluded from
tree walks (the seeded violations must never fail the real lint run) and
is analyzed here explicitly, file by file.
"""
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.analysis.framework import (  # noqa: E402
    AnalyzerConfig,
    Baseline,
    Finding,
    analyze_paths,
    collect_files,
    parse_file,
    run_passes,
)
from tools.analysis.passes import ALL_PASSES, rule_catalog  # noqa: E402
from tools.analysis.passes.determinism import DeterminismPass  # noqa: E402
from tools.analysis.passes.journal_schema import (  # noqa: E402
    JournalSchemaPass,
    extract_schema,
)
from tools.analysis.passes.lockorder import LockOrderPass  # noqa: E402
from tools.analysis.passes.obs_tap import ObsTapPurityPass  # noqa: E402
from tools.analysis.passes.tracing import TracingPass  # noqa: E402

FIXTURES = REPO / "tests" / "lint_fixtures"
REAL_JOURNAL = REPO / "src" / "repro" / "core" / "journal.py"
REAL_MANIFEST = REPO / "tools" / "analysis" / "schema_manifest.json"

# Fixtures sit outside src/, so point the determinism pass at them.
FIXTURE_CONFIG = AnalyzerConfig(decision_paths=("tests/lint_fixtures/",))


def run_fixture(name, passes, config=FIXTURE_CONFIG):
    pf = parse_file(FIXTURES / name, root=str(REPO))
    return run_passes(pf, passes, config)


def rules_of(findings):
    return sorted(f.rule for f in findings)


# --------------------------------------------------------------- determinism
class TestDeterminismPass:
    def test_seeded_violations(self):
        findings = run_fixture("det_bad.py", [DeterminismPass()])
        assert rules_of(findings) == [
            "det-rng", "det-rng",
            "det-set-order", "det-set-order",
            "det-wallclock", "det-wallclock",
        ]

    def test_wallclock_exact_lines(self):
        findings = run_fixture("det_bad.py", [DeterminismPass()])
        wall = sorted(f.line for f in findings if f.rule == "det-wallclock")
        assert wall == [9, 10]  # time.time() and datetime.datetime.now()

    def test_clean_idioms_pass(self):
        assert run_fixture("det_good.py", [DeterminismPass()]) == []

    def test_pass_scoped_to_decision_paths(self):
        # Outside decision_paths the pass doesn't apply at all.
        findings = run_fixture(
            "det_bad.py", [DeterminismPass()], config=AnalyzerConfig()
        )
        assert findings == []


# -------------------------------------------------------------------- waivers
class TestWaivers:
    def test_reasoned_waiver_suppresses_reasonless_does_not(self):
        findings = run_fixture("det_waived.py", [DeterminismPass()])
        got = {(f.rule, f.line) for f in findings}
        # line 6 (reasoned waiver) fully suppressed; line 9 keeps the
        # original finding AND gains lint-bad-waiver.
        assert got == {("det-set-order", 9), ("lint-bad-waiver", 9)}

    def test_waiver_only_covers_named_rules(self, tmp_path):
        src = (FIXTURES / "det_bad.py").read_text()
        # Waive the wrong rule on the time.time() line: must not suppress.
        src = src.replace(
            "deadline = time.time() + 5.0",
            "deadline = time.time() + 5.0  # lint: allow[det-rng] wrong rule",
        )
        p = tmp_path / "wrong_rule.py"
        p.write_text(src)
        pf = parse_file(p, root=str(tmp_path))
        config = AnalyzerConfig(decision_paths=("wrong_rule.py",))
        findings = run_passes(pf, [DeterminismPass()], config)
        assert ("det-wallclock", 9) in {(f.rule, f.line) for f in findings}


# ------------------------------------------------------------------ lock order
class TestLockOrderPass:
    def test_seeded_violations(self):
        findings = run_fixture("lock_bad.py", [LockOrderPass()])
        assert rules_of(findings) == [
            "lock-bare-acquire",
            "lock-blocking-io",
            "lock-order-inversion",
            "lock-order-inversion",
        ]

    def test_inverted_steal_is_flagged_at_inner_acquire(self):
        findings = run_fixture("lock_bad.py", [LockOrderPass()])
        inv = [f for f in findings if f.rule == "lock-order-inversion"]
        assert 13 in {f.line for f in inv}  # steal lock under shard lock
        assert any("steal" in f.message for f in inv)

    def test_documented_hierarchy_passes(self):
        # sorted-unpack pair, ascending constants, acquire+try/finally,
        # fsync outside the lock: all clean.
        assert run_fixture("lock_good.py", [LockOrderPass()]) == []


# --------------------------------------------------------------------- tracing
class TestTracingPass:
    def test_seeded_violations(self):
        findings = run_fixture("trace_bad.py", [TracingPass()])
        got = {(f.rule, f.line) for f in findings}
        assert got == {
            ("trace-py-branch", 10),   # if x > 0 on a traced arg
            ("trace-concretize", 17),  # float(x) on a traced arg
            ("trace-shape-pow2", 23),  # ad-hoc jnp.pad
        }

    def test_static_and_shape_branches_pass(self):
        # static_argnames branch, x.shape[0] branch, pads routed through
        # _pad_rows/_pow2_ceil: all clean.
        assert run_fixture("trace_good.py", [TracingPass()]) == []

    def test_real_kernel_modules_are_clean(self):
        findings = analyze_paths(
            [str(REPO / "src" / "repro" / "kernels")],
            [TracingPass()],
            AnalyzerConfig(),
            root=str(REPO),
        )
        assert findings == []


# ------------------------------------------------------------- obs tap purity
class TestObsTapPurity:
    def test_seeded_violations(self):
        findings = run_fixture("obs_bad.py", [ObsTapPurityPass()])
        assert rules_of(findings) == ["obs-tap-pure"] * 6
        got = sorted(f.line for f in findings)
        # attr assign, mutator call, alias mutation, augassign, item
        # assign, and the inline bad lambda at its registration site.
        assert got == [5, 9, 14, 18, 22, 31]

    def test_clean_idioms_pass(self):
        # Class-instance __call__, inst.method registration, mutate-a-copy,
        # the sink=sink capture idiom, an unregistered mutating function,
        # and clearing a tap slot with None: all clean.
        assert run_fixture("obs_good.py", [ObsTapPurityPass()]) == []

    def test_reasoned_waiver_suppresses_reasonless_does_not(self):
        findings = run_fixture("obs_waived.py", [ObsTapPurityPass()])
        got = {(f.rule, f.line) for f in findings}
        assert got == {("obs-tap-pure", 10), ("lint-bad-waiver", 10)}

    def test_real_obs_adapters_are_clean(self):
        # The marquee target: the shipped hot tap (_LoopTap) registers via
        # add_round_tap and must itself satisfy the rule.
        pf = parse_file(
            REPO / "src" / "repro" / "obs" / "adapters.py", root=str(REPO)
        )
        assert run_passes(pf, [ObsTapPurityPass()], AnalyzerConfig()) == []

    def test_replay_recorders_are_clean(self):
        pf = parse_file(REPO / "tests" / "replay.py", root=str(REPO))
        assert run_passes(pf, [ObsTapPurityPass()], AnalyzerConfig()) == []


# -------------------------------------------------------------- journal schema
def _mini_manifest(tmp_path, fields=("decisions", "cost"), version=1):
    p = tmp_path / "manifest.json"
    p.write_text(json.dumps({"version": version, "fields": sorted(fields)}))
    return str(p)


class TestJournalSchemaPass:
    def test_unconsumed_field_flagged(self, tmp_path):
        config = AnalyzerConfig(schema_manifest=_mini_manifest(tmp_path))
        findings = run_fixture(
            "journal_bad.py", [JournalSchemaPass()], config=config
        )
        # debug_note is both unconsumed and added without a version bump.
        assert rules_of(findings) == [
            "journal-field-unconsumed", "journal-version-drift",
        ]
        assert all(f.line == 10 for f in findings)  # the emit site

    def test_agreeing_schema_passes(self, tmp_path):
        config = AnalyzerConfig(schema_manifest=_mini_manifest(tmp_path))
        assert run_fixture(
            "journal_good.py", [JournalSchemaPass()], config=config
        ) == []

    def test_unversioned_field_is_exactly_version_drift(self, tmp_path):
        # Field consumed by diff_entries but added without a version bump:
        # the ONLY finding must be journal-version-drift.
        src = (FIXTURES / "journal_good.py").read_text()
        src = src.replace(
            '"cost": float(outcome.cost),',
            '"cost": float(outcome.cost),\n        "extra": 0,',
        ).replace('("decisions", "cost")', '("decisions", "cost", "extra")')
        p = tmp_path / "journal_drift.py"
        p.write_text(src)
        pf = parse_file(p, root=str(tmp_path))
        config = AnalyzerConfig(schema_manifest=_mini_manifest(tmp_path))
        findings = run_passes(pf, [JournalSchemaPass()], config)
        assert rules_of(findings) == ["journal-version-drift"]

    def test_version_bump_clears_drift(self, tmp_path):
        src = (FIXTURES / "journal_good.py").read_text()
        src = src.replace(
            '"cost": float(outcome.cost),',
            '"cost": float(outcome.cost),\n        "extra": 0,',
        ).replace('("decisions", "cost")', '("decisions", "cost", "extra")')
        src = src.replace(
            "TRACE_SCHEMA_VERSION = 1", "TRACE_SCHEMA_VERSION = 2"
        )
        p = tmp_path / "journal_bumped.py"
        p.write_text(src)
        pf = parse_file(p, root=str(tmp_path))
        config = AnalyzerConfig(schema_manifest=_mini_manifest(tmp_path))
        assert run_passes(pf, [JournalSchemaPass()], config) == []

    def test_removed_field_flagged_at_version_line(self, tmp_path):
        config = AnalyzerConfig(
            schema_manifest=_mini_manifest(
                tmp_path, fields=("decisions", "cost", "vanished")
            )
        )
        findings = run_fixture(
            "journal_good.py", [JournalSchemaPass()], config=config
        )
        assert rules_of(findings) == ["journal-version-drift"]
        assert "vanished" in findings[0].message


class TestRealJournalSchema:
    """Satellite: drift regression against the actual core/journal.py."""

    def test_manifest_matches_reality(self):
        schema = extract_schema(
            __import__("ast").parse(REAL_JOURNAL.read_text())
        )
        manifest = json.loads(REAL_MANIFEST.read_text())
        assert sorted(schema["emitted"]) == manifest["fields"]
        assert schema["version"] == manifest["version"]

    def test_real_journal_is_clean(self):
        pf = parse_file(REAL_JOURNAL, root=str(REPO))
        assert run_passes(pf, [JournalSchemaPass()], AnalyzerConfig()) == []

    def _mutate(self, bump_version):
        src = REAL_JOURNAL.read_text()
        emit_anchor = (
            '"spill_changed": [int(b) for b in outcome.spill_changed],'
        )
        diff_anchor = '"decisions", "cost", "vector", "spill_changed", "stall",'
        assert emit_anchor in src and diff_anchor in src
        src = src.replace(
            emit_anchor, emit_anchor + '\n        "synthetic_flux": 1.0,'
        )
        # Also consume it, so only the version-drift rule is in play.
        src = src.replace(diff_anchor, diff_anchor + ' "synthetic_flux",')
        if bump_version:
            src = src.replace(
                "TRACE_SCHEMA_VERSION = 1", "TRACE_SCHEMA_VERSION = 2"
            )
        return src

    def test_new_field_without_bump_is_flagged(self, tmp_path):
        p = tmp_path / "journal_mutated.py"
        p.write_text(self._mutate(bump_version=False))
        pf = parse_file(p, root=str(tmp_path))
        findings = run_passes(pf, [JournalSchemaPass()], AnalyzerConfig())
        assert rules_of(findings) == ["journal-version-drift"]
        assert "synthetic_flux" in findings[0].message

    def test_new_field_with_bump_passes(self, tmp_path):
        p = tmp_path / "journal_bumped.py"
        p.write_text(self._mutate(bump_version=True))
        pf = parse_file(p, root=str(tmp_path))
        assert run_passes(pf, [JournalSchemaPass()], AnalyzerConfig()) == []


# ------------------------------------------------------------------- baseline
class TestBaseline:
    def test_baseline_suppresses_old_but_not_new(self):
        old = Finding("a.py", 3, "det-rng", "msg one")
        base = Baseline.from_findings([old])
        moved = Finding("a.py", 9, "det-rng", "msg one")  # same fingerprint
        fresh = Finding("a.py", 4, "det-rng", "msg two")
        assert base.new_findings([moved, fresh]) == [fresh]

    def test_counts_per_fingerprint(self):
        f = Finding("a.py", 1, "det-rng", "msg")
        base = Baseline.from_findings([f])
        dup = Finding("a.py", 2, "det-rng", "msg")
        # One grandfathered, the second occurrence is new.
        assert len(base.new_findings([f, dup])) == 1

    def test_roundtrip(self, tmp_path):
        f = Finding("a.py", 1, "det-rng", "msg")
        path = tmp_path / "baseline.json"
        Baseline.from_findings([f, f]).save(path)
        loaded = Baseline.load(path)
        assert loaded.counts == {f.fingerprint(): 2}


# ------------------------------------------------------------------ framework
class TestFramework:
    def test_fixture_dir_excluded_from_tree_walk(self):
        files = collect_files([str(REPO / "tests")], root=str(REPO))
        assert not any("lint_fixtures" in str(p) for p in files)

    def test_finding_render_format(self):
        f = Finding("src/x.py", 12, "det-rng", "boom")
        assert f.render() == "src/x.py:12 det-rng boom"

    def test_rule_catalog_covers_all_rules(self):
        cat = rule_catalog()
        for rule in (
            "det-wallclock", "det-rng", "det-set-order",
            "lock-order-inversion", "lock-bare-acquire", "lock-blocking-io",
            "trace-py-branch", "trace-concretize", "trace-shape-pow2",
            "journal-field-unconsumed", "journal-version-drift",
            "obs-tap-pure",
            "lint-bad-waiver", "lint-syntax-error",
        ):
            assert rule in cat, rule

    def test_syntax_error_becomes_finding(self, tmp_path):
        p = tmp_path / "broken.py"
        p.write_text("def nope(:\n")
        findings = analyze_paths(
            [str(p)], ALL_PASSES, AnalyzerConfig(), root=str(tmp_path)
        )
        assert [f.rule for f in findings] == ["lint-syntax-error"]


# ------------------------------------------------------------------------ CLI
def run_cli(*argv):
    return subprocess.run(
        [sys.executable, "-m", "tools.analysis", *argv],
        cwd=str(REPO), capture_output=True, text=True,
    )


class TestCli:
    def test_e2e_merged_tree_is_clean(self):
        # The acceptance bar: the analyzer exits 0 over src/ and tests/.
        res = run_cli(
            "src", "tests", "--baseline", "tools/analysis/baseline.json"
        )
        assert res.returncode == 0, res.stdout + res.stderr
        assert "0 new finding(s)" in res.stdout

    def test_seeded_fixture_fails_with_rendered_findings(self):
        res = run_cli("tests/lint_fixtures/lock_bad.py")
        assert res.returncode == 1
        assert "lock-order-inversion" in res.stdout
        # file:line rule-id message
        assert "tests/lint_fixtures/lock_bad.py:13 " in res.stdout

    def test_write_baseline_then_clean(self, tmp_path):
        base = tmp_path / "b.json"
        res = run_cli(
            "tests/lint_fixtures/trace_bad.py",
            "--baseline", str(base), "--write-baseline",
        )
        assert res.returncode == 0, res.stdout + res.stderr
        res = run_cli(
            "tests/lint_fixtures/trace_bad.py", "--baseline", str(base)
        )
        assert res.returncode == 0
        assert "baselined" in res.stdout

    def test_list_rules(self):
        res = run_cli("--list-rules")
        assert res.returncode == 0
        assert "det-set-order" in res.stdout
        assert "journal-version-drift" in res.stdout

    def test_missing_path_is_usage_error(self):
        res = run_cli("no/such/dir")
        assert res.returncode == 2
